"""Multi-device behaviour on 8 host CPU devices (subprocess per case —
the device-count flag must be set before jax initializes, so these cannot
run in the main test process which pins 1 device)."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(body: str, n: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    # pin the backend: unset JAX_PLATFORMS makes jax probe for accelerator
    # plugins, which hangs on CPU-only CI hosts; the forced host device
    # count composes fine with an explicit cpu platform
    env["JAX_PLATFORMS"] = "cpu"
    script = "import jax, jax.numpy as jnp, numpy as np\n" + \
        textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_runs():
    run_devices("""
    from jax.sharding import Mesh
    from repro.configs.base import ModelConfig
    from repro.dist import sharding as shd
    from repro.models.model import get_model, make_batch
    from repro.optim import adamw
    from repro.train.loop import make_train_step

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      head_dim=16, vocab_pad_multiple=64, dtype="float32",
                      grad_accum=2)
    api = get_model(cfg)
    with shd.activate(mesh):
        params = api.init(jax.random.PRNGKey(0))
        specs = shd.param_specs(params, mesh)
        params = jax.tree_util.tree_map(jax.device_put, params, specs)
        ocfg = adamw.AdamWConfig(lr=1e-3)
        opt = adamw.init(params, ocfg)
        step = jax.jit(make_train_step(api, ocfg))
        batch = make_batch(cfg, 0, 8, 32)
        from repro.data.pipeline import shard_batch
        batch = shard_batch({k: np.asarray(v) for k, v in batch.items()},
                            mesh)
        p2, o2, m = step(params, opt, batch, 0)
        assert bool(jnp.isfinite(m["loss"])), m
        # weights really are distributed
        w = p2["layers"]["ffn"]["gate"]
        assert len(w.sharding.device_set) > 1
    print("OK sharded train", float(m["loss"]))
    """)


def test_elastic_checkpoint_reshard():
    run_devices("""
    import tempfile
    from repro.configs.base import ModelConfig
    from repro.dist import sharding as shd
    from repro.models.model import get_model
    from repro.train import checkpoint as C

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      head_dim=16, vocab_pad_multiple=64, dtype="float32")
    api = get_model(cfg)
    mesh_a = jax.make_mesh((2, 2), ("data", "model"),
                           devices=jax.devices()[:4])
    mesh_b = jax.make_mesh((4, 2), ("data", "model"))
    params = api.init(jax.random.PRNGKey(0))
    specs_a = shd.param_specs(params, mesh_a)
    params_a = jax.tree_util.tree_map(jax.device_put, params, specs_a)
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 5, params_a)
        specs_b = shd.param_specs(params, mesh_b)
        restored, step = C.restore(d, params, shardings=specs_b)
        assert step == 5
        for a, b in zip(jax.tree_util.tree_leaves(params_a),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored on the BIGGER mesh
        w = restored["layers"]["ffn"]["gate"]
        assert len(w.sharding.device_set) > 4
    print("OK elastic reshard")
    """)


def test_compressed_allreduce():
    run_devices("""
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.dist.compression import compressed_allreduce_mean, wire_bytes

    mesh = jax.make_mesh((8,), ("pod",))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 1024)) * \
        (1 + jnp.arange(8)[:, None]).astype(jnp.float32)

    @partial(shard_map, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))
    def comp_mean(xs):
        m, err = compressed_allreduce_mean(xs[0], "pod")
        return m[None]

    exact = jnp.mean(x, axis=0)
    approx = comp_mean(x)[0]
    rel = float(jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact))
    assert rel < 0.02, rel
    comp, un = wire_bytes(x[0])
    assert comp < un / 3.5
    print("OK compressed allreduce rel", rel)
    """)


def test_error_feedback_reduces_bias():
    run_devices("""
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.dist.compression import compressed_allreduce_mean

    mesh = jax.make_mesh((4,), ("pod",), devices=jax.devices()[:4])
    g = jax.random.normal(jax.random.PRNGKey(1), (4, 512))

    @partial(shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")),
             out_specs=(P("pod"), P("pod")))
    def step(xs, errs):
        m, e = compressed_allreduce_mean(xs[0], "pod", errs[0])
        return m[None], e[None]

    exact = jnp.mean(g, axis=0)
    err = jnp.zeros_like(g)
    # same gradient repeatedly: error feedback drives the ACCUMULATED mean
    # toward the exact accumulated value
    acc = jnp.zeros_like(exact)
    acc_exact = jnp.zeros_like(exact)
    for t in range(8):
        m, err = step(g, err)
        acc = acc + m[0]
        acc_exact = acc_exact + exact
    rel = float(jnp.linalg.norm(acc - acc_exact) /
                jnp.linalg.norm(acc_exact))
    assert rel < 0.005, rel
    print("OK error feedback rel", rel)
    """)


def test_ring_collective_matmuls():
    run_devices("""
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.dist.collective_matmul import (ring_allgather_matmul,
                                              ring_matmul_reducescatter)

    mesh = jax.make_mesh((8,), ("model",))
    B, K, N = 16, 64, 32
    x = jax.random.normal(jax.random.PRNGKey(0), (B, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N))
    y_ref = x @ w

    @partial(shard_map, mesh=mesh, in_specs=(P(None, "model"), P(None, "model")),
             out_specs=P(None, "model"))
    def ag_mm(xs, ws):
        return ring_allgather_matmul(xs, ws, "model")

    y1 = ag_mm(x, w)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)

    @partial(shard_map, mesh=mesh, in_specs=(P(None, "model"), P("model")),
             out_specs=P(None, "model"))
    def rs_mm(xs, ws):
        return ring_matmul_reducescatter(xs, ws, "model")

    y2 = rs_mm(x, w)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    print("OK ring matmuls")
    """)


def test_pipeline_parallel_matches_sequential():
    run_devices("""
    from repro.dist.pipeline import make_pipelined_apply

    mesh = jax.make_mesh((4,), ("stage",), devices=jax.devices()[:4])
    S, D = 4, 32
    ws = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) / jnp.sqrt(D)

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    n_micro = 6
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, 8, D))
    apply = make_pipelined_apply(stage_fn, mesh, n_micro)
    y_pipe = apply(ws, x)
    # sequential reference
    y_ref = x
    for s in range(S):
        y_ref = jnp.tanh(y_ref @ ws[s])
    np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-5)
    print("OK pipeline")
    """)


def test_mini_production_mesh_compiles_multipod_shape():
    """2x2x2 ("pod","data","model") miniature of the 2x16x16 mesh: the full
    512-device version runs in launch/dryrun.py; this guards the code path
    in CI time."""
    run_devices("""
    from repro.configs.base import ModelConfig
    from repro.dist import sharding as shd
    from repro.launch import shapes as shp
    from repro.launch.dryrun import build_cell

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      head_dim=16, vocab_pad_multiple=64, grad_accum=2)
    spec = shp.ShapeSpec("mini_train", 64, 8, "train")
    with shd.activate(mesh):
        fn, args = build_cell(cfg, spec, mesh, "axllm-int8")
        compiled = fn.lower(*args).compile()
        ma = compiled.memory_analysis()
        assert getattr(ma, "temp_size_in_bytes", 1) >= 0
    spec_d = shp.ShapeSpec("mini_decode", 128, 8, "decode")
    with shd.activate(mesh):
        fn, args = build_cell(cfg, spec_d, mesh, "axllm-int8")
        fn.lower(*args).compile()
    print("OK mini multi-pod compile")
    """)


def test_seqsharded_decode_matches_reference():
    """Fused shard_map decode (local cache update + flash combine) must be
    numerically identical to the unsharded reference path."""
    run_devices("""
    from repro.configs.base import ModelConfig
    from repro.dist import sharding as shd
    from repro.models import attention as A
    from repro.models.model import get_model, make_batch

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      head_dim=16, vocab_pad_multiple=64, dtype="float32")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 0, 4, 8)
    # reference on 1 device, no mesh
    cache = api.init_cache(4, 32)
    lp_ref, cache_ref = api.prefill(params, batch, cache)
    nxt = jnp.argmax(lp_ref[:, : cfg.vocab_size], -1).astype(jnp.int32)
    ld_ref, _ = api.decode(params, nxt, cache_ref)

    # sharded: mesh (2 data, 4 model); kv=2 -> cache seq shards over model
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with shd.activate(mesh):
        cache2 = api.init_cache(4, 32)
        cspec = shd.cache_specs(jax.eval_shape(lambda: api.init_cache(4, 32)),
                                mesh, 4, 32)
        # sanity: the seq dim really is sharded
        assert "model" in str(cspec["k"].spec), cspec["k"].spec
        cache2 = jax.tree_util.tree_map(jax.device_put, cache2, cspec)
        lp2, cache2 = jax.jit(api.prefill)(params, batch, cache2)
        ld2, _ = jax.jit(api.decode)(params, nxt, cache2)
    np.testing.assert_allclose(np.asarray(ld2), np.asarray(ld_ref),
                               rtol=2e-4, atol=2e-4)
    print("OK seq-sharded decode")
    """)
