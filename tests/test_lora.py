"""LoRA (paper §III, Fig. 5): merge equivalence, quantized-base adapters,
combined [W ‖ A] reuse statistics."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import axllm_linear as AL
from repro.core import reuse as R
from repro.core import simulator as S
from repro.core.quantization import QuantConfig, quantize


def test_lora_zero_init_is_identity():
    rng = jax.random.PRNGKey(0)
    w = jax.random.normal(rng, (64, 32))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    cfg = AL.LoRAConfig(rank=8)
    ad = AL.lora_init(rng, 64, 32, cfg)
    y = AL.lora_linear(x, w, ad, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-6)


def test_merge_equivalence():
    rng = jax.random.PRNGKey(2)
    w = jax.random.normal(rng, (64, 32))
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
    cfg = AL.LoRAConfig(rank=8)
    ad = AL.lora_init(rng, 64, 32, cfg)
    ad = dict(ad, lora_b=jax.random.normal(jax.random.PRNGKey(4), (8, 32))
              * 0.1)
    y1 = AL.lora_linear(x, w, ad, cfg)
    y2 = x @ AL.merge_lora(w, ad, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)


def test_lora_on_quantized_base():
    rng = jax.random.PRNGKey(5)
    w = jax.random.normal(rng, (512, 256))
    x = jax.random.normal(jax.random.PRNGKey(6), (8, 512))
    qt = quantize(w, QuantConfig())
    cfg = AL.LoRAConfig(rank=8)
    ad = AL.lora_init(rng, 512, 256, cfg)
    ad = dict(ad, lora_b=jax.random.normal(jax.random.PRNGKey(7), (8, 256))
              * 0.1)
    y_ref = AL.lora_linear(x, qt, ad, cfg, impl="ref")
    y_pal = AL.lora_linear(x, qt, ad, cfg, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-4)


def test_combined_matrix_reuse_beats_standalone():
    """Fig. 5: processing [W ‖ A] lets A's elements reuse W's RC entries —
    the combined reuse rate exceeds A's standalone rate."""
    rng = np.random.default_rng(0)
    w = S.gaussian_codes(rng, 256, 768)
    a = S.gaussian_codes(rng, 256, 16)
    ra_alone = R.reuse_rate(a, None)
    combined = np.concatenate([w, a], axis=1)
    # marginal reuse of A's columns inside the combined matrix
    uniq_w = R.segment_unique_counts(w, None).sum()
    uniq_c = R.segment_unique_counts(combined, None).sum()
    marginal_unique = uniq_c - uniq_w
    ra_combined = 1 - marginal_unique / a.size
    assert ra_combined > ra_alone
    assert ra_combined > 0.85
