"""Reuse (LUT) matmul validation: the paper's Result-Cache arithmetic
on device (kernels/reuse_matmul.py + kernels/ops.reuse_matmul).

Three contracts:

1. Bit-exactness. In the integer/dyadic regime (integer activations,
   scale = qmax * 2^-e) every product and partial sum is exactly
   representable in f32, so the reuse path — gather-from-LUT instead of
   multiply — must reproduce the exact int64 matmul BIT-FOR-BIT, in both
   the jnp oracle and the Pallas kernel (interpret mode). Codebook modes
   with an integer table get the same treatment; NF4 (irrational table
   values) is association-sensitive and compared at tolerance against
   the multiply path.

2. Measured reuse. The kernel counts the multiplies it cannot avoid
   (distinct alphabet cells per (k-row, bn-wide column segment)); that
   count must equal ``core.reuse.segment_unique_counts`` on the same
   codes with the same fold — the number the simulator and Fig. 8
   analytics predict. One number, three independent implementations.

3. Alphabet pinning (regression for the PR-1 double-fold bug class):
   ``core.reuse.rc_alphabet`` is the single source of the (levels,
   fold_sign) contract; these tests pin its values and its agreement
   with ``fold_codes`` so the simulator and kernel cannot drift apart —
   including the packed-int4 trap where raw code *bytes* look like
   valid uint8 cells.
"""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import reuse as R
from repro.core.quantization import (QTensor, QuantConfig, nf4_codebook,
                                     pack_int4, quantize)
from repro.kernels import ops

M, K, N = 64, 512, 256


def _qtensor(codes, scale, bits, mode, packed=False, granularity=None,
             group_size=128):
    c = pack_int4(jnp.asarray(codes)) if packed else jnp.asarray(codes)
    gran = granularity or ("per_group" if np.asarray(scale).shape[0] > 1
                           else "per_channel")
    return QTensor(codes=c, scale=jnp.asarray(scale), codebook=None,
                   bits=bits, mode=mode, granularity=gran,
                   group_size=group_size, packed=packed, shape=codes.shape)


def _int_x(seed, m=M):
    rng = np.random.default_rng(seed)
    return rng, jnp.asarray(rng.integers(-8, 9, size=(m, K)), jnp.float32)


REUSE_PATHS = ("reuse_ref", "reuse_interpret")


# ---------------------------------------------------------------------------
# 1. bit-exact golden tests (integer/dyadic regime)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", REUSE_PATHS)
def test_affine_int8_bit_exact(impl):
    rng, x = _int_x(0)
    codes = rng.integers(-127, 128, size=(K, N)).astype(np.int8)
    qt = _qtensor(codes, np.full((1, N), 127.0 * 2.0 ** -3, np.float32),
                  8, "affine")
    exact = ((np.asarray(x, np.int64) @ codes.astype(np.int64))
             * 2.0 ** -3).astype(np.float32)
    y, _ = ops.reuse_matmul(x, qt, impl=impl)
    np.testing.assert_array_equal(np.asarray(y), exact)


@pytest.mark.parametrize("impl", REUSE_PATHS)
@pytest.mark.parametrize("packed", [False, True])
def test_affine_int4_bit_exact(impl, packed):
    rng, x = _int_x(1)
    codes = rng.integers(-7, 8, size=(K, N)).astype(np.int8)
    qt = _qtensor(codes, np.full((1, N), 7.0 * 2.0 ** -2, np.float32),
                  4, "affine", packed=packed)
    exact = ((np.asarray(x, np.int64) @ codes.astype(np.int64))
             * 2.0 ** -2).astype(np.float32)
    y, _ = ops.reuse_matmul(x, qt, impl=impl)
    np.testing.assert_array_equal(np.asarray(y), exact)


@pytest.mark.parametrize("impl", REUSE_PATHS)
def test_affine_per_group_bit_exact(impl):
    rng, x = _int_x(2)
    codes = rng.integers(-127, 128, size=(K, N)).astype(np.int8)
    exps = rng.integers(-4, 1, size=(K // 128, N))
    scale = (127.0 * 2.0 ** exps).astype(np.float32)
    qt = _qtensor(codes, scale, 8, "affine", granularity="per_group")
    xi = np.asarray(x, np.int64)
    exact = np.zeros((M, N), np.float64)
    for g in range(K // 128):
        part = xi[:, g * 128:(g + 1) * 128] @ \
            codes[g * 128:(g + 1) * 128].astype(np.int64)
        exact += part * (2.0 ** exps[g])[None, :]
    y, _ = ops.reuse_matmul(x, qt, impl=impl)
    np.testing.assert_array_equal(np.asarray(y), exact.astype(np.float32))


@pytest.mark.parametrize("impl", REUSE_PATHS)
def test_codebook_int8_tracks_float_reference(impl):
    """The identity-8 table is normalized (code/127), so products are
    rounded and the reuse decomposition (per-level gather-sums, then
    scale) reorders the additions vs the multiply path's single dot —
    bitwise equality is not a well-defined contract here (unlike the
    dyadic affine regime). Compare against the float64 ground truth at
    f32 tolerance instead."""
    rng, x = _int_x(3)
    codes = rng.integers(-128, 128, size=(K, N)).astype(np.int8)
    qt = _qtensor(codes, np.full((1, N), 2.0 ** -4, np.float32),
                  8, "codebook")
    truth = (np.asarray(x, np.float64)
             @ (codes.astype(np.float64) / 127.0) * 2.0 ** -4)
    y, _ = ops.reuse_matmul(x, qt, impl=impl)
    np.testing.assert_allclose(np.asarray(y), truth, rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("impl", REUSE_PATHS)
def test_codebook_nf4_matches_multiply_path(impl):
    """NF4 table values are not integers, so (x*cb)*s vs x*(cb*s) may
    differ in the last ulp — compare against the multiply-path oracle at
    f32 tolerance instead of bitwise."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    qt = quantize(jnp.asarray(rng.standard_normal((K, N)), jnp.float32),
                  QuantConfig(4, "codebook", "per_channel"))
    y_mul = ops.axllm_matmul(x, qt, impl="ref")
    y_reu, _ = ops.reuse_matmul(x, qt, impl=impl)
    np.testing.assert_allclose(np.asarray(y_reu), np.asarray(y_mul),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("qcfg", [
    QuantConfig(8, "affine", "per_channel"),
    QuantConfig(8, "affine", "per_group", group_size=128),
    QuantConfig(8, "affine", "per_tensor"),
    QuantConfig(8, "codebook", "per_channel"),
    QuantConfig(4, "codebook", "per_channel", pack=True),
    QuantConfig(4, "affine", "per_channel", pack=True),
], ids=lambda c: f"{c.bits}b-{c.mode}-{c.granularity}")
def test_reuse_matches_multiply_all_quant_modes(qcfg):
    """Every deployable quant config: reuse oracle and interpret-mode
    kernel agree with the multiply path on real quantized weights."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((32, K)), jnp.float32)
    qt = quantize(jnp.asarray(rng.standard_normal((K, N)), jnp.float32),
                  qcfg)
    y_mul = np.asarray(ops.axllm_matmul(x, qt, impl="ref"))
    for impl in REUSE_PATHS:
        y, _ = ops.reuse_matmul(x, qt, impl=impl)
        np.testing.assert_allclose(np.asarray(y), y_mul,
                                   rtol=2e-5, atol=2e-4, err_msg=impl)


def test_reuse_skinny_decode_shapes():
    """m = 1 (single-token decode) pads to the block table's bm."""
    rng = np.random.default_rng(6)
    qt = quantize(jnp.asarray(rng.standard_normal((K, N)), jnp.float32),
                  QuantConfig(8, "affine", "per_channel"))
    for m in (1, 3, 8):
        x = jnp.asarray(rng.standard_normal((m, K)), jnp.float32)
        y_mul = np.asarray(ops.axllm_matmul(x, qt, impl="ref"))
        y, _ = ops.reuse_matmul(x, qt, impl="reuse_interpret")
        assert y.shape == (m, N)
        np.testing.assert_allclose(np.asarray(y), y_mul,
                                   rtol=2e-5, atol=2e-4)


def test_reuse_leading_batch_dims_and_dtype():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((2, 4, K)), jnp.bfloat16)
    qt = quantize(jnp.asarray(rng.standard_normal((K, N)), jnp.float32),
                  QuantConfig(8, "affine", "per_channel"))
    y = ops.axllm_matmul(x, qt, impl="reuse_ref")
    assert y.shape == (2, 4, N) and y.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# 2. measured multiply count == analytics prediction
# ---------------------------------------------------------------------------

@st.composite
def quant_codes(draw):
    bits = draw(st.sampled_from([4, 8]))
    mode = draw(st.sampled_from(["affine", "codebook"]))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.default_rng(seed)
    lo, hi = (-7, 8) if bits == 4 else (-127, 128)
    if mode == "codebook":
        lo, hi = (-8, 8) if bits == 4 else (-128, 128)
    codes = rng.integers(lo, hi, size=(K, N)).astype(np.int8)
    return bits, mode, codes


@given(quant_codes())
@settings(deadline=None, max_examples=12)
def test_kernel_mult_count_matches_segment_unique_counts(case):
    bits, mode, codes = case
    qt = _qtensor(codes, np.full((1, N), 1.0, np.float32), bits, mode)
    x = jnp.ones((4, K), jnp.float32)
    levels, fold = R.rc_alphabet(bits, mode)
    _, _, bn, _ = ops.pick_blocks(4, K, N, reuse_levels=len(levels))
    expect = int(R.segment_unique_counts(codes, bn, fold_sign=fold).sum())
    _, m_ref = ops.reuse_matmul(x, qt, impl="reuse_ref", with_stats=True)
    _, m_ker = ops.reuse_matmul(x, qt, impl="reuse_interpret",
                                with_stats=True)
    assert int(m_ref) == expect
    assert int(m_ker) == expect


def test_mult_count_packed_equals_unpacked():
    """Nibble packing is storage, not semantics: the kernel must count
    the same distinct cells either way."""
    rng = np.random.default_rng(8)
    codes = rng.integers(-7, 8, size=(K, N)).astype(np.int8)
    scale = np.full((1, N), 7.0, np.float32)
    x = jnp.ones((4, K), jnp.float32)
    counts = []
    for packed in (False, True):
        qt = _qtensor(codes, scale, 4, "affine", packed=packed)
        _, m = ops.reuse_matmul(x, qt, impl="reuse_interpret",
                                with_stats=True)
        counts.append(int(m))
    assert counts[0] == counts[1]


def test_with_stats_false_is_jit_safe():
    """The serving default must stay traceable: stats off -> no host
    callback, usable inside the jitted decode hot path."""
    rng = np.random.default_rng(9)
    qt = quantize(jnp.asarray(rng.standard_normal((K, N)), jnp.float32),
                  QuantConfig(8, "affine", "per_channel"))

    @jax.jit
    def f(a):
        y, mults = ops.reuse_matmul(a, qt, impl="reuse_ref")
        assert mults is None
        return y

    x = jnp.asarray(rng.standard_normal((4, K)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(f(x)), np.asarray(ops.axllm_matmul(x, qt, impl="ref")),
        rtol=2e-5, atol=2e-4)


# ---------------------------------------------------------------------------
# 3. alphabet pinning (simulator <-> kernel contract)
# ---------------------------------------------------------------------------

def test_rc_alphabet_pinned_values():
    lv8, fold8 = R.rc_alphabet(8, "affine")
    assert fold8 is True and lv8.dtype == np.float32
    np.testing.assert_array_equal(lv8, np.arange(128, dtype=np.float32))
    lv4, fold4 = R.rc_alphabet(4, "affine")
    assert fold4 is True
    np.testing.assert_array_equal(lv4, np.arange(8, dtype=np.float32))
    nf4, foldn = R.rc_alphabet(4, "codebook")
    assert foldn is False and len(nf4) == 16
    np.testing.assert_array_equal(nf4, np.asarray(nf4_codebook(),
                                                  np.float32))
    id8, foldi = R.rc_alphabet(8, "codebook")
    assert foldi is False and len(id8) == 256
    with pytest.raises(ValueError):
        R.rc_alphabet(8, "nonsense")


def test_codebook_counts_use_unfolded_cells():
    """Codebook mode indexes the explicit 2^bits table — folding there
    would conflate codes c and -c whose table entries are distinct rows
    (and the identity-8 table's -128 entry has no positive mirror at
    all). Pin that the measured count equals the UNFOLDED analytics and
    differs from the folded one, so an accidental re-fold (the PR-1 bug
    class) trips this test."""
    rng = np.random.default_rng(20)
    codes = rng.integers(-8, 8, size=(K, N)).astype(np.int8)
    qt = _qtensor(codes, np.full((1, N), 1.0, np.float32), 4, "codebook")
    levels, fold = R.rc_alphabet(4, "codebook")
    assert fold is False
    _, _, bn, _ = ops.pick_blocks(4, K, N, reuse_levels=len(levels))
    unfolded = int(R.segment_unique_counts(codes, bn,
                                           fold_sign=False).sum())
    folded = int(R.segment_unique_counts(codes, bn, fold_sign=True).sum())
    assert folded < unfolded  # ±c pairs collapse under a fold
    x = jnp.ones((4, K), jnp.float32)
    _, mults = ops.reuse_matmul(x, qt, impl="reuse_interpret",
                                with_stats=True)
    assert int(mults) == unfolded != folded


@pytest.mark.parametrize("bits,mode", [(8, "affine"), (4, "affine"),
                                       (8, "codebook"), (4, "codebook")])
def test_kernel_cell_mapping_matches_fold_codes(bits, mode):
    """The kernel indexes its LUT as |c| (folded) or c + L/2 (unfolded);
    fold_codes uses |c| or c + 128. Both must induce the same partition
    of codes into cells — same distinct-count everywhere — or measured
    and predicted reuse drift apart."""
    levels, fold = R.rc_alphabet(bits, mode)
    n_levels = len(levels)
    if mode == "affine":
        lo, hi = -(n_levels - 1), n_levels
    else:
        lo, hi = -(n_levels // 2), n_levels // 2
    codes = np.arange(lo, hi, dtype=np.int32)
    kernel_cells = np.abs(codes) if fold else codes + (n_levels >> 1)
    lib_cells = R.fold_codes(codes.reshape(1, -1), fold_sign=fold).ravel()
    assert kernel_cells.min() >= 0
    assert kernel_cells.max() < n_levels
    # same partition: two codes share a kernel cell iff they share a
    # fold_codes cell (injective re-labeling)
    pairs = {}
    for kc, lc in zip(kernel_cells, lib_cells):
        assert pairs.setdefault(kc, lc) == lc
    assert len(set(pairs.values())) == len(pairs)


def test_fold_codes_rejects_packed_bytes():
    """Raw packed-int4 storage bytes must not silently count as cells
    (the kernel_bench provenance bug this PR fixed)."""
    rng = np.random.default_rng(10)
    codes = rng.integers(-7, 8, size=(64, 64)).astype(np.int8)
    packed = np.asarray(pack_int4(jnp.asarray(codes)))
    assert packed.dtype == np.uint8
    with pytest.raises(ValueError, match="packed"):
        R.fold_codes(packed, fold_sign=False)
    qt = _qtensor(codes, np.full((1, 64), 7.0, np.float32), 4, "affine",
                  packed=True)
    # the QTensor path decodes first and matches the unpacked counts
    np.testing.assert_array_equal(
        R.fold_codes(qt, fold_sign=True),
        R.fold_codes(codes, fold_sign=True))


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def test_axllm_matmul_reuse_impl_dispatch():
    """axllm_matmul(impl='reuse*') routes through the reuse path and
    matches its own multiply path."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((8, K)), jnp.float32)
    qt = quantize(jnp.asarray(rng.standard_normal((K, N)), jnp.float32),
                  QuantConfig(8, "affine", "per_channel"))
    y_mul = np.asarray(ops.axllm_matmul(x, qt, impl="ref"))
    for impl in ("reuse", "reuse_ref", "reuse_interpret"):
        y = np.asarray(ops.axllm_matmul(x, qt, impl=impl))
        np.testing.assert_allclose(y, y_mul, rtol=2e-5, atol=2e-4,
                                   err_msg=impl)


def test_reuse_impl_flows_through_linear_and_lora():
    from repro.core.axllm_linear import linear
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.standard_normal((8, K)), jnp.float32)
    qt = quantize(jnp.asarray(rng.standard_normal((K, N)), jnp.float32),
                  QuantConfig(8, "affine", "per_channel"))
    y_mul = np.asarray(linear(x, qt, impl="auto"))
    y_reu = np.asarray(linear(x, qt, impl="reuse"))
    np.testing.assert_allclose(y_reu, y_mul, rtol=2e-5, atol=2e-4)
    a = jnp.asarray(rng.standard_normal((K, 8)) * 0.05, jnp.float32)
    b = jnp.asarray(rng.standard_normal((8, N)) * 0.05, jnp.float32)
    y_l_mul = np.asarray(ops.lora_matmul(x, qt, a, b, 2.0, impl="auto"))
    y_l_reu = np.asarray(ops.lora_matmul(x, qt, a, b, 2.0, impl="reuse"))
    np.testing.assert_allclose(y_l_reu, y_l_mul, rtol=2e-5, atol=2e-4)


def test_attention_ops_normalize_reuse_impl():
    """Reuse is a matmul concept; attention ops must treat impl='reuse'
    as their base dispatch instead of failing on an unknown string."""
    rng = np.random.default_rng(13)
    q = jnp.asarray(rng.standard_normal((2, 8, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 8, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 8, 2, 16)), jnp.float32)
    y_auto = np.asarray(ops.flash_attention(q, k, v, impl="auto"))
    y_reuse = np.asarray(ops.flash_attention(q, k, v, impl="reuse"))
    np.testing.assert_array_equal(y_reuse, y_auto)


# ---------------------------------------------------------------------------
# end-to-end: serve decode token-identity (acceptance criterion)
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="reuse-e2e", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab_size=256, head_dim=16, vocab_pad_multiple=64,
                       dtype="float32")


@pytest.mark.parametrize("quant,bits,mode,fuse", [
    (False, None, "affine", False),     # fp32 weights, reuse impl inert
    (True, 8, "affine", False),
    (True, 8, "affine", True),          # fused wqkv/gate_up
    (True, 4, "affine", False),         # packed int4
    (True, 4, "codebook", False),       # NF4
    (True, 4, "codebook", True),
], ids=["fp32", "int8", "int8-fused", "int4", "nf4", "nf4-fused"])
def test_engine_reuse_decode_token_identity(quant, bits, mode, fuse):
    """The acceptance bar: an engine dispatching every projection through
    the reuse path decodes the exact same tokens as the multiply path."""
    from repro.models.model import get_model
    from repro.serve.engine import ServeEngine
    cfg = _tiny_cfg()
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(14)
    prompts = [rng.integers(0, cfg.vocab_size, size=pl).astype(np.int32)
               for pl in (5, 9, 3)]
    outs = {}
    for impl in ("auto", "reuse"):
        eng = ServeEngine(cfg, params, n_slots=2, max_len=64,
                          quantize=quant, quant_bits=bits, quant_mode=mode,
                          fuse_qkv=fuse, impl=impl)
        outs[impl] = eng.generate(prompts, max_new=8)
    for a, b in zip(outs["auto"], outs["reuse"]):
        assert a == b


@pytest.mark.slow
def test_engine_reuse_interpret_smoke():
    """One decode step through the actual kernel body (interpret mode) —
    slow, so marked out of the tier-1 default run."""
    from repro.models.model import get_model
    from repro.serve.engine import ServeEngine
    cfg = _tiny_cfg()
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    prompts = [np.asarray([5, 7, 11], np.int32)]
    out_mul = ServeEngine(cfg, params, n_slots=1, max_len=16,
                          quantize=True, impl="auto").generate(
        prompts, max_new=2)
    out_int = ServeEngine(cfg, params, n_slots=1, max_len=16,
                          quantize=True, impl="reuse_interpret").generate(
        prompts, max_new=2)
    assert out_mul == out_int


# ---------------------------------------------------------------------------
# 5. ring collectives x reuse path (tensor-parallel serving, PR 7)
# ---------------------------------------------------------------------------

@pytest.mark.multi_device
@pytest.mark.parametrize("gran", ["per_channel", "per_group"])
def test_ring_allgather_matmul_matches_reuse_bit_exact(
        eight_cpu_devices, gran):
    """ring_allgather_matmul on a column-sharded QTensor must equal
    ops.reuse_matmul on the gathered operand BIT-FOR-BIT in the dyadic
    regime: the ring splits K into per-device blocks, each block runs the
    same reuse arithmetic, and the f32 block sums stay exact (partial
    sums < 2^24 * 2^-e), so the changed association cannot round."""
    from functools import partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist.collective_matmul import ring_allgather_matmul

    mesh = jax.make_mesh((4,), ("model",),
                         devices=eight_cpu_devices[:4])
    rng, x = _int_x(3)
    codes = rng.integers(-127, 128, size=(K, N)).astype(np.int8)
    if gran == "per_group":
        g = 128
        scale = np.full((K // g, 1, N), 127.0 * 2.0 ** -3, np.float32)
    else:
        scale = np.full((1, N), 127.0 * 2.0 ** -3, np.float32)
    qt = _qtensor(codes, scale, 8, "affine", granularity=gran)
    y_ref, _ = ops.reuse_matmul(x, qt, impl="reuse_ref")

    # shard_map moves the raw leaves; the local QTensor shard (full K
    # rows, N/4 columns) is rebuilt inside the body
    scale_spec = P(None, None, "model") if gran == "per_group" \
        else P(None, "model")

    @partial(shard_map, mesh=mesh,
             in_specs=(P(None, "model"), P(None, "model"), scale_spec),
             out_specs=P(None, "model"))
    def ring(x_l, codes_l, scale_l):
        w_l = QTensor(codes=codes_l, scale=scale_l, codebook=None,
                      bits=8, mode="affine", granularity=gran,
                      group_size=128, packed=False,
                      shape=(K, codes_l.shape[-1]))
        return ring_allgather_matmul(x_l, w_l, "model", impl="reuse_ref")

    y = ring(x, qt.codes, qt.scale)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


@pytest.mark.multi_device
def test_ring_reducescatter_matmul_matches_reuse_bit_exact(
        eight_cpu_devices):
    """The row-parallel half: x column-sharded, W row-sharded, output
    reduce-scattered over N — still bit-exact vs the gathered reuse
    matmul in the dyadic regime (per-shard partials are exact dyadics)."""
    from functools import partial

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist.collective_matmul import ring_matmul_reducescatter

    mesh = jax.make_mesh((4,), ("model",),
                         devices=eight_cpu_devices[:4])
    rng, x = _int_x(4)
    codes = rng.integers(-127, 128, size=(K, N)).astype(np.int8)
    qt = _qtensor(codes, np.full((1, N), 127.0 * 2.0 ** -3, np.float32),
                  8, "affine")
    y_ref, _ = ops.reuse_matmul(x, qt, impl="reuse_ref")

    @partial(shard_map, mesh=mesh,
             in_specs=(P(None, "model"), P("model", None), P(None, None)),
             out_specs=P(None, "model"))
    def ring(x_l, codes_l, scale_l):
        w_l = QTensor(codes=codes_l, scale=scale_l, codebook=None,
                      bits=8, mode="affine", granularity="per_channel",
                      group_size=128, packed=False,
                      shape=codes_l.shape)
        return ring_matmul_reducescatter(x_l, w_l, "model",
                                         impl="reuse_ref")

    y = ring(x, qt.codes, qt.scale)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
