"""Golden tests for the AxLLM reuse path.

1. The fused Pallas dequant-matmul must match the ref.py dense matmul
   BIT-FOR-BIT on int8/int4 codes. Two regimes make bitwise equality a
   well-defined contract instead of a tolerance:
     * codebook mode — both impls read the identical RC table entry per
       code (the one-hot MXU lookup is exact), so the dequantized weights
       agree elementwise and identically-shaped f32 dots agree bitwise;
     * affine mode with dyadic scales (scale = qmax * 2^-e) — every
       product and partial sum is an integer times 2^-e, exactly
       representable in f32 well below 2^24, so BOTH impls must equal the
       int64 numpy matmul no matter their summation order.

2. The analytic reuse rate (core/reuse.py, the Fig. 8 metric) must equal
   the cycle simulator's counted multiply savings: the simulator executes
   a miss per first occurrence of an RC cell per segment and a hit per
   repeat, so rc_hits / total_ops is the same quantity reuse_rate()
   computes combinatorially.
"""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import reuse as R
from repro.core.quantization import QTensor, pack_int4
from repro.core.simulator import SimConfig, simulate_matrix
from repro.kernels import ops

M, K, N = 64, 512, 256  # one full (bm, bk, bn) kernel block


def _qtensor(codes, scale, bits, mode, packed=False):
    """codes is always the unpacked [K, N] int8 array; `packed` stores it
    two-per-byte the way deploy-time quantization would."""
    c = pack_int4(jnp.asarray(codes)) if packed else jnp.asarray(codes)
    return QTensor(codes=c, scale=jnp.asarray(scale), codebook=None,
                   bits=bits, mode=mode, granularity="per_channel",
                   group_size=128, packed=packed, shape=codes.shape)


def _int_inputs(seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-8, 9, size=(M, K)).astype(np.float32)
    scale = (2.0 ** rng.integers(-4, 3, size=(1, N))).astype(np.float32)
    return rng, jnp.asarray(x), scale


def test_codebook_int8_bit_for_bit():
    rng, x, scale = _int_inputs(0)
    codes = rng.integers(-127, 128, size=(K, N)).astype(np.int8)
    qt = _qtensor(codes, scale, 8, "codebook")
    y_ref = np.asarray(ops.axllm_matmul(x, qt, impl="ref"))
    y_pal = np.asarray(ops.axllm_matmul(x, qt, impl="pallas_interpret"))
    np.testing.assert_array_equal(y_pal, y_ref)


def test_codebook_int4_packed_bit_for_bit():
    rng, x, scale = _int_inputs(1)
    codes = rng.integers(-8, 8, size=(K, N)).astype(np.int8)
    qt = _qtensor(codes, scale, 4, "codebook", packed=True)
    y_ref = np.asarray(ops.axllm_matmul(x, qt, impl="ref"))
    y_pal = np.asarray(ops.axllm_matmul(x, qt, impl="pallas_interpret"))
    np.testing.assert_array_equal(y_pal, y_ref)


def test_affine_int8_exact_integer_semantics():
    """With dyadic scales both impls must reproduce the exact int64
    matmul bit-for-bit — the strongest form of the paper's 'preserves
    exact arithmetic semantics' claim (§II)."""
    rng, x, _ = _int_inputs(2)
    codes = rng.integers(-127, 128, size=(K, N)).astype(np.int8)
    scale = np.full((1, N), 127.0 * 2.0 ** -3, np.float32)
    qt = _qtensor(codes, scale, 8, "affine")
    exact = ((np.asarray(x, np.int64) @ codes.astype(np.int64))
             * 2.0 ** -3).astype(np.float32)
    y_ref = np.asarray(ops.axllm_matmul(x, qt, impl="ref"))
    y_pal = np.asarray(ops.axllm_matmul(x, qt, impl="pallas_interpret"))
    np.testing.assert_array_equal(y_pal, exact)
    np.testing.assert_array_equal(y_ref, exact)


def test_affine_int4_exact_integer_semantics():
    rng, x, _ = _int_inputs(3)
    codes = rng.integers(-7, 8, size=(K, N)).astype(np.int8)
    scale = np.full((1, N), 7.0 * 2.0 ** -2, np.float32)
    qt = _qtensor(codes, scale, 4, "affine", packed=True)
    exact = ((np.asarray(x, np.int64) @ codes.astype(np.int64))
             * 2.0 ** -2).astype(np.float32)
    y_ref = np.asarray(ops.axllm_matmul(x, qt, impl="ref"))
    y_pal = np.asarray(ops.axllm_matmul(x, qt, impl="pallas_interpret"))
    np.testing.assert_array_equal(y_pal, exact)
    np.testing.assert_array_equal(y_ref, exact)


# ---------------------------------------------------------------------------
# reuse_rate vs the cycle simulator's counted savings
# ---------------------------------------------------------------------------

@st.composite
def code_matrices(draw):
    n = draw(st.integers(1, 24))
    m = draw(st.integers(1, 400))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.default_rng(seed)
    return rng.integers(-127, 128, size=(n, m)).astype(np.int32)


@given(code_matrices(), st.sampled_from([64, 256, 512]),
       st.sampled_from([True, False]))
@settings(deadline=None, max_examples=25)
def test_reuse_rate_matches_simulator_savings(codes, buf, fold):
    cfg = SimConfig(buf=buf, fold_sign=fold)
    rep = simulate_matrix(codes, cfg, measure_hazards=False)
    # every op is either an executed multiply or an RC hit, no third bucket
    assert rep.mults + rep.rc_hits == rep.total_ops == codes.size
    analytic = R.reuse_rate(codes, buf, fold_sign=fold)
    # same integer counts; the two float expressions (hits/total vs
    # 1 - uniq/total) may differ in the last ulp
    assert abs(rep.reuse_rate - analytic) < 1e-12
    # counted savings == eliminated multiplies
    assert rep.rc_hits == codes.size - \
        R.segment_unique_counts(codes, buf, fold_sign=fold).sum()


@given(code_matrices(), st.integers(1, 4))
@settings(deadline=None, max_examples=10)
def test_simulator_token_scaling_preserves_rate(codes, tokens):
    """The RC clears between inputs (§III.c): reuse rate is per-token
    invariant while absolute savings scale linearly."""
    cfg = SimConfig(buf=256)
    r1 = simulate_matrix(codes, cfg, tokens=1, measure_hazards=False)
    rt = simulate_matrix(codes, cfg, tokens=tokens, measure_hazards=False)
    assert rt.reuse_rate == r1.reuse_rate
    assert rt.rc_hits == tokens * r1.rc_hits
