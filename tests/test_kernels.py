"""Pallas kernel validation: interpret-mode sweeps vs the pure-jnp oracles
(shape x dtype x quant-mode grids per kernel)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantization import QuantConfig, quantize
from repro.kernels import ops, ref


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# axllm_matmul
# ---------------------------------------------------------------------------

MATMUL_SHAPES = [(8, 512, 256), (100, 512, 256), (128, 1024, 512),
                 (256, 512, 1024), (1, 512, 256)]
QUANT_CONFIGS = [
    QuantConfig(8, "affine", "per_channel"),
    QuantConfig(8, "affine", "per_group", group_size=128),
    QuantConfig(8, "affine", "per_tensor"),
    QuantConfig(8, "codebook", "per_channel"),
    QuantConfig(4, "codebook", "per_channel", pack=True),
    QuantConfig(4, "affine", "per_channel", pack=True),
    QuantConfig(4, "affine", "per_channel", pack=False),
]


@pytest.mark.parametrize("shape", MATMUL_SHAPES)
def test_axllm_matmul_shapes(shape):
    m, k, n = shape
    rng = np.random.default_rng(0)
    x = _rand(rng, (m, k))
    qt = quantize(_rand(rng, (k, n)), QUANT_CONFIGS[0])
    y_ref = ops.axllm_matmul(x, qt, impl="ref")
    y_pal = ops.axllm_matmul(x, qt, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("qcfg", QUANT_CONFIGS,
                         ids=lambda c: f"{c.bits}b-{c.mode}-{c.granularity}"
                         f"{'-packed' if c.pack and c.bits == 4 else ''}")
def test_axllm_matmul_quant_modes(qcfg):
    rng = np.random.default_rng(1)
    x = _rand(rng, (64, 512))
    qt = quantize(_rand(rng, (512, 256)), qcfg)
    y_ref = ops.axllm_matmul(x, qt, impl="ref")
    y_pal = ops.axllm_matmul(x, qt, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_axllm_matmul_dtypes(dtype):
    rng = np.random.default_rng(2)
    x = _rand(rng, (32, 512), dtype)
    qt = quantize(_rand(rng, (512, 256)), QUANT_CONFIGS[0])
    y_ref = ops.axllm_matmul(x, qt, impl="ref")
    y_pal = ops.axllm_matmul(x, qt, impl="pallas_interpret")
    assert y_pal.dtype == dtype
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-2, atol=2e-1)


def test_axllm_matmul_leading_batch_dims():
    rng = np.random.default_rng(3)
    x = _rand(rng, (2, 16, 512))
    qt = quantize(_rand(rng, (512, 256)), QUANT_CONFIGS[0])
    y = ops.axllm_matmul(x, qt, impl="pallas_interpret")
    assert y.shape == (2, 16, 256)


def test_lora_matmul_matches_ref():
    rng = np.random.default_rng(4)
    x = _rand(rng, (16, 512))
    qt = quantize(_rand(rng, (512, 256)), QUANT_CONFIGS[0])
    a = _rand(rng, (512, 8))
    b = _rand(rng, (8, 256))
    y1 = ops.lora_matmul(x, qt, a, b, 2.0, impl="ref")
    y2 = ops.lora_matmul(x, qt, a, b, 2.0, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               rtol=2e-5, atol=2e-4)


# ---------------------------------------------------------------------------
# Decode-shape block table (pad decision lives in the table, not the call)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [8, 16, 24, 32, 40, 48, 56, 64, 72, 96, 120])
def test_pick_blocks_no_pad_fast_path(m):
    """Every multiple of 8 in the decode range dispatches without
    re-padding M (the old table rounded up to the next power of two)."""
    bm, bk, bn, pad_m = ops.pick_blocks(m, 512, 256)
    assert pad_m == 0 and m % bm == 0


def test_pick_blocks_widens_bn_for_skinny_m():
    bm, _, bn, _ = ops.pick_blocks(8, 1024, 1024)
    assert bm == 8 and bn == 512          # decode shape: wide N tiles
    bm, _, bn, _ = ops.pick_blocks(256, 1024, 1024)
    assert bm == 128 and bn == 256        # prefill shape: default tiling


def test_pick_blocks_divisor_safe():
    """Shapes the old min(512, k) rule would crash on (k % bk != 0)."""
    for m, k, n in [(8, 384, 320), (16, 768, 640), (2, 96, 48)]:
        bm, bk, bn, pad_m = ops.pick_blocks(m, k, n)
        assert k % bk == 0 and n % bn == 0 and (m + pad_m) % bm == 0


def test_pick_blocks_per_group_alignment():
    bm, bk, bn, _ = ops.pick_blocks(8, 640, 256, group_size=128,
                                    per_group=True)
    assert bk % 128 == 0 and 640 % bk == 0


@pytest.mark.parametrize("k,gs", [(384, 128), (1536, 512), (96, 32)])
def test_pick_blocks_per_group_k_not_multiple_of_gs_bk(k, gs):
    """k a non-power-of-two multiple of the group size (e.g. 3 groups):
    the group-aligned bk must still divide k exactly — the naive
    (bk // gs) * gs of a power-of-two bk does not."""
    bm, bk, bn, pad_m = ops.pick_blocks(16, k, 256, group_size=gs,
                                        per_group=True)
    assert bk % gs == 0 and k % bk == 0
    assert pad_m == 0


def test_pick_blocks_skinny_m8_with_per_group():
    """The skinny-decode fast path and per-group alignment compose: m=8
    keeps the no-pad bm=8 row and the widened bn, while bk snaps to the
    group grid."""
    bm, bk, bn, pad_m = ops.pick_blocks(8, 512, 1024, group_size=128,
                                        per_group=True)
    assert bm == 8 and pad_m == 0
    assert bn == 512                        # skinny launch widens N tiles
    assert bk % 128 == 0 and 512 % bk == 0
    # odd skinny m with per_group still pads up to the bm=8 row
    bm, bk, bn, pad_m = ops.pick_blocks(9, 512, 1024, group_size=128,
                                        per_group=True)
    assert bm == 8 and pad_m == 7 and bk % 128 == 0


@pytest.mark.parametrize("m", [8, 24, 48])
def test_axllm_matmul_no_pad_shapes_interpret(m):
    """The no-pad decode shapes produce correct results end to end."""
    rng = np.random.default_rng(7)
    x = _rand(rng, (m, 512))
    qt = quantize(_rand(rng, (512, 256)), QUANT_CONFIGS[0])
    y_ref = ops.axllm_matmul(x, qt, impl="ref")
    y_pal = ops.axllm_matmul(x, qt, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-4)


def test_axllm_matmul_wide_bn_skinny_m_interpret():
    """Skinny m widens bn to 512 — exercise that tile shape end to end,
    not just the table entry."""
    rng = np.random.default_rng(8)
    m, k, n = 8, 256, 512
    assert ops.pick_blocks(m, k, n)[:3] == (8, 256, 512)
    x = _rand(rng, (m, k))
    qt = quantize(_rand(rng, (k, n)), QUANT_CONFIGS[0])
    y_ref = ops.axllm_matmul(x, qt, impl="ref")
    y_pal = ops.axllm_matmul(x, qt, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, Sq, Sk, H, Hk, d, causal)
    (2, 256, 256, 4, 4, 64, True),
    (2, 256, 512, 8, 2, 64, True),      # GQA + longer KV
    (1, 512, 512, 4, 1, 128, True),     # MQA
    (2, 256, 256, 4, 4, 64, False),
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_vs_oracle(case):
    b, sq, sk, h, hk, d, causal = case
    rng = np.random.default_rng(5)
    q = _rand(rng, (b, sq, h, d))
    k = _rand(rng, (b, sk, hk, d))
    v = _rand(rng, (b, sk, hk, d))
    o_ref = ref.attention_ref(q, k, v, causal=causal)
    o_pal = ops.flash_attention(q, k, v, causal=causal,
                                impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_chunked_oracle_matches_dense():
    rng = np.random.default_rng(6)
    q = _rand(rng, (2, 200, 4, 32))
    k = _rand(rng, (2, 300, 2, 32))
    v = _rand(rng, (2, 300, 2, 32))
    for causal in (True, False):
        o1 = ref.attention_ref(q, k, v, causal=causal)
        o2 = ref.chunked_attention_ref(q, k, v, causal=causal, chunk=128)
        np.testing.assert_allclose(np.asarray(o2), np.asarray(o1),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

def _kv_quant(x):
    s = jnp.maximum(jnp.max(jnp.abs(x), -1, keepdims=True), 1e-8) / 127.0
    return (jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8),
            s.astype(jnp.float32))


@pytest.mark.parametrize("case", [(2, 1024, 8, 2, 64), (1, 2048, 4, 4, 128),
                                  (4, 512, 4, 1, 64)])
def test_decode_attention_vs_oracle(case):
    b, s, h, hk, d = case
    rng = np.random.default_rng(7)
    q = _rand(rng, (b, h, d))
    kc = _rand(rng, (b, s, hk, d))
    vc = _rand(rng, (b, s, hk, d))
    length = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
    o_ref = ref.decode_attention_ref(q, kc, vc, length)
    o_pal = ops.decode_attention(q, kc, vc, length, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_int8_kv():
    rng = np.random.default_rng(8)
    b, s, h, hk, d = 2, 1024, 8, 2, 64
    q = _rand(rng, (b, h, d))
    kc = _rand(rng, (b, s, hk, d))
    vc = _rand(rng, (b, s, hk, d))
    kq, ks = _kv_quant(kc)
    vq, vs = _kv_quant(vc)
    length = jnp.asarray([700, 1024], jnp.int32)
    o_ref = ref.decode_attention_ref(q, kq, vq, length, k_scale=ks,
                                     v_scale=vs)
    o_pal = ops.decode_attention(q, kq, vq, length, k_scale=ks, v_scale=vs,
                                 impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
    # int8-KV error vs exact stays small
    o_exact = ref.decode_attention_ref(q, kc, vc, length)
    rel = np.abs(np.asarray(o_ref) - np.asarray(o_exact)).max() \
        / np.abs(np.asarray(o_exact)).max()
    assert rel < 0.05


def test_decode_attention_int8_kv_length_zero_rows():
    """length == 0 rows (empty slots riding through a batched decode) must
    come back as exact zeros on both paths — a fully masked softmax must
    not renormalize into a uniform average of garbage."""
    rng = np.random.default_rng(9)
    b, s, h, hk, d = 3, 512, 8, 2, 64
    q = _rand(rng, (b, h, d))
    kq, ks = _kv_quant(_rand(rng, (b, s, hk, d)))
    vq, vs = _kv_quant(_rand(rng, (b, s, hk, d)))
    length = jnp.asarray([0, 130, 0], jnp.int32)
    o_ref = ref.decode_attention_ref(q, kq, vq, length, k_scale=ks,
                                     v_scale=vs)
    o_pal = ops.decode_attention(q, kq, vq, length, k_scale=ks, v_scale=vs,
                                 impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
    assert np.allclose(np.asarray(o_ref)[[0, 2]], 0.0)
    assert np.allclose(np.asarray(o_pal)[[0, 2]], 0.0)


def test_decode_attention_non_divisible_cache_length():
    """S=768 with the default 512 block used to raise; the kernel now
    falls back to the largest power-of-two divisor block."""
    rng = np.random.default_rng(10)
    b, s, h, hk, d = 2, 768, 4, 2, 64
    q = _rand(rng, (b, h, d))
    kc = _rand(rng, (b, s, hk, d))
    vc = _rand(rng, (b, s, hk, d))
    length = jnp.asarray([700, 768], jnp.int32)
    o_ref = ref.decode_attention_ref(q, kc, vc, length)
    o_pal = ops.decode_attention(q, kc, vc, length, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# quantize kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(512, 512), (1024, 256), (128, 1024)])
def test_quantize_kernel_vs_oracle(shape):
    rng = np.random.default_rng(9)
    w = _rand(rng, shape)
    c1, s1 = ops.quantize_channels(w, impl="ref")
    c2, s2 = ops.quantize_channels(w, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
