"""Multi-LoRA serving: adapter registry lifecycle, the gathered batched
delta pipeline, and engine equivalence — a mixed batch of base + N
distinct adapters must decode token-identically to per-request runs
(fp + int8 + interpret mode, fused and unfused), while recurrent
families reject registries with a clear error."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import axllm_linear as AL
from repro.models.model import get_model
from repro.serve.adapters import AdapterRegistry, target_dims
from repro.serve.engine import ServeEngine

CFG = ModelConfig(name="la", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, vocab_pad_multiple=64, dtype="float32")
LCFG = AL.LoRAConfig(rank=4, alpha=8.0, targets=("wq", "wv", "wo"))


def make_adapter(cfg, lcfg, seed, scale=0.3, targets=None):
    """Random adapter with non-zero B (so it measurably changes tokens)."""
    rng = np.random.default_rng(seed)
    ad = {}
    for t in targets or lcfg.targets:
        n_in, n_out = target_dims(cfg, t)
        ad[t] = {
            "lora_a": jnp.asarray(
                rng.normal(size=(cfg.n_layers, n_in, lcfg.rank))
                / np.sqrt(lcfg.rank), jnp.float32),
            "lora_b": jnp.asarray(
                rng.normal(size=(cfg.n_layers, lcfg.rank, n_out)) * scale,
                jnp.float32),
        }
    return ad


@pytest.fixture(scope="module")
def params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


@pytest.fixture()
def registry():
    reg = AdapterRegistry(CFG, LCFG, max_loras=3)
    reg.add("a1", make_adapter(CFG, LCFG, 1))
    reg.add("a2", make_adapter(CFG, LCFG, 2))
    return reg


# ---------------------------------------------------------------------------
# lora_delta_batched: the gathered second pipeline
# ---------------------------------------------------------------------------

def test_delta_batched_matches_unbatched_rows():
    """Row i of the batched gathered delta == the unbatched two-matmul
    LoRA delta with adapter idx[i]; -1 rows are exact zeros."""
    rng = np.random.default_rng(0)
    L, n_in, r, n_out = 3, 16, 4, 24
    stack = {"lora_a": jnp.asarray(rng.normal(size=(L, n_in, r)),
                                   jnp.float32),
             "lora_b": jnp.asarray(rng.normal(size=(L, r, n_out)),
                                   jnp.float32)}
    x = jnp.asarray(rng.normal(size=(4, 5, n_in)), jnp.float32)
    idx = jnp.asarray([2, -1, 0, 1], jnp.int32)
    out = AL.lora_delta_batched(x, stack, idx, 0.5)
    assert out.shape == (4, 5, n_out)
    np.testing.assert_array_equal(np.asarray(out[1]), 0.0)
    for i, j in ((0, 2), (2, 0), (3, 1)):
        ref = 0.5 * (x[i] @ stack["lora_a"][j]) @ stack["lora_b"][j]
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)


def test_delta_batched_all_base_is_zero():
    stack = {"lora_a": jnp.ones((2, 8, 4)), "lora_b": jnp.ones((2, 4, 8))}
    x = jnp.ones((3, 8))
    out = AL.lora_delta_batched(x, stack, jnp.full((3,), -1, jnp.int32), 2.0)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


# ---------------------------------------------------------------------------
# Registry lifecycle + validation
# ---------------------------------------------------------------------------

def test_registry_add_index_evict():
    reg = AdapterRegistry(CFG, LCFG, max_loras=2)
    assert len(reg) == 0
    row1 = reg.add("fr", make_adapter(CFG, LCFG, 1))
    row2 = reg.add("de", make_adapter(CFG, LCFG, 2))
    assert {row1, row2} == {0, 1}
    assert reg.index_of("de") == row2 and "fr" in reg
    reg.evict("fr")
    assert "fr" not in reg and len(reg) == 1
    # the freed row is reused and its tensors were zeroed
    assert reg.add("es", make_adapter(CFG, LCFG, 3)) == row1


def test_registry_full_and_duplicate():
    reg = AdapterRegistry(CFG, LCFG, max_loras=1)
    reg.add("fr", make_adapter(CFG, LCFG, 1))
    with pytest.raises(ValueError, match="already registered"):
        reg.add("fr", make_adapter(CFG, LCFG, 2))
    with pytest.raises(RuntimeError, match="registry full"):
        reg.add("de", make_adapter(CFG, LCFG, 2))


def test_registry_rank_mismatch():
    reg = AdapterRegistry(CFG, LCFG, max_loras=2)
    wrong = make_adapter(CFG, dataclasses.replace(LCFG, rank=8), 1)
    with pytest.raises(ValueError, match="rank 8 != registry rank 4"):
        reg.add("fr", wrong)


def test_registry_rejects_quantized_adapter():
    """Quantize-check: the delta pipeline stays dense by construction."""
    from repro.core.quantization import QuantConfig, quantize
    reg = AdapterRegistry(CFG, LCFG, max_loras=2)
    ad = make_adapter(CFG, LCFG, 1)
    ad["wq"]["lora_b"] = quantize(ad["wq"]["lora_b"], QuantConfig())
    with pytest.raises(TypeError, match="QTensor"):
        reg.add("fr", ad)


def test_registry_unknown_target():
    reg = AdapterRegistry(CFG, LCFG, max_loras=2)
    ad = make_adapter(CFG, LCFG, 1)
    ad["gate"] = ad.pop("wq")
    with pytest.raises(ValueError, match="targets"):
        reg.add("fr", ad)


def test_registry_missing_target_is_identity(params):
    """An adapter targeting only wq leaves wv/wo rows zero — serving it
    must equal serving a single-target adapter, not crash or drift."""
    reg = AdapterRegistry(CFG, LCFG, max_loras=2)
    reg.add("q-only", make_adapter(CFG, LCFG, 5, targets=("wq",)))
    eng = ServeEngine(CFG, params, n_slots=1, max_len=64, adapters=reg)
    out = eng.generate([np.arange(8)], max_new=6, adapters=["q-only"])
    assert len(out[0]) == 6


def test_evict_while_assigned_raises(params, registry):
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, adapters=registry)
    eng.submit(np.arange(8), max_new=4, adapter="a1")
    with pytest.raises(RuntimeError, match="active request"):
        registry.evict("a1")
    eng.run()
    registry.evict("a1")                      # drained: now legal
    assert "a1" not in registry


def test_unknown_adapter_rejected_at_submit(params, registry):
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, adapters=registry)
    with pytest.raises(KeyError, match="unknown adapter"):
        eng.submit(np.arange(8), adapter="nope")
    with pytest.raises(ValueError, match="AdapterRegistry"):
        ServeEngine(CFG, params, n_slots=2, max_len=64).submit(
            np.arange(8), adapter="a1")


def test_registry_dim_mismatch_at_engine_init(params):
    other = dataclasses.replace(CFG, n_layers=3)
    reg = AdapterRegistry(other, LCFG)
    with pytest.raises(ValueError, match="n_layers"):
        ServeEngine(CFG, params, n_slots=2, max_len=64, adapters=reg)


def test_recurrent_family_rejects_registry():
    cfg = ModelConfig(name="lsx", family="ssm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=256,
                      vocab_pad_multiple=64, xlstm_slstm_every=2,
                      dtype="float32", remat=False)
    p = get_model(cfg).init(jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="no multi-LoRA serving path"):
        ServeEngine(cfg, p, n_slots=2, max_len=64,
                    adapters=AdapterRegistry(cfg, LCFG))


# ---------------------------------------------------------------------------
# Engine equivalence: mixed batch == per-request
# ---------------------------------------------------------------------------

PROMPTS = [np.arange(8), np.arange(8) + 50, np.arange(12) + 100]
NAMES = [None, "a1", "a2"]


def _mixed_vs_solo(cfg, params, registry, *, quantize=False, impl="auto",
                   fuse_qkv=None, max_new=8):
    """Assert one mixed engine run == three solo runs, token for token."""
    eng = ServeEngine(cfg, params, n_slots=len(PROMPTS), max_len=64,
                      quantize=quantize, impl=impl, fuse_qkv=fuse_qkv,
                      adapters=registry)
    mixed = eng.generate(PROMPTS, max_new=max_new, adapters=NAMES)
    for p, name, got in zip(PROMPTS, NAMES, mixed):
        solo = ServeEngine(cfg, params, n_slots=1, max_len=64,
                           quantize=quantize, impl=impl, fuse_qkv=fuse_qkv,
                           adapters=registry)
        assert got == solo.generate([p], max_new=max_new,
                                    adapters=[name])[0], name
    return mixed


@pytest.mark.slow
def test_mixed_batch_equals_per_request_fp(params, registry):
    mixed = _mixed_vs_solo(CFG, params, registry)
    # the adapters actually steer generation away from the base model
    base = ServeEngine(CFG, params, n_slots=1, max_len=64).generate(
        [PROMPTS[1]], max_new=8)[0]
    assert mixed[1] != base
    # base-only rows are bit-identical to a no-registry engine
    assert mixed[0] == ServeEngine(CFG, params, n_slots=1,
                                   max_len=64).generate([PROMPTS[0]],
                                                        max_new=8)[0]


def test_mixed_batch_equals_per_request_int8(params, registry):
    _mixed_vs_solo(CFG, params, registry, quantize=True)


@pytest.mark.slow
def test_mixed_batch_int8_interpret_mode(params, registry):
    """Pallas kernel body (interpret mode) under the batched LoRA path."""
    _mixed_vs_solo(CFG, params, registry, quantize=True,
                   impl="pallas_interpret", max_new=3)


def test_fused_qkv_lora_matches_unfused(params, registry):
    """Adapter deltas land in the fused wqkv output's q/k/v columns —
    fused and unfused mixed batches decode token-identically."""
    unfused = _mixed_vs_solo(CFG, params, registry, quantize=True)
    eng = ServeEngine(CFG, params, n_slots=3, max_len=64, quantize=True,
                      fuse_qkv=True, adapters=registry)
    assert eng.generate(PROMPTS, max_new=8, adapters=NAMES) == unfused


def test_lora_decode_matches_direct_api(params, registry):
    """Engine serving == raw api.prefill/api.decode greedy loop with the
    same stacked adapters (the scheduler adds nothing numerically)."""
    api = get_model(CFG)
    name = "a1"
    idx = jnp.asarray([registry.index_of(name)], jnp.int32)
    prompt = PROMPTS[1]
    cache = api.init_cache(1, 64)
    logits, cache = api.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, cache,
        adapters=registry.stacked, adapter_idx=idx,
        lora_scaling=registry.scaling)
    toks = [int(jnp.argmax(logits[0, : CFG.vocab_size]))]
    while len(toks) < 6:
        logits, cache = api.decode(
            params, jnp.asarray([toks[-1]], jnp.int32), cache,
            adapters=registry.stacked, adapter_idx=idx,
            lora_scaling=registry.scaling)
        toks.append(int(jnp.argmax(logits[0, : CFG.vocab_size])))
    eng = ServeEngine(CFG, params, n_slots=1, max_len=64, adapters=registry)
    assert eng.generate([prompt], max_new=6, adapters=[name])[0] == toks


@pytest.mark.slow
def test_chunked_lora_decode_matches_per_token(params, registry):
    ref = ServeEngine(CFG, params, n_slots=2, max_len=64, decode_chunk=1,
                      adapters=registry).generate(
        PROMPTS, max_new=6, adapters=NAMES)
    for chunk in (3, 8):
        eng = ServeEngine(CFG, params, n_slots=2, max_len=64,
                          decode_chunk=chunk, adapters=registry)
        assert eng.generate(PROMPTS, max_new=6, adapters=NAMES) == ref


@pytest.mark.slow
def test_moe_family_mixed_batch():
    cfg = ModelConfig(name="lmo", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=256,
                      head_dim=16, vocab_pad_multiple=64, n_experts=4,
                      top_k=2, expert_pad_to=4, capacity_factor=8.0,
                      dtype="float32", remat=False)
    p = get_model(cfg).init(jax.random.PRNGKey(3))
    reg = AdapterRegistry(cfg, LCFG, max_loras=2)
    reg.add("a1", make_adapter(cfg, LCFG, 1))
    reg.add("a2", make_adapter(cfg, LCFG, 2))
    eng = ServeEngine(cfg, p, n_slots=3, max_len=64, adapters=reg)
    mixed = eng.generate(PROMPTS, max_new=5, adapters=NAMES)
    for pr, name, got in zip(PROMPTS, NAMES, mixed):
        solo = ServeEngine(cfg, p, n_slots=1, max_len=64, adapters=reg)
        assert got == solo.generate([pr], max_new=5, adapters=[name])[0]


def test_hot_add_evict_between_waves(params):
    """Swap an adapter mid-stream: stacked shapes are invariant, so the
    jitted prefill/decode callables are reused and new requests pick up
    the new weights."""
    reg = AdapterRegistry(CFG, LCFG, max_loras=2)
    reg.add("a1", make_adapter(CFG, LCFG, 1))
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, adapters=reg)
    first = eng.generate([PROMPTS[0]], max_new=6, adapters=["a1"])
    compiles = eng.stats.prefill_compiles
    reg.evict("a1")
    reg.add("a3", make_adapter(CFG, LCFG, 7))
    second = eng.generate([PROMPTS[0]], max_new=6, adapters=["a3"])
    assert eng.stats.prefill_compiles == compiles     # no recompiles
    assert second != first                            # new weights took
    solo = ServeEngine(CFG, params, n_slots=1, max_len=64, adapters=reg)
    assert second == solo.generate([PROMPTS[0]], max_new=6,
                                   adapters=["a3"])


def test_cancelled_lora_request_releases_adapter(params, registry):
    eng = ServeEngine(CFG, params, n_slots=1, max_len=64, adapters=registry)
    reqs = eng.generate([np.arange(8)] * 3, max_new=8, max_steps=2,
                        return_requests=True,
                        adapters=["a1", "a1", "a2"])
    assert any(r.truncated for r in reqs)
    assert registry.refcount("a1") == 0 and registry.refcount("a2") == 0
    registry.evict("a1")                              # nothing pinned
