"""Streaming serving: chunked prefill, token emission, cancellation-safe
teardown, and mid-run execution deadlines.

The invariants under test mirror docs/ARCHITECTURE.md's request lifecycle:
a prefill-token budget bounds every step's prefill work while staying
token-identical to the unbudgeted path (including across mid-prefill
preemption), `on_token` / `stream()` emit exactly the tokens the finished
request holds, cancellation at any lifecycle point (queued, mid-prefill
chunk, mid-decode, mid-speculative round) balances the books — slot,
blocks, adapter pins — while published prefixes survive for reuse, and
TTFT / inter-token deadlines expire a stream mid-run with its resources
freed.
"""

import itertools

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.model import get_model
from repro.serve.engine import ServeEngine, StopStream

CFG = ModelConfig(name="s", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, vocab_pad_multiple=64, dtype="float32")

MIXED = [np.arange(8), np.arange(31) + 7, np.arange(45) % 256,
         np.arange(12) + 40]

MAX_NEW = 6


@pytest.fixture(scope="module")
def params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def reference(params):
    eng = ServeEngine(CFG, params, n_slots=4, max_len=64)
    return eng.generate(MIXED, max_new=MAX_NEW)


def _paged(params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("kv_block_size", 8)
    return ServeEngine(CFG, params, paged=True, **kw)


# ---------------------------------------------------------------------------
# Chunked prefill
# ---------------------------------------------------------------------------

def test_budgeted_prefill_token_identical(params, reference):
    eng = _paged(params, prefill_budget=16)
    got = eng.generate(MIXED, max_new=MAX_NEW)
    assert got == reference
    # the 45-token prompt cannot fit one 16-token chunk: prefill really
    # was chunked, not just admitted whole
    assert eng.stats.prefill_chunks > len(MIXED)
    eng.pager.check_consistency()


def test_budget_bounds_every_steps_prefill(params):
    budget = 16
    eng = _paged(params, prefill_budget=budget)
    for p in MIXED:
        eng.submit(p, max_new=MAX_NEW)
    done = 0
    while True:
        before = eng.stats.prefill_tokens
        if not eng.step():
            break
        done += 1
        assert eng.stats.prefill_tokens - before <= budget
    assert done > 0
    # computed chunks + radix-reused prefix tokens cover every prompt
    assert eng.stats.prefill_tokens + eng.stats.prefix_hit_tokens == \
        sum(len(p) for p in MIXED)


def test_prefill_budget_init_rejections(params):
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(CFG, params, n_slots=2, max_len=64, prefill_budget=16)
    with pytest.raises(ValueError, match="kv_block_size"):
        _paged(params, prefill_budget=4)      # below one block
    with pytest.raises(ValueError, match="speculate"):
        _paged(params, prefill_budget=16, speculate=True)


def test_adopt_compiled_rejects_budget_mismatch(params):
    budgeted = _paged(params, prefill_budget=16)
    unbudgeted = _paged(params)
    with pytest.raises(ValueError):
        unbudgeted.adopt_compiled(budgeted)


def test_mid_prefill_preemption_token_identical(params, reference):
    """A long prompt preempted mid-prefill by higher-priority arrivals
    must publish its consumed prefix and resume token-identically."""
    eng = _paged(params, prefill_budget=8,
                 num_blocks=2 * 2 * 8 + 2)    # tight pool: preemption bites
    long_rid = eng.submit(MIXED[2], max_new=MAX_NEW, priority=0)
    eng.step()                                # first chunk consumed
    assert any(s is not None and s.prefilling for s in eng.slots)
    hi = [eng.submit(MIXED[0], max_new=MAX_NEW, priority=5),
          eng.submit(MIXED[3], max_new=MAX_NEW, priority=5)]
    while eng.step():
        pass
    assert eng.stats.preempted_prefill >= 1
    by_rid = {r.rid: r for r in eng.finished}
    assert by_rid[long_rid].tokens == reference[2]
    assert by_rid[hi[0]].tokens == reference[0]
    assert by_rid[hi[1]].tokens == reference[3]
    eng.pager.check_consistency()


# ---------------------------------------------------------------------------
# Streaming emission
# ---------------------------------------------------------------------------

def test_on_token_emits_exactly_the_finished_tokens(params, reference):
    got = {}

    def tap(req, tok):
        got.setdefault(req.rid, []).append(tok)

    eng = _paged(params, decode_chunk=1)
    rids = [eng.submit(p, max_new=MAX_NEW, on_token=tap) for p in MIXED]
    while eng.step():
        pass
    by_rid = {r.rid: r for r in eng.finished}
    for rid, want in zip(rids, reference):
        assert got[rid] == want == by_rid[rid].tokens


def test_t_first_stamped_at_first_emission(params):
    clock = itertools.count(0)
    eng = _paged(params, decode_chunk=1,
                 clock=lambda: float(next(clock)))
    rid = eng.submit(MIXED[0], max_new=MAX_NEW)
    while eng.step():
        pass
    r = {x.rid: x for x in eng.finished}[rid]
    # first token comes out of the prefill harvest; later decode chunks
    # must not move the stamp (the old bug stamped at finish-harvest)
    assert r.t_first is not None and r.t_submit < r.t_first <= r.t_last


def test_stream_generator_matches_generate(params, reference):
    eng = _paged(params)
    assert list(eng.stream(MIXED[1], max_new=MAX_NEW)) == reference[1]


def test_stream_early_close_cancels(params, reference):
    eng = _paged(params, decode_chunk=1)
    seen = []
    for tok in eng.stream(MIXED[0], max_new=MAX_NEW):
        seen.append(tok)
        if len(seen) == 2:
            break                             # client walks away
    assert seen == reference[0][:2]
    assert eng.stats.cancelled == 1
    assert all(s is None for s in eng.slots)
    eng.pager.evict_prefixes()
    assert eng.pager.blocks_in_use == 0


# ---------------------------------------------------------------------------
# Cancellation matrix: queued / mid-prefill / mid-decode / mid-speculation
# ---------------------------------------------------------------------------

def _finish_of(eng, rid):
    return {r.rid: r for r in eng.finished}[rid]


def test_cancel_while_queued(params, reference):
    eng = _paged(params, n_slots=1)
    keep = eng.submit(MIXED[0], max_new=MAX_NEW)
    victim = eng.submit(MIXED[3], max_new=MAX_NEW)
    assert eng.cancel(victim) is True
    while eng.step():
        pass
    assert _finish_of(eng, victim).finish_reason == "cancelled"
    assert _finish_of(eng, victim).tokens == []
    assert _finish_of(eng, keep).tokens == reference[0]
    assert eng.cancel(victim) is False        # already finished
    with pytest.raises(KeyError):
        eng.cancel(10_000)


def test_cancel_mid_prefill_chunk_keeps_published_prefix(params, reference):
    eng = _paged(params, prefill_budget=8, decode_chunk=1)
    victim = eng.submit(MIXED[2], max_new=MAX_NEW)
    eng.step()
    s = next(s for s in eng.slots if s is not None and s.rid == victim)
    assert s.prefilling and 0 < s.prefill_cursor < len(MIXED[2])
    assert eng.cancel(victim) is True
    r = _finish_of(eng, victim)
    assert r.finish_reason == "cancelled" and r.tokens == []
    assert all(s is None for s in eng.slots)
    eng.pager.check_consistency()
    # the consumed chunks were published: resubmitting the same prompt
    # reuses them and still decodes token-identically
    hits_before = eng.stats.prefix_hit_tokens
    retry = eng.submit(MIXED[2], max_new=MAX_NEW)
    while eng.step():
        pass
    assert eng.stats.prefix_hit_tokens > hits_before
    assert _finish_of(eng, retry).tokens == reference[2]
    eng.pager.evict_prefixes()
    assert eng.pager.blocks_in_use == 0


def test_cancel_mid_decode_leaves_prefix_and_survivors_identical(
        params, reference):
    eng = _paged(params, decode_chunk=1)
    victim = eng.submit(MIXED[0], max_new=MAX_NEW)
    keep = eng.submit(MIXED[1], max_new=MAX_NEW)
    while not _seated_tokens(eng, victim):
        eng.step()
    assert eng.cancel(victim) is True
    while eng.step():
        pass
    r = _finish_of(eng, victim)
    assert r.finish_reason == "cancelled"
    assert 0 < len(r.tokens) < len(reference[0])
    assert r.tokens == reference[0][:len(r.tokens)]
    assert _finish_of(eng, keep).tokens == reference[1]
    eng.pager.evict_prefixes()
    assert eng.pager.blocks_in_use == 0


def _seated_tokens(eng, rid):
    for s in eng.slots:
        if s is not None and s.rid == rid and not s.prefilling:
            return list(s.tokens)
    return []


def test_stop_stream_from_callback_cancels(params, reference):
    emitted = []

    def client(req, tok):
        emitted.append(tok)
        if len(emitted) == 3:
            raise StopStream()

    eng = _paged(params)
    rid = eng.submit(MIXED[1], max_new=MAX_NEW, on_token=client)
    while eng.step():
        pass
    r = _finish_of(eng, rid)
    assert r.finish_reason == "cancelled"
    assert r.tokens == emitted == reference[1][:3]
    assert eng.stats.cancelled == 1
    eng.pager.evict_prefixes()
    assert eng.pager.blocks_in_use == 0


def test_cancel_mid_speculative_round(params, reference):
    """Cancelling a speculating slot releases target blocks AND the
    dense draft cache row; the survivor must stay bit-identical to the
    target-only reference."""
    eng = _paged(params, speculate=True, spec_k=4)
    victim = eng.submit(MIXED[0], max_new=MAX_NEW)
    keep = eng.submit(MIXED[1], max_new=MAX_NEW)
    eng.step()                                # prefill + first spec round
    if any(s is not None and s.rid == victim for s in eng.slots):
        assert eng.cancel(victim) is True
        assert _finish_of(eng, victim).finish_reason == "cancelled"
    tokens = _finish_of(eng, victim).tokens
    assert tokens == reference[0][:len(tokens)]
    while eng.step():
        pass
    assert _finish_of(eng, keep).tokens == reference[1]
    assert all(s is None for s in eng.slots)
    eng.pager.evict_prefixes()
    assert eng.pager.blocks_in_use == 0
    eng.pager.check_consistency()


def test_cancel_releases_adapter_pin(params):
    from repro.launch.serve import make_synthetic_adapters
    reg, names = make_synthetic_adapters(CFG, n=1)
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, paged=True,
                      kv_block_size=8, adapters=reg, decode_chunk=1)
    rid = eng.submit(MIXED[0], max_new=MAX_NEW, adapter=names[0])
    eng.step()
    assert any(reg._refs)                     # pinned while in flight
    assert eng.cancel(rid) is True
    assert not any(reg._refs)
    eng.pager.evict_prefixes()
    assert eng.pager.blocks_in_use == 0


# ---------------------------------------------------------------------------
# Execution deadlines
# ---------------------------------------------------------------------------

def test_ttft_deadline_expires_mid_prefill(params):
    clock = itertools.count(0)                # 1 virtual second per read
    eng = _paged(params, prefill_budget=8,
                 clock=lambda: float(next(clock)))
    rid = eng.submit(MIXED[2], max_new=MAX_NEW, ttft_deadline_s=2.0)
    while eng.step():
        pass
    r = _finish_of(eng, rid)
    assert r.finish_reason == "expired" and r.tokens == []
    assert all(s is None for s in eng.slots)
    eng.pager.evict_prefixes()
    assert eng.pager.blocks_in_use == 0


def test_itl_deadline_expires_stalled_stream(params, reference):
    clock = itertools.count(0)
    eng = _paged(params, decode_chunk=1, clock=lambda: float(next(clock)))
    rid = eng.submit(MIXED[0], max_new=MAX_NEW, itl_deadline_s=0.0)
    while eng.step():
        pass
    r = _finish_of(eng, rid)
    # the virtual clock advances every observation, so any gap after the
    # first token blows an inter-token deadline of zero
    assert r.finish_reason == "expired"
    assert 0 < len(r.tokens) < len(reference[0])
    assert r.tokens == reference[0][:len(r.tokens)]
    eng.pager.evict_prefixes()
    assert eng.pager.blocks_in_use == 0


def test_generous_deadlines_do_not_expire(params, reference):
    eng = _paged(params, prefill_budget=16)
    rids = [eng.submit(p, max_new=MAX_NEW, ttft_deadline_s=1e6,
                       itl_deadline_s=1e6) for p in MIXED]
    while eng.step():
        pass
    assert eng.stats.expired == 0
    for rid, want in zip(rids, reference):
        assert _finish_of(eng, rid).tokens == want
