"""End-to-end system behaviour: train -> deploy-quantize -> serve, with the
paper's reuse statistics measured on the REAL trained weights (closing the
loop between the framework and the simulator's Fig. 8 claims)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import reuse as R
from repro.core.axllm_linear import deploy_quantize
from repro.core.quantization import QTensor, QuantConfig, decode_codes
from repro.data.pipeline import make_dataset
from repro.models.model import get_model
from repro.optim import adamw
from repro.serve.engine import ServeEngine
from repro.train.loop import make_train_step

CFG = ModelConfig(name="sys", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=256,
                  head_dim=16, vocab_pad_multiple=64, dtype="float32")


@pytest.fixture(scope="module")
def trained():
    api = get_model(CFG)
    params = api.init(jax.random.PRNGKey(0))
    ocfg = adamw.AdamWConfig(lr=2e-3)
    opt = adamw.init(params, ocfg)
    fn = jax.jit(make_train_step(api, ocfg, total_steps=80, warmup=5))
    ds = make_dataset(CFG, batch=16, seq=32, seed=0)
    losses = []
    for s in range(60):
        b = jax.tree_util.tree_map(jnp.asarray, ds.batch_at(s))
        params, opt, m = fn(params, opt, b, s)
        losses.append(float(m["loss"]))
    return api, params, losses


def test_training_converges(trained):
    _, _, losses = trained
    assert losses[-1] < losses[0] - 1.0


def test_quantized_model_loss_within_band(trained):
    """Paper §V: int8 keeps accuracy within ~1% — here: quantized-model CE
    within a small delta of the fp model on held-out batches."""
    api, params, _ = trained
    qparams = deploy_quantize(params, QuantConfig())
    ds = make_dataset(CFG, batch=16, seq=32, seed=99)
    b = jax.tree_util.tree_map(jnp.asarray, ds.batch_at(0))
    l_fp = float(api.loss(params, b))
    l_q = float(api.loss(qparams, b))
    assert abs(l_q - l_fp) / l_fp < 0.02


def test_reuse_rate_on_trained_weights(trained):
    """Fig. 8 statistics hold on REAL trained weights, not just the
    Gaussian surrogate."""
    api, params, _ = trained
    qparams = deploy_quantize(params, QuantConfig())
    w = qparams["layers"]["ffn"]["up"]
    assert isinstance(w, QTensor)
    codes = np.asarray(decode_codes(w))[0]      # first layer [64, 256]
    rate = R.reuse_rate(codes, 256)
    assert rate > 0.5                            # 256-wide rows, 128 cells
    full = R.reuse_rate(codes, None)
    assert full >= rate


def test_quantized_serving_agrees_after_training(trained):
    api, params, _ = trained
    prompts = [np.arange(8), np.arange(8) + 11]
    fp = ServeEngine(CFG, params, n_slots=2, max_len=64).generate(
        prompts, max_new=8)
    q = ServeEngine(CFG, params, n_slots=2, max_len=64,
                    quantize=True).generate(prompts, max_new=8)
    agree = np.mean([a == b for A, B in zip(fp, q) for a, b in zip(A, B)])
    assert agree >= 0.75  # trained model: int8 rarely flips the argmax


def test_serve_decode_matches_teacher_forcing(trained):
    """Engine decode path == full forward on the generated sequence."""
    api, params, _ = trained
    eng = ServeEngine(CFG, params, n_slots=1, max_len=64)
    prompt = np.arange(8)
    out = eng.generate([prompt], max_new=5)[0]
    seq = jnp.asarray(np.concatenate([prompt, out[:-1]]))[None]
    logits = api.forward(params, {"tokens": seq})
    for i, tok in enumerate(out):
        pos = len(prompt) + i - 1
        pred = int(jnp.argmax(logits[0, pos, : CFG.vocab_size]))
        assert pred == tok
