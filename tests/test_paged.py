"""Block-paged KV cache: paged flash-decode kernel vs oracle, the host
allocator/radix-index manager, and end-to-end engine equivalence — paged
decode must be token-identical to the dense path across every serving
configuration (fp32, int8 weights, int8 KV, pallas_interpret, fused QKV,
multi-LoRA, decode_chunk 1/8), with prefix reuse and eviction on top."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels import ops, ref
from repro.models.model import get_model
from repro.serve.engine import ServeEngine
from repro.serve.paged_cache import PagedKVCache

CFG = ModelConfig(name="s", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, vocab_pad_multiple=64, dtype="float32")

MIXED = [np.arange(8), np.arange(12) + 3, np.arange(31) + 7,
         np.arange(12) + 40, np.arange(8) + 60, np.arange(31) + 90]


@pytest.fixture(scope="module")
def params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Kernel: paged flash-decode vs oracle vs dense
# ---------------------------------------------------------------------------

def _rand(rng, shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _kv_quant(x):
    s = jnp.maximum(jnp.max(jnp.abs(x), -1, keepdims=True), 1e-8) / 127.0
    return (jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8),
            s.astype(jnp.float32))


@pytest.mark.parametrize("case", [(3, 16, 8, 8, 2, 64), (2, 32, 4, 4, 4, 32)])
def test_paged_decode_kernel_vs_oracle(case):
    b, bs, mb, h, hk, d = case
    nb = b * mb + 4
    rng = np.random.default_rng(11)
    q = _rand(rng, (b, h, d))
    pk = _rand(rng, (nb, bs, hk, d))
    pv = _rand(rng, (nb, bs, hk, d))
    # non-trivial tables: a permutation of the pool, trash beyond length
    bt = jnp.asarray(1 + rng.permutation(nb - 1)[: b * mb].reshape(b, mb),
                     jnp.int32)
    length = jnp.asarray([0, bs + 3, mb * bs][:b], jnp.int32)
    o_ref = ref.paged_decode_attention_ref(q, pk, pv, bt, length)
    o_pal = ops.decode_attention(q, pk, pv, length, block_tables=bt,
                                 impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
    # length == 0 rows are exact zeros on both paths
    assert np.allclose(np.asarray(o_ref[0]), 0.0)
    assert np.allclose(np.asarray(o_pal[0]), 0.0)
    # gathering the table into a dense cache reproduces the dense oracle
    kd = pk[bt].reshape(b, mb * bs, hk, d)
    vd = pv[bt].reshape(b, mb * bs, hk, d)
    np.testing.assert_allclose(
        np.asarray(ref.decode_attention_ref(q, kd, vd, length)),
        np.asarray(o_ref), rtol=1e-6, atol=1e-6)


def test_paged_decode_kernel_int8_kv():
    b, bs, mb, h, hk, d = 2, 16, 4, 8, 2, 64
    nb = b * mb + 2
    rng = np.random.default_rng(12)
    q = _rand(rng, (b, h, d))
    kq, ks = _kv_quant(_rand(rng, (nb, bs, hk, d)))
    vq, vs = _kv_quant(_rand(rng, (nb, bs, hk, d)))
    bt = jnp.asarray(1 + rng.permutation(nb - 1)[: b * mb].reshape(b, mb),
                     jnp.int32)
    length = jnp.asarray([0, 3 * bs + 5], jnp.int32)
    o_ref = ref.paged_decode_attention_ref(q, kq, vq, bt, length,
                                           k_scale=ks, v_scale=vs)
    o_pal = ops.decode_attention(q, kq, vq, length, block_tables=bt,
                                 k_scale=ks, v_scale=vs,
                                 impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_prefix_attention_matches_causal_oracle():
    """Suffix-only prefill attention == full causal attention restricted to
    the suffix rows, per row of a ragged (padded) prefix."""
    rng = np.random.default_rng(13)
    b, s, h, hk, d, pad = 2, 6, 4, 2, 16, 8
    plen = np.array([5, 8], np.int32)
    kp, vp = _rand(rng, (b, pad, hk, d)), _rand(rng, (b, pad, hk, d))
    q = _rand(rng, (b, s, h, d))
    ks, vs = _rand(rng, (b, s, hk, d)), _rand(rng, (b, s, hk, d))
    out = ops.prefix_attention(q, kp, vp, jnp.asarray(plen), ks, vs)
    for i in range(b):
        n = int(plen[i])
        kf = jnp.concatenate([kp[i:i + 1, :n], ks[i:i + 1]], axis=1)
        vf = jnp.concatenate([vp[i:i + 1, :n], vs[i:i + 1]], axis=1)
        want = ref.attention_ref(q[i:i + 1], kf, vf, causal=True)
        np.testing.assert_allclose(np.asarray(out[i:i + 1]),
                                   np.asarray(want), rtol=2e-5, atol=2e-5)


def test_prefix_attention_dispatch_regression():
    """impl='pallas' used to silently run the jnp oracle — the dispatch
    must now refuse loudly until a compiled kernel exists, while
    'pallas_interpret' (oracle semantics) and the reuse impl aliases
    (normalized to their base dispatch) keep working."""
    rng = np.random.default_rng(14)
    b, s, h, hk, d, pad = 1, 3, 2, 1, 8, 4
    plen = jnp.asarray([2], jnp.int32)
    kp, vp = _rand(rng, (b, pad, hk, d)), _rand(rng, (b, pad, hk, d))
    q = _rand(rng, (b, s, h, d))
    ks, vs = _rand(rng, (b, s, hk, d)), _rand(rng, (b, s, hk, d))
    base = ops.prefix_attention(q, kp, vp, plen, ks, vs, impl="auto")
    with pytest.raises(NotImplementedError, match="prefix_attention"):
        ops.prefix_attention(q, kp, vp, plen, ks, vs, impl="pallas")
    for impl in ("pallas_interpret", "ref", "reuse", "reuse_ref"):
        out = ops.prefix_attention(q, kp, vp, plen, ks, vs, impl=impl)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(base))


# ---------------------------------------------------------------------------
# Host manager: allocator, radix index, CoW, eviction
# ---------------------------------------------------------------------------

def _pager(**kw):
    args = dict(n_slots=2, n_blocks=12, block_size=4, max_blocks_per_slot=4)
    args.update(kw)
    return PagedKVCache(**args)


def test_pager_alloc_free_and_trash_reserved():
    p = _pager()
    assert p.blocks_in_use == 0
    bids = [p.alloc() for _ in range(11)]       # 12 blocks minus trash
    assert 0 not in bids and len(set(bids)) == 11
    with pytest.raises(RuntimeError, match="exhausted"):
        p.alloc()                               # nothing evictable
    p._release_block(bids[0])
    assert p.alloc() == bids[0]


def test_pager_undersized_pool_rejected():
    with pytest.raises(ValueError, match="cannot back"):
        _pager(n_blocks=9)                      # needs 2*4 + 2
    with pytest.raises(ValueError, match="power of two"):
        _pager(block_size=6)


def test_pager_match_insert_roundtrip():
    p = _pager()
    toks = list(range(11))                      # 2 full blocks + 3 tail
    b0, b1 = p.alloc(), p.alloc()
    assert p.insert(toks, [b0, b1]) == 2
    hit, n = p.match(toks)
    assert hit == [b0, b1] and n == 8
    # divergent second chunk stops the walk after one block
    hit, n = p.match(list(range(4)) + [99] * 7)
    assert hit == [b0] and n == 4
    # a prompt that is exactly the cached blocks keeps one token for
    # prefill: the hit is capped at len-1 and floored to full blocks
    hit, n = p.match(list(range(8)))
    assert n == 4 and hit == [b0]
    # duplicate insert publishes nothing new
    assert p.insert(toks, [p.alloc(), p.alloc()]) == 0


def test_pager_cow_on_shared_block():
    p = _pager()
    toks = list(range(8))
    b0, b1 = p.alloc(), p.alloc()
    p.insert(toks, [b0, b1])
    # two slots take the same cached blocks, then each makes its window
    # writable: the shared block must be copy-on-written, once per slot
    p.acquire_blocks(0, [b0, b1])
    p.acquire_blocks(1, [b0, b1])
    cow0 = p.prepare_decode(0, 6, 2)            # writes inside block 1
    assert len(cow0) == 1 and cow0[0][0] == b1
    assert p.tables[0, 1] == cow0[0][1] != b1
    cow1 = p.prepare_decode(1, 6, 2)
    assert len(cow1) == 1 and cow1[0][0] == b1
    # fresh appends past the table end need no copy
    assert p.prepare_decode(0, 8, 4) == []
    assert p.slot_blocks(0)[2] != 0


def test_pager_release_keeps_indexed_blocks():
    p = _pager()
    toks = list(range(8))
    b0, b1 = p.alloc(), p.alloc()
    p.insert(toks, [b0, b1])
    p.acquire_blocks(0, [b0, b1])
    p.release_slot(0)
    assert p.blocks_in_use == 2                 # index still holds them
    assert p.match(toks)[0] == [b0]             # capped at len-1


def test_pager_lru_eviction():
    p = _pager(n_blocks=13)                     # 12 usable
    old = [p.alloc(), p.alloc()]
    new = [p.alloc(), p.alloc()]
    p.insert(list(range(8)), old)
    p.insert(list(range(100, 108)), new)
    for b in old + new:                         # slots finished: index-only
        p._release_block(b)
    p.match(list(range(9)))                     # touch `old`: now MRU
    taken = [p.alloc() for _ in range(8)]       # pool is now dry
    got = p.alloc()                             # must evict an LRU leaf
    assert p.evictions == 1 and got == new[1]   # deepest LRU leaf first
    hit, n = p.match(list(range(100, 109)))
    assert n == 4                               # new[1] gone, new[0] stays
    assert p.match(list(range(9)))[1] == 8      # MRU chain untouched
    del taken, got


# ---------------------------------------------------------------------------
# Engine: paged == dense, token for token
# ---------------------------------------------------------------------------

def _tokens(cfg, params, prompts, max_new=6, **kw):
    eng = ServeEngine(cfg, params, n_slots=2, max_len=64, **kw)
    return eng.generate(prompts, max_new=max_new), eng


@pytest.mark.parametrize("mode", ["fp32", "int8", "int8kv", "fused",
                                  "chunk1"])
def test_paged_engine_matches_dense(params, mode):
    cfg = CFG
    kw = {}
    if mode == "int8":
        kw["quantize"] = True
    elif mode == "int8kv":
        cfg = dataclasses.replace(CFG, quant_kv=True)
        kw["quantize"] = True
    elif mode == "fused":
        kw.update(quantize=True, fuse_qkv=True)
    elif mode == "chunk1":
        kw["decode_chunk"] = 1
    dense, _ = _tokens(cfg, params, MIXED, **kw)
    paged, eng = _tokens(cfg, params, MIXED, paged=True, kv_block_size=8,
                         **kw)
    assert dense == paged
    assert eng.stats.finished == len(MIXED)


def test_paged_engine_interpret_mode(params):
    """The real Pallas kernel bodies (paged decode included) under
    interpret mode produce the same tokens as the oracle path."""
    prompts = MIXED[:2]
    dense, _ = _tokens(CFG, params, prompts, max_new=4, impl="ref")
    paged, _ = _tokens(CFG, params, prompts, max_new=4, paged=True,
                       kv_block_size=8, impl="pallas_interpret")
    assert dense == paged


def test_paged_engine_multi_lora(params):
    from repro.launch.serve import make_synthetic_adapters
    reg, names = make_synthetic_adapters(CFG, n=2)
    adapters = [None, names[0], names[1], names[0]]
    prompts = [np.arange(8), np.arange(8), np.arange(8) + 40,
               np.arange(12) + 3]
    dense = ServeEngine(CFG, params, n_slots=2, max_len=64, quantize=True,
                        adapters=reg).generate(prompts, max_new=5,
                                               adapters=adapters)
    reg2, _ = make_synthetic_adapters(CFG, n=2)
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, quantize=True,
                      adapters=reg2, paged=True, kv_block_size=8)
    paged = eng.generate(prompts, max_new=5, adapters=adapters)
    assert dense == paged


def test_paged_lora_never_reuses_base_prefix(params):
    """Adapters targeting wv make the KV adapter-specific: a LoRA request
    whose prompt is already indexed from a base-model run must NOT take
    the cached base KV (it recomputes its own, and publishes nothing)."""
    from repro.launch.serve import make_synthetic_adapters
    prompt = np.arange(20)
    reg, names = make_synthetic_adapters(CFG, n=1)     # targets wq, wv
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, quantize=True,
                      adapters=reg, paged=True, kv_block_size=8)
    base = eng.generate([prompt], max_new=5)           # indexes the prompt
    assert eng.stats.prefix_hit_tokens == 0
    lora = eng.generate([prompt], max_new=5, adapters=[names[0]])
    assert eng.stats.prefix_hit_tokens == 0            # no cross-hit
    # the reference: a dense engine decoding the same adapter solo
    reg2, n2 = make_synthetic_adapters(CFG, n=1)
    want = ServeEngine(CFG, params, n_slots=1, max_len=64, quantize=True,
                       adapters=reg2).generate([prompt], max_new=5,
                                               adapters=[n2[0]])
    assert lora == want and lora != base
    # base requests still hit the index afterwards
    assert eng.generate([prompt], max_new=5) == base
    assert eng.stats.prefix_hit_tokens > 0


def test_paged_prefix_reuse_and_stats(params):
    prefix = np.arange(16) + 5
    prompts = [np.concatenate([prefix, np.arange(4) + 100 + 7 * i])
               for i in range(4)]
    dense, _ = _tokens(CFG, params, prompts)
    paged, eng = _tokens(CFG, params, prompts, paged=True, kv_block_size=8)
    assert dense == paged
    assert eng.stats.prefix_hit_tokens > 0
    assert eng.stats.blocks_in_use > 0
    # a second identical batch on the same engine reuses even more (the
    # full prompts are indexed now) and still matches
    hits0 = eng.stats.prefix_hit_tokens
    assert eng.generate(prompts, max_new=6) == dense
    assert eng.stats.prefix_hit_tokens > hits0


def test_paged_prefix_cache_off(params):
    dense, _ = _tokens(CFG, params, MIXED)
    paged, eng = _tokens(CFG, params, MIXED, paged=True, kv_block_size=8,
                         prefix_cache=False)
    assert dense == paged
    assert eng.stats.prefix_hit_tokens == 0
    # without the index, drained slots return every block to the free list
    assert eng.stats.blocks_in_use == 0


def test_paged_eviction_under_pressure(params):
    """A pool sized at the bare minimum forces index eviction between
    generations; tokens stay identical to dense."""
    mb = 64 // 8
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, paged=True,
                      kv_block_size=8, num_blocks=2 * mb + 2)
    dense, _ = _tokens(CFG, params, MIXED)
    for _ in range(2):
        assert eng.generate(MIXED, max_new=6) == dense
    assert eng.pager.evictions > 0


def test_paged_rejects_recurrent_family():
    cfg = ModelConfig(name="sx", family="ssm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=256,
                      vocab_pad_multiple=64, xlstm_slstm_every=2,
                      dtype="float32", remat=False)
    p = get_model(cfg).init(jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="no paged KV cache path"):
        ServeEngine(cfg, p, n_slots=2, max_len=64, paged=True)


def test_paged_long_prompt_and_cache_full(params):
    """Truncation + cache-full stop conditions behave exactly as dense."""
    dense = ServeEngine(CFG, params, n_slots=1, max_len=16).generate(
        [np.arange(40)], max_new=8, return_requests=True)
    eng = ServeEngine(CFG, params, n_slots=1, max_len=16, paged=True,
                      kv_block_size=8)
    paged = eng.generate([np.arange(40)], max_new=8, return_requests=True)
    assert dense[0].tokens == paged[0].tokens
    assert paged[0].prompt_truncated and paged[0].truncated


def test_paged_moe_family():
    cfg = ModelConfig(name="sm", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=256,
                      head_dim=16, vocab_pad_multiple=64, n_experts=4,
                      top_k=2, expert_pad_to=4, capacity_factor=8.0,
                      dtype="float32", remat=False)
    p = get_model(cfg).init(jax.random.PRNGKey(3))
    dense, _ = _tokens(cfg, p, MIXED[:3], max_new=4)
    paged, _ = _tokens(cfg, p, MIXED[:3], max_new=4, paged=True,
                       kv_block_size=8)
    assert dense == paged


def test_paged_cache_spec_validation(params):
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, paged=True,
                      kv_block_size=8)
    api = eng.api
    spec = api.paged_cache_spec
    cache = jax.eval_shape(lambda: api.init_paged_cache(3, 20, 8, 4))
    assert set(spec) == set(cache)
    for name, ax in spec.items():
        want = 20 if ax == 1 else 3
        assert cache[name].shape[ax] == want, (name, cache[name].shape)
