import os

# smoke tests / benches must see ONE device — the 512-device override is
# exclusively the dry-run's (set inside repro.launch.dryrun, never globally)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
