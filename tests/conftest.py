import os
import sys

# Deterministic CPU backend for the whole suite.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# 8 host CPU devices, set BEFORE the first jax import (jax locks the device
# count on init): test_sharding / test_distributed exercise real meshes on
# CPU-only CI. Single-device tests are unaffected (unsharded arrays commit
# to device 0). The dry-run's 512-device override stays private to its own
# process (launch/dryrun.py), and test_distributed's subprocesses set their
# own flag. APPEND to any pre-existing XLA_FLAGS rather than losing the
# forced count to unrelated tuning flags; an explicit device-count flag in
# the environment wins.
_DEV_FLAG = "--xla_force_host_platform_device_count"
if _DEV_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" {_DEV_FLAG}=8").strip()

# The container image ships without hypothesis; fall back to the vendored
# API-compatible shim so the property tests still collect and run. CI
# installs the real pin and never loads the shim.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_vendor"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)

# Test modules whose cases need more than one device (marker applied below
# so CI lanes can split: -m multi_device / -m "not multi_device").
_MULTI_DEVICE_MODULES = {"test_distributed", "test_sharding",
                         "test_sharded_serve"}


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multi_device: exercises >1 jax device (8 forced host CPU devices)")


def pytest_collection_modifyitems(config, items):
    for item in items:
        mod = item.module.__name__.rsplit(".", 1)[-1]
        if mod in _MULTI_DEVICE_MODULES:
            item.add_marker(pytest.mark.multi_device)
        if mod == "test_distributed" and \
                "eight_cpu_devices" not in item.fixturenames:
            # guard: skip (with the flag spelled out) instead of failing
            # obscurely when the device forcing was overridden
            item.fixturenames.append("eight_cpu_devices")


@pytest.fixture(scope="session")
def eight_cpu_devices():
    """The 8 forced host CPU devices (skips if the flag was overridden)."""
    devices = jax.devices()
    if len(devices) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8"
                    f" (got {len(devices)} devices)")
    return devices
