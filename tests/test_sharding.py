"""Sharding rule translation: divisibility fallback, duplicate-axis
avoidance, param/cache spec inference (single-device: structural checks)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist import sharding as shd


@pytest.fixture(scope="module")
def mesh11():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


RULES = dict(shd.DEFAULT_RULES)


def _spec(shape, names, mesh_shape=(16, 16), axes=("data", "model")):
    """Resolve against a fake mesh via a stub object with .shape mapping."""
    class FakeMesh:
        shape = dict(zip(axes, mesh_shape))
    return shd.resolve_spec(shape, names, FakeMesh, RULES)


def test_divisible_dims_shard():
    assert _spec((256, 4096), ("batch", "mlp")) == P("data", "model")


def test_indivisible_dim_replicates():
    # kv_heads = 2 on a 16-way model axis -> replicate (glm4-9b case)
    assert _spec((64, 2), ("embed", "kv_heads")) == P("data", None)


def test_batch_one_replicates():
    assert _spec((1, 1024), ("batch", "seq")) == P(None, None)


def test_duplicate_axis_not_reused():
    # both dims want "model": only the first gets it
    spec = _spec((64, 64), ("heads", "vocab"), mesh_shape=(4, 16))
    assert spec == P("model", None)


def test_multi_axis_batch():
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    spec = shd.resolve_spec((256, 128), ("batch", None), FakeMesh, RULES)
    assert spec == P(("pod", "data"), None)


def test_partial_multi_axis_when_indivisible():
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    # 16 divides by pod(2) then data would need 32 -> only pod used
    spec = shd.resolve_spec((16,), ("batch",), FakeMesh, RULES)
    assert spec == P(("pod", "data")) or spec == P("pod")


def test_param_specs_structure_matches(mesh11):
    from repro.configs.base import ModelConfig
    from repro.models.model import get_model
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      head_dim=16, vocab_pad_multiple=64, dtype="float32")
    api = get_model(cfg)
    abs_params = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    specs = shd.param_specs(abs_params, mesh11)
    # structure must match exactly (usable as jit in_shardings)
    jax.tree_util.tree_map(lambda a, s: None, abs_params, specs)


def test_cache_specs_structure_matches(mesh11):
    from repro.configs.base import ModelConfig
    from repro.models.model import get_model
    for fam_kwargs in (
            dict(family="dense"),
            dict(family="ssm", d_ff=0, xlstm_slstm_every=2, head_dim=None),
            dict(family="hybrid", ssm_state=16, ssm_head_dim=16,
                 hybrid_attn_every=2, n_layers=5)):
        from repro.configs.base import ModelConfig
        base = dict(name="t", family="dense", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
                    vocab_pad_multiple=64, dtype="float32")
        base.update(fam_kwargs)
        cfg = ModelConfig(**base)
        api = get_model(cfg)
        cache = jax.eval_shape(lambda: api.init_cache(4, 32))
        specs = shd.cache_specs(cache, mesh11, 4, 32)
        jax.tree_util.tree_map(lambda a, s: None, cache, specs)


def test_shard_is_identity_outside_mesh():
    x = jnp.ones((4, 4))
    y = shd.shard(x, "batch", "mlp")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# PR 7 serving-mesh helpers
# ---------------------------------------------------------------------------

def test_size1_mesh_axes_skipped():
    # a (1, 1) mesh must resolve everything to replication: the engine's
    # mesh=1 path has to compile the exact single-device program
    class FakeMesh:
        shape = {"data": 1, "model": 1}
    spec = shd.resolve_spec((256, 4096), ("batch", "mlp"), FakeMesh, RULES)
    assert spec == P(None, None)


def test_size1_axis_skipped_within_multi_axis_mesh():
    class FakeMesh:
        shape = {"data": 1, "model": 8}
    spec = shd.resolve_spec((256, 4096), ("batch", "mlp"), FakeMesh, RULES)
    assert spec == P(None, "model")


def test_row_parallel_wo_down_names():
    # Megatron split: wo/down shard the CONTRACTION dim ("mlp") so each
    # block needs exactly one all-reduce, on the block output
    assert shd._param_names("wo", 3) == (None, "mlp", "embed")
    assert shd._param_names("down", 3) == (None, "mlp", "embed")
    # column-parallel partners keep the output dim sharded
    assert shd._param_names("wq", 3)[-1] == "mlp"
    assert shd._param_names("gate", 3)[-1] == "mlp"


def test_serve_rules_for_picks_head_vs_seq():
    class Mesh2:
        shape = {"data": 1, "model": 2}

    class Mesh8:
        shape = {"data": 1, "model": 8}
    # n_kv_heads=2: divides model=2 -> head-sharded (cache_seq None)
    assert shd.serve_rules_for(Mesh2, 2)["cache_seq"] is None
    assert shd.serve_rules_for(Mesh2, 2)["kv_heads"] == "model"
    # 2 % 8 != 0 -> fall back to sequence sharding
    assert shd.serve_rules_for(Mesh8, 2)["cache_seq"] == "model"


def test_adapter_specs_structure(mesh11):
    from repro.core.axllm_linear import LoRAConfig
    from repro.serve.adapters import AdapterRegistry
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      head_dim=16, vocab_pad_multiple=64, dtype="float32")
    reg = AdapterRegistry(cfg, LoRAConfig(rank=4, targets=("wq", "wo")))
    specs = shd.adapter_specs(reg.stacked, mesh11)
    jax.tree_util.tree_map(lambda a, s: None, reg.stacked, specs)
    # A replicated, B sharded on its last (output) dim name-wise
    for t in ("wq", "wo"):
        assert specs[t]["lora_a"].spec == P()


def test_paged_cache_specs_structure(mesh11):
    from repro.configs.base import ModelConfig
    from repro.models.model import get_model
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      head_dim=16, vocab_pad_multiple=64, dtype="float32")
    api = get_model(cfg)
    cache = jax.eval_shape(lambda: api.init_paged_cache(4, 8, 8, 4))
    specs = shd.paged_cache_specs(cache, mesh11)
    jax.tree_util.tree_map(lambda a, s: None, cache, specs)
