"""Serving engine: batched continuous decoding, AxLLM-quantized parity,
int8 KV cache, slot reuse."""

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.model import get_model
from repro.serve.engine import ServeEngine

CFG = ModelConfig(name="s", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, vocab_pad_multiple=64, dtype="float32")


@pytest.fixture(scope="module")
def params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


def test_batched_equals_single_request(params):
    """Greedy decode of a request must not depend on its batch-mates."""
    p1 = np.arange(8)
    p2 = np.arange(8) + 100
    eng_b = ServeEngine(CFG, params, n_slots=2, max_len=64)
    outs = eng_b.generate([p1, p2], max_new=8)
    eng_s = ServeEngine(CFG, params, n_slots=1, max_len=64)
    solo = eng_s.generate([p1], max_new=8)
    assert outs[0] == solo[0]


def test_slot_reuse_more_requests_than_slots(params):
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64)
    prompts = [np.arange(6) + i for i in range(5)]
    outs = eng.generate(prompts, max_new=5)
    assert len(outs) == 5
    assert all(len(o) == 5 for o in outs)


def test_quantized_engine_mostly_agrees(params):
    prompts = [np.arange(8), np.arange(8) + 50]
    fp = ServeEngine(CFG, params, n_slots=2, max_len=64).generate(
        prompts, max_new=8)
    q = ServeEngine(CFG, params, n_slots=2, max_len=64,
                    quantize=True).generate(prompts, max_new=8)
    agree = np.mean([a == b for A, B in zip(fp, q) for a, b in zip(A, B)])
    assert agree >= 0.5  # random-init model; trained models agree ~fully


def test_int8_kv_cache_engine(params):
    import dataclasses
    cfg = dataclasses.replace(CFG, quant_kv=True)
    eng = ServeEngine(cfg, params, n_slots=2, max_len=64, quantize=True)
    outs = eng.generate([np.arange(8)], max_new=6)
    assert len(outs[0]) == 6


def test_mixed_length_prompts_wave_grouping(params):
    eng = ServeEngine(CFG, params, n_slots=4, max_len=64)
    prompts = [np.arange(4), np.arange(8), np.arange(4) + 9,
               np.arange(8) + 3]
    outs = eng.generate(prompts, max_new=4)
    assert len(outs) == 4 and all(len(o) == 4 for o in outs)


def test_engine_on_recurrent_family():
    cfg = ModelConfig(name="sx", family="ssm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=256,
                      vocab_pad_multiple=64, xlstm_slstm_every=2,
                      dtype="float32", remat=False)
    p = get_model(cfg).init(jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, p, n_slots=2, max_len=64, quantize=True)
    outs = eng.generate([np.arange(6), np.arange(6) + 2], max_new=5)
    assert all(len(o) == 5 for o in outs)
