"""Serving engine: continuous-batching scheduler — ragged prefill waves,
cache_spec slot insertion, EOS/stop conditions, long-prompt policy, partial
results, AxLLM-quantized parity, int8 KV cache, slot reuse."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.model import get_model
from repro.serve.engine import ServeEngine

CFG = ModelConfig(name="s", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, vocab_pad_multiple=64, dtype="float32")


@pytest.fixture(scope="module")
def params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


def test_batched_equals_single_request(params):
    """Greedy decode of a request must not depend on its batch-mates."""
    p1 = np.arange(8)
    p2 = np.arange(8) + 100
    eng_b = ServeEngine(CFG, params, n_slots=2, max_len=64)
    outs = eng_b.generate([p1, p2], max_new=8)
    eng_s = ServeEngine(CFG, params, n_slots=1, max_len=64)
    solo = eng_s.generate([p1], max_new=8)
    assert outs[0] == solo[0]


def test_slot_reuse_more_requests_than_slots(params):
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64)
    prompts = [np.arange(6) + i for i in range(5)]
    outs = eng.generate(prompts, max_new=5)
    assert len(outs) == 5
    assert all(len(o) == 5 for o in outs)


def test_quantized_engine_mostly_agrees(params):
    prompts = [np.arange(8), np.arange(8) + 50]
    fp = ServeEngine(CFG, params, n_slots=2, max_len=64).generate(
        prompts, max_new=8)
    q = ServeEngine(CFG, params, n_slots=2, max_len=64,
                    quantize=True).generate(prompts, max_new=8)
    agree = np.mean([a == b for A, B in zip(fp, q) for a, b in zip(A, B)])
    assert agree >= 0.5  # random-init model; trained models agree ~fully


def test_int8_kv_cache_engine(params):
    import dataclasses
    cfg = dataclasses.replace(CFG, quant_kv=True)
    eng = ServeEngine(cfg, params, n_slots=2, max_len=64, quantize=True)
    outs = eng.generate([np.arange(8)], max_new=6)
    assert len(outs[0]) == 6


def test_mixed_length_prompts_wave_grouping(params):
    eng = ServeEngine(CFG, params, n_slots=4, max_len=64)
    prompts = [np.arange(4), np.arange(8), np.arange(4) + 9,
               np.arange(8) + 3]
    outs = eng.generate(prompts, max_new=4)
    assert len(outs) == 4 and all(len(o) == 4 for o in outs)


def test_engine_on_recurrent_family():
    cfg = ModelConfig(name="sx", family="ssm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=256,
                      vocab_pad_multiple=64, xlstm_slstm_every=2,
                      dtype="float32", remat=False)
    p = get_model(cfg).init(jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, p, n_slots=2, max_len=64, quantize=True)
    outs = eng.generate([np.arange(6), np.arange(6) + 2], max_new=5)
    assert all(len(o) == 5 for o in outs)


# ---------------------------------------------------------------------------
# Scheduler: ragged waves, occupancy, equivalence
# ---------------------------------------------------------------------------

def _direct_greedy(cfg, params, prompt, max_new, max_len=64):
    """Reference decode: exact-length solo prefill + api.decode loop."""
    api = get_model(cfg)
    cache = api.init_cache(1, max_len)
    prompt = jnp.asarray(np.asarray(prompt, np.int32)[None])
    logits, cache = api.prefill(params, {"tokens": prompt}, cache)
    toks = [int(jnp.argmax(logits[0, : cfg.vocab_size]))]
    while len(toks) < max_new:
        logits, cache = api.decode(
            params, jnp.asarray([toks[-1]], jnp.int32), cache)
        toks.append(int(jnp.argmax(logits[0, : cfg.vocab_size])))
    return toks


MIXED = [np.arange(8), np.arange(12) + 3, np.arange(31) + 7,
         np.arange(12) + 40, np.arange(8) + 60, np.arange(31) + 90]


def test_mixed_length_stream_full_occupancy(params):
    """Lengths 8/12/31, more requests than slots: one padded wave per
    admission, slots never idle between waves."""
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64)
    outs = eng.generate(MIXED, max_new=4)
    assert len(outs) == 6 and all(len(o) == 4 for o in outs)
    st = eng.stats
    assert st.admitted == 6 and st.finished == 6 and st.truncated == 0
    assert st.mean_occupancy == 1.0           # 6 requests drain 2 slots evenly
    assert st.tokens_per_step == 2.0
    assert st.prefill_waves >= 3
    # ragged: one wave admits mixed lengths together, so far fewer waves
    # than distinct (wave, length) pairs
    assert st.prefill_compiles <= len(eng._prefill_cache) + 1


@pytest.mark.slow
def test_ragged_prefill_matches_direct_decode(params):
    """Padded mixed-length batched prefill must equal exact-length solo
    prefill + decode (the masking/cursor contract)."""
    eng = ServeEngine(CFG, params, n_slots=3, max_len=64)
    outs = eng.generate(MIXED[:3], max_new=6)
    for p, o in zip(MIXED[:3], outs):
        assert o == _direct_greedy(CFG, params, p, 6)


def test_quantized_engine_matches_direct_quantized_decode(params):
    """End-to-end: engine(quantize=True) == api.decode greedy on the same
    deploy-quantized params."""
    from repro.core.axllm_linear import deploy_quantize
    from repro.core.quantization import QuantConfig
    qp = deploy_quantize(params, QuantConfig(bits=8, mode="affine",
                                             granularity="per_channel"))
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, quantize=True)
    outs = eng.generate(MIXED[:2], max_new=6)
    for p, o in zip(MIXED[:2], outs):
        assert o == _direct_greedy(CFG, qp, p, 6)


@pytest.mark.slow
def test_nslots_collides_with_stacked_dim():
    """Regression: n_slots == n_super on xLSTM. Shape-guessing slot writes
    picked the superblock axis and corrupted the cache; cache_spec pins the
    batch axis."""
    cfg = ModelConfig(name="sx4", family="ssm", n_layers=4, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=256,
                      vocab_pad_multiple=64, xlstm_slstm_every=2,
                      dtype="float32", remat=False)
    p = get_model(cfg).init(jax.random.PRNGKey(1))
    prompts = [np.arange(6), np.arange(6) + 50, np.arange(6) + 100]
    eng = ServeEngine(cfg, p, n_slots=2, max_len=64)   # n_super == n_slots
    outs = eng.generate(prompts, max_new=5)
    for pr, o in zip(prompts, outs):
        solo = ServeEngine(cfg, p, n_slots=1, max_len=64)
        assert o == solo.generate([pr], max_new=5)[0]


def test_cache_spec_matches_shape_inference():
    """Every family's cache_spec names exactly the axis that changes with
    batch size (checked abstractly, no allocation)."""
    cfgs = [
        CFG,
        dataclasses.replace(CFG, quant_kv=True),
        ModelConfig(name="i-ssm", family="ssm", n_layers=4, d_model=64,
                    n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=256,
                    vocab_pad_multiple=64, xlstm_slstm_every=2,
                    dtype="float32"),
        ModelConfig(name="i-hyb", family="hybrid", n_layers=5, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                    head_dim=16, vocab_pad_multiple=64, ssm_state=16,
                    ssm_head_dim=16, hybrid_attn_every=2, dtype="float32"),
        ModelConfig(name="i-aud", family="audio", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
                    head_dim=16, vocab_pad_multiple=64,
                    is_encoder_decoder=True, n_enc_layers=1, enc_seq=9,
                    d_feat=4, dtype="float32"),
    ]
    for cfg in cfgs:
        api = get_model(cfg)
        c3 = jax.eval_shape(lambda a=api: a.init_cache(3, 16))
        c5 = jax.eval_shape(lambda a=api: a.init_cache(5, 16))

        def check(a, b, ax):
            diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                    if x != y]
            assert diff == [ax], (cfg.name, a.shape, b.shape, ax)

        jax.tree_util.tree_map(check, c3, c5, api.cache_spec)


@pytest.mark.slow
def test_engine_on_hybrid_family_mixed_lengths():
    """Hybrid (Mamba + shared-attn sites, remainder layers): equal-length
    sub-waves + cache_spec writes across attn/conv/ssm/*_rem leaves."""
    cfg = ModelConfig(name="shy", family="hybrid", n_layers=5, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      head_dim=16, vocab_pad_multiple=64, ssm_state=16,
                      ssm_head_dim=16, hybrid_attn_every=2,
                      dtype="float32", remat=False)
    p = get_model(cfg).init(jax.random.PRNGKey(2))
    prompts = [np.arange(6), np.arange(9) + 20, np.arange(6) + 40]
    eng = ServeEngine(cfg, p, n_slots=2, max_len=64)
    outs = eng.generate(prompts, max_new=4)
    assert all(len(o) == 4 for o in outs)
    for pr, o in zip(prompts, outs):
        solo = ServeEngine(cfg, p, n_slots=1, max_len=64)
        assert o == solo.generate([pr], max_new=4)[0]


# ---------------------------------------------------------------------------
# Stop conditions
# ---------------------------------------------------------------------------

def test_eos_early_exit_frees_slot(params):
    # decode_chunk=1: admission happens every device step, so the EOS-freed
    # slot demonstrably shortens the stream (chunked engines only admit at
    # chunk boundaries — that latency/throughput trade is covered below)
    base_eng = ServeEngine(CFG, params, n_slots=2, max_len=64,
                           decode_chunk=1)
    prompts = [np.arange(8), np.arange(8) + 30, np.arange(8) + 77]
    base = base_eng.generate(prompts, max_new=8)
    eos = base[0][2]
    idx = base[0].index(eos)
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, eos_id=eos,
                      decode_chunk=1)
    outs = eng.generate(prompts, max_new=8)
    assert outs[0] == base[0][: idx + 1]       # stops right after EOS
    assert len(outs) == 3 and eng.stats.finished == 3
    # the freed slot admits request 3 earlier, so the stream drains in
    # fewer decode steps than the no-EOS run
    assert eng.stats.steps < base_eng.stats.steps


@pytest.mark.slow
def test_eos_mid_chunk_freezes_slot(params):
    """Chunked decode: EOS inside a chunk must freeze the slot's tokens on
    device (validity mask) and produce the same result as per-token."""
    prompts = [np.arange(8), np.arange(8) + 30, np.arange(8) + 77]
    base = ServeEngine(CFG, params, n_slots=2, max_len=64,
                       decode_chunk=1).generate(prompts, max_new=8)
    eos = base[0][2]
    for chunk in (4, 8):
        eng = ServeEngine(CFG, params, n_slots=2, max_len=64, eos_id=eos,
                          decode_chunk=chunk)
        outs = eng.generate(prompts, max_new=8)
        ref = ServeEngine(CFG, params, n_slots=2, max_len=64, eos_id=eos,
                          decode_chunk=1).generate(prompts, max_new=8)
        assert outs == ref


def test_eos_on_first_prefill_token(params):
    eng0 = ServeEngine(CFG, params, n_slots=1, max_len=64)
    first = eng0.generate([np.arange(8)], max_new=4)[0][0]
    eng = ServeEngine(CFG, params, n_slots=1, max_len=64, eos_id=first)
    reqs = eng.generate([np.arange(8)], max_new=4, return_requests=True)
    assert reqs[0].tokens == [first] and reqs[0].done
    assert eng.stats.steps == 0                # never occupied a decode slot


# ---------------------------------------------------------------------------
# Long prompts + partial results
# ---------------------------------------------------------------------------

def test_long_prompt_reject(params):
    eng = ServeEngine(CFG, params, n_slots=1, max_len=16,
                      long_prompt="reject")
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.submit(np.arange(20))


def test_long_prompt_truncate_and_cache_full(params):
    eng = ServeEngine(CFG, params, n_slots=1, max_len=16)
    reqs = eng.generate([np.arange(40)], max_new=8, return_requests=True)
    r = reqs[0]
    assert r.prompt_truncated and len(r.prompt) == 15   # kept the tail
    assert np.array_equal(r.prompt, np.arange(40)[-15:])
    # 15 prompt positions + 1 decode write fills the 16-entry cache
    assert r.truncated and len(r.tokens) == 2


def test_partial_results_when_steps_exhausted(params):
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64)
    prompts = [np.arange(8) + i for i in range(5)]
    reqs = eng.generate(prompts, max_new=8, max_steps=3,
                        return_requests=True)
    assert len(reqs) == 5                      # no KeyError on in-flight rows
    assert len(reqs[0].tokens) == 4 and reqs[0].truncated
    assert reqs[4].tokens == [] and reqs[4].truncated
    assert eng.stats.truncated == 5            # cancelled requests counted
    # cancelled requests are evicted: a later generate() on the same engine
    # starts clean and must not resume/mutate already-returned results
    before = list(reqs[0].tokens)
    fresh = eng.generate([np.arange(8)], max_new=2)
    assert reqs[0].tokens == before and len(fresh[0]) == 2
    # plain generate() returns the same partial token lists
    eng2 = ServeEngine(CFG, params, n_slots=2, max_len=64)
    outs = eng2.generate(prompts, max_new=8, max_steps=3)
    assert outs == [r.tokens for r in reqs]


def test_step_driver_drains_prefill_only_requests(params):
    """External `while eng.step()` loops (the serve_bench driver) must not
    strand queued requests when a whole wave finishes at prefill."""
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64)
    for i in range(6):
        eng.submit(np.arange(8) + i, max_new=1)    # all finish at prefill
    while eng.step():
        pass
    assert eng.stats.finished == 6 and not eng.queue


# ---------------------------------------------------------------------------
# Chunked decode
# ---------------------------------------------------------------------------

def test_chunked_engine_matches_per_token(params):
    """decode_chunk amortizes dispatches without changing a single token."""
    ref = ServeEngine(CFG, params, n_slots=2, max_len=64,
                      decode_chunk=1).generate(MIXED, max_new=6)
    for chunk in (3, 8):
        eng = ServeEngine(CFG, params, n_slots=2, max_len=64,
                          decode_chunk=chunk)
        assert eng.generate(MIXED, max_new=6) == ref
        # one dispatch per chunk, not per token
        assert eng.stats.decode_chunks < eng.stats.steps
        assert eng.stats.decode_tokens == eng.stats.steps * 2  # full slots


def test_chunk_clamped_to_remaining_budget(params):
    """A wave that needs 3 decode tokens must not pay for an 8-step scan:
    stats.steps counts executed device steps, so occupancy stays exact."""
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, decode_chunk=8)
    eng.generate([np.arange(8), np.arange(8) + 9], max_new=4)
    assert eng.stats.steps == 3                # 1 prefill + 3 decode tokens
    assert eng.stats.decode_chunks == 1
    assert eng.stats.mean_occupancy == 1.0


def test_run_budget_counts_device_steps(params):
    """run(max_steps) bounds device decode steps, not dispatches."""
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, decode_chunk=8)
    ids = [eng.submit(np.arange(8), max_new=20) for _ in range(2)]
    eng.run(max_steps=5)
    assert eng.stats.steps == 5
    assert all(len(eng.slots[i].tokens) == 6 for i in range(2))
    assert ids == [0, 1]


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------

def test_sample_rank_safe(params):
    eng = ServeEngine(CFG, params, n_slots=1, max_len=16)
    logits3 = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 320))
    got = eng._sample(logits3)
    want = np.asarray(jnp.argmax(logits3[:, -1, : CFG.vocab_size], -1))
    assert np.array_equal(got, want)
    eng.greedy = False
    draw = eng._sample(logits3)
    assert draw.shape == (2,) and (draw < CFG.vocab_size).all()
