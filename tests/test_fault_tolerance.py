"""Fault tolerance: bitwise crash-resume, restart budget, straggler
watchdog, restart-from-scratch when no checkpoint exists yet."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data.pipeline import make_dataset
from repro.models.model import get_model
from repro.optim import adamw
from repro.train.fault_tolerance import (FailureInjector, StepMonitor,
                                         resilient_train)
from repro.train.loop import make_train_step

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, vocab_pad_multiple=64, dtype="float32")


@pytest.fixture(scope="module")
def setup():
    api = get_model(CFG)
    params = api.init(jax.random.PRNGKey(0))
    ocfg = adamw.AdamWConfig(lr=1e-3)
    opt = adamw.init(params, ocfg)
    step_fn = jax.jit(make_train_step(api, ocfg, total_steps=100, warmup=5))

    def wrapped(p, o, batch, step):
        return step_fn(p, o, jax.tree_util.tree_map(jnp.asarray, batch),
                       step)

    ds = make_dataset(CFG, batch=8, seq=32, seed=0)
    return wrapped, params, opt, ds


def _train(setup, ckpt_dir, fail_at=(), total=12, save_every=4):
    wrapped, params, opt, ds = setup
    return resilient_train(
        train_step=wrapped, params=params, opt_state=opt, dataset=ds,
        ckpt_dir=ckpt_dir, total_steps=total, save_every=save_every,
        fail_hook=FailureInjector(fail_at=fail_at) if fail_at else None)


def test_bitwise_resume_after_crash(setup):
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        pA, _, _, rA = _train(setup, d1)
        pB, _, _, rB = _train(setup, d2, fail_at=[7])
        assert rA == 0 and rB == 1
        for a, b in zip(jax.tree_util.tree_leaves(pA),
                        jax.tree_util.tree_leaves(pB)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_multiple_failures_within_budget(setup):
    with tempfile.TemporaryDirectory() as d:
        p, _, _, restarts = _train(setup, d, fail_at=[5, 9], total=12)
        assert restarts == 2


def test_failure_before_first_checkpoint_restarts_from_scratch(setup):
    with tempfile.TemporaryDirectory() as d:
        p, _, _, restarts = _train(setup, d, fail_at=[2], total=8,
                                   save_every=100)
        assert restarts == 1  # restarted from step 0, still completed


def test_restart_budget_exceeded_raises(setup):
    wrapped, params, opt, ds = setup
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(RuntimeError):
            resilient_train(
                train_step=wrapped, params=params, opt_state=opt,
                dataset=ds, ckpt_dir=d, total_steps=10, save_every=100,
                max_restarts=1,
                fail_hook=FailureInjector(fail_at=[1, 2, 3]))


def test_straggler_monitor():
    mon = StepMonitor(straggler_factor=3.0, warmup_steps=2)
    for s in range(6):
        assert not mon.observe(s, 0.1)
    assert mon.observe(6, 1.0)          # 10x EMA -> straggler
    assert len(mon.events) == 1
    assert not mon.observe(7, 0.1)


def test_data_pipeline_random_access():
    ds = make_dataset(CFG, batch=4, seq=16, seed=3)
    b1 = ds.batch_at(10)
    b2 = ds.batch_at(10)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch_at(11)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
