"""Self-speculative decoding: the differential battery.

The hard gate of PR-level acceptance is *bit-identity* — speculative
greedy decode must produce exactly the tokens target-only greedy decode
produces, across every serving configuration, because every emitted
token is the target's own argmax (the draft only proposes). The battery:

- fast representatives (tier-1): one case per axis — dense int8+int4,
  paged, fused, multi-LoRA, bf16 target + shiftadd draft, spec_k 1/8,
  EOS landing mid-acceptance;
- the full {target} x {draft} x {mode} x {spec_k} matrix, `slow`-marked
  for its own CI lane;
- hypothesis property tests for the pure host rules (accept-longest-
  prefix, emitted block, round sizing);
- rollback invariants on the paged pool: slot tables shrink back to
  exactly the accepted KV every round, blocks_in_use returns to zero at
  drain, no refcount leaks (check_consistency), and the
  `PagedKVCache.truncate` primitive in isolation.
"""

import math

import hypothesis
import hypothesis.strategies as st
import jax
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs.base import ModelConfig
from repro.models.model import get_model
from repro.serve.engine import ServeEngine
from repro.serve.paged_cache import TRASH_BLOCK, PagedKVCache
from repro.serve.speculative import accept_length, emitted_tokens, round_k

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")

CFG = ModelConfig(name="s", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, vocab_pad_multiple=64, dtype="float32")

MIXED = [np.arange(8) + 1, np.arange(12) + 3, np.arange(31) + 7,
         np.arange(12) + 40, np.arange(8) + 60]


@pytest.fixture(scope="module")
def params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


def _adapters(n=2):
    from repro.launch.serve import make_synthetic_adapters
    return make_synthetic_adapters(CFG, n)


def _engine(params, *, speculate=False, adapters=None, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("decode_chunk", 4)
    return ServeEngine(CFG, params, greedy=True, speculate=speculate,
                       adapters=adapters, **kw)


def _assert_identical(params, *, max_new=12, prompts=MIXED, adapters=None,
                      names=None, spec_k=4, **kw):
    """Target-only vs speculative engines over the same workload: token
    lists must match exactly. Returns the speculative engine's stats."""
    gen_kw = {}
    if names is not None:
        gen_kw["adapters"] = (names * len(prompts))[: len(prompts)]
    ref = _engine(params, adapters=adapters, **kw).generate(
        prompts, max_new=max_new, **gen_kw)
    eng = _engine(params, speculate=True, spec_k=spec_k, adapters=adapters,
                  **kw)
    out = eng.generate(prompts, max_new=max_new, **gen_kw)
    assert out == ref
    assert eng.stats.spec_rounds > 0
    assert eng.stats.spec_emitted_tokens == sum(len(t) for t in out) \
        - len(prompts)                    # first tokens come from prefill
    if eng.paged:
        eng.pager.check_consistency()
        assert eng.pager.blocks_in_use == 0 or kw.get("prefix_cache", True)
    return eng.stats


# ---------------------------------------------------------------------------
# Tier-1 representatives: one fast case per matrix axis
# ---------------------------------------------------------------------------

def test_spec_dense_int8_target_int4_draft(params):
    stats = _assert_identical(params, quantize=True, draft_bits=4)
    # the serve-bench gate in miniature: speculation must beat one
    # token per round on this fixed workload (deterministic seeds)
    assert stats.accepted_tokens_per_step > 1.0
    assert stats.drafted_tokens > 0
    assert 0.0 < stats.acceptance_rate <= 1.0


def test_spec_bf16_target_shiftadd_draft(params):
    stats = _assert_identical(params, quantize=False, draft_mode="shiftadd",
                              draft_bits=8)
    assert stats.accepted_tokens_per_step > 1.0


def test_spec_paged(params):
    _assert_identical(params, quantize=True, paged=True, kv_block_size=8)


def test_spec_fused(params):
    _assert_identical(params, quantize=True, fuse_qkv=True)


def test_spec_multi_lora(params):
    reg, names = _adapters(2)
    _assert_identical(params, quantize=True, adapters=reg,
                      names=[names[0], None, names[1]])


@pytest.mark.parametrize("spec_k", [1, 8])
def test_spec_k_extremes(params, spec_k):
    _assert_identical(params, quantize=True, spec_k=spec_k)


def test_spec_eos_mid_acceptance(params):
    """An EOS landing inside the accepted prefix must cut the request
    exactly where target-only decode would stop."""
    ref_eng = _engine(params, quantize=True)
    ref_tokens = ref_eng.generate(MIXED[:2], max_new=12)
    # pick an eos id from the middle of a reference stream so the stop
    # genuinely lands mid-round for some spec_k
    eos = ref_tokens[0][len(ref_tokens[0]) // 2]
    for spec_k in (2, 4):
        _assert_identical(params, quantize=True, eos_id=int(eos),
                          spec_k=spec_k, prompts=MIXED[:2])


def test_spec_cache_full_truncation(params):
    """max_len pressure: the k clamp must keep every verify write in
    bounds and the cache_full stop must fire identically."""
    _assert_identical(params, quantize=True, max_len=16, max_new=32,
                      prompts=[np.arange(6) + 1, np.arange(10) + 2])


def test_spec_restore_after_preemption(params):
    """A speculating slot preempted by pool pressure must resume
    bit-identically (recompute restore rebuilds target AND draft KV)."""
    base = dict(quantize=True, paged=True, kv_block_size=8, n_slots=2,
                max_len=64)
    ref = ServeEngine(CFG, params, greedy=True, **base)
    want = ref.generate(MIXED, max_new=12)
    eng = ServeEngine(CFG, params, greedy=True, speculate=True, spec_k=4,
                      **base)
    for p in MIXED[:2]:
        eng.submit(p, max_new=12)
    eng.step()
    # force a preemption of a mid-flight speculating slot
    victim = next(i for i, s in enumerate(eng.slots) if s is not None)
    eng._preempt_slot(victim)
    eng.pager.check_consistency()
    assert eng.stats.preempted == 1
    for p in MIXED[2:]:
        eng.submit(p, max_new=12)
    eng.run()
    got = {r.rid: r.tokens for r in eng.finished}
    assert [got[i] for i in sorted(got)] == want
    assert eng.stats.restored >= 1
    assert eng.stats.fast_restores == 0        # gated off under speculation


# ---------------------------------------------------------------------------
# Constructor validation
# ---------------------------------------------------------------------------

def test_spec_requires_greedy(params):
    with pytest.raises(ValueError, match="greedy"):
        ServeEngine(CFG, params, speculate=True, greedy=False)


def test_spec_requires_positive_k(params):
    with pytest.raises(ValueError, match="spec_k"):
        ServeEngine(CFG, params, speculate=True, spec_k=0)


def test_spec_rejects_recurrent_family():
    ssm = ModelConfig(name="m", family="ssm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=256,
                      vocab_pad_multiple=64, xlstm_slstm_every=2,
                      dtype="float32", remat=False)
    p = get_model(ssm).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="speculative"):
        ServeEngine(ssm, p, speculate=True)


def test_spec_rejects_unknown_draft_mode(params):
    with pytest.raises(ValueError, match="draft mode"):
        ServeEngine(CFG, params, speculate=True, draft_mode="fp64")


def test_adopt_compiled_rejects_spec_mismatch(params):
    a = _engine(params, quantize=True, speculate=True, spec_k=4)
    b = _engine(params, quantize=True)
    with pytest.raises(ValueError, match="adopt_compiled"):
        b.adopt_compiled(a)
    c = _engine(params, quantize=True, speculate=True, spec_k=2)
    with pytest.raises(ValueError, match="adopt_compiled"):
        c.adopt_compiled(a)


# ---------------------------------------------------------------------------
# Host acceptance rules (hypothesis)
# ---------------------------------------------------------------------------

@st.composite
def draft_target_pairs(draw, max_k=8, vocab=16):
    """(draft, target) with target one longer, over a small vocab so
    agreements actually happen."""
    k = draw(st.integers(0, max_k))
    draft = draw(st.lists(st.integers(0, vocab - 1), min_size=k,
                          max_size=k))
    target = draw(st.lists(st.integers(0, vocab - 1), min_size=k + 1,
                           max_size=k + 1))
    return draft, target


@given(draft_target_pairs())
def test_accept_length_is_first_mismatch(pair):
    draft, target = pair
    m = accept_length(draft, target)
    assert 0 <= m <= len(draft)
    assert all(draft[i] == target[i] for i in range(m))
    if m < len(draft):
        assert draft[m] != target[m]


@given(draft_target_pairs())
def test_emitted_tokens_are_targets_prefix(pair):
    draft, target = pair
    out = emitted_tokens(draft, target)
    m = accept_length(draft, target)
    assert out == [int(t) for t in target[: m + 1]]
    assert 1 <= len(out) <= len(draft) + 1     # always progresses


@given(st.lists(st.integers(0, 15), min_size=0, max_size=8))
def test_accept_all_when_target_agrees(draft):
    """All-accept edge: target echoing the whole draft accepts k and the
    bonus token is target's final entry."""
    target = list(draft) + [99]
    assert accept_length(draft, target) == len(draft)
    assert emitted_tokens(draft, target) == list(draft) + [99]


def test_accept_k0_edge():
    assert accept_length([], [7]) == 0
    assert emitted_tokens([], [7]) == [7]


def test_accept_length_shape_mismatch():
    with pytest.raises(ValueError):
        accept_length([1, 2], [1, 2])


@given(st.integers(1, 16), st.integers(4, 64),
       st.lists(st.integers(0, 60), min_size=1, max_size=4),
       st.lists(st.integers(1, 40), min_size=1, max_size=4))
def test_round_k_invariants(spec_k, max_len, positions, budgets):
    hypothesis.assume(all(p <= max_len - 1 for p in positions))
    k = round_k(spec_k, max_len=max_len, positions=positions,
                budgets=budgets)
    assert 0 <= k <= spec_k
    # every verify write stays in bounds for every slot
    assert max(positions) + k <= max_len - 1
    # a round emits at most k+1; never draft past the largest budget
    assert k == 0 or k + 1 <= max(budgets) + 1
    # bucketing: k is 0, a power of two, or spec_k itself
    assert k in (0, spec_k) or (k & (k - 1)) == 0


def test_round_k_rejects_bad_spec_k():
    with pytest.raises(ValueError):
        round_k(0, max_len=8, positions=[1], budgets=[4])


# ---------------------------------------------------------------------------
# Rollback invariants: the paged pool never leaks speculative blocks
# ---------------------------------------------------------------------------

def test_truncate_frees_trailing_blocks():
    p = PagedKVCache(n_slots=2, n_blocks=20, block_size=4,
                     max_blocks_per_slot=8, prefix_cache=False)
    assert p.admit(0, [], 5)
    base = p.blocks_in_use
    blocks = p.slot_blocks(0)
    assert p.truncate(0, 9) == 2              # keep ceil(9/4)=3 of 5
    assert p.blocks_in_use == base - 2
    assert p.slot_blocks(0) == blocks[:3]
    assert all(int(b) == TRASH_BLOCK for b in p.tables[0, 3:])
    assert p.truncate(0, 12) == 0             # already exact: no-op
    assert p.truncate(0, 20) == 0             # growing is not truncate's job
    p.check_consistency()
    p.release_slot(0)
    assert p.blocks_in_use == 0


def test_truncate_preserves_published_prefixes():
    """A truncated block the radix index still holds survives with its
    published prefix intact — rollback must not rewrite history."""
    p = PagedKVCache(n_slots=2, n_blocks=20, block_size=4,
                     max_blocks_per_slot=8)
    seq = list(range(1, 13))                   # 3 full blocks
    assert p.admit(0, [], 3)
    p.insert(seq, p.slot_blocks(0))
    shared = p.slot_blocks(0)
    assert p.truncate(0, 5) == 1              # drop the slot's 3rd block
    p.check_consistency()
    # the published prefix still matches in full for a new request
    hit, n = p.match(seq + [13])              # match does not acquire
    assert n == 12 and hit == shared
    p.release_slot(0)
    p.check_consistency()


def test_truncate_boundary_block_kept():
    p = PagedKVCache(n_slots=1, n_blocks=12, block_size=4,
                     max_blocks_per_slot=8, prefix_cache=False)
    assert p.admit(0, [], 4)
    # new_len inside block 2: blocks 0..2 stay, block 3 frees
    assert p.truncate(0, 11) == 1
    assert len(p.slot_blocks(0)) == 3
    p.check_consistency()


def test_spec_rollback_returns_blocks_every_round(params):
    """Drive a paged speculative engine step by step: after every round
    each running slot holds exactly ceil(kv_len / block) blocks — the
    k+1 verify window's surplus went back to the pool — and the books
    balance at every step and at drain."""
    eng = _engine(params, speculate=True, spec_k=4, quantize=True,
                  paged=True, kv_block_size=8, prefix_cache=False)
    for prompt in MIXED:
        eng.submit(prompt, max_new=12)
    while eng.step():
        eng.pager.check_consistency()
        for i, r in enumerate(eng.slots):
            if r is None:
                continue
            kv_len = len(r.prompt) + len(r.tokens) - 1
            assert len(eng.pager.slot_blocks(i)) == math.ceil(kv_len / 8)
    assert eng.pager.blocks_in_use == 0        # no leaked refcounts
    eng.pager.check_consistency()


# ---------------------------------------------------------------------------
# The full differential matrix (its own CI lane)
# ---------------------------------------------------------------------------

TARGETS = [("int8", dict(quantize=True)),
           ("bf16", dict(quantize=False))]
DRAFTS = [("int4", dict(draft_bits=4, draft_mode="affine")),
          ("shiftadd", dict(draft_bits=8, draft_mode="shiftadd"))]
MODES = [("plain", dict()),
         ("fused", dict(fuse_qkv=True)),
         ("paged", dict(paged=True, kv_block_size=8)),
         ("lora", dict())]


@pytest.mark.slow
@pytest.mark.parametrize("tname,tkw", TARGETS, ids=[t[0] for t in TARGETS])
@pytest.mark.parametrize("dname,dkw", DRAFTS, ids=[d[0] for d in DRAFTS])
@pytest.mark.parametrize("mname,mkw", MODES, ids=[m[0] for m in MODES])
@pytest.mark.parametrize("spec_k", [1, 8])
def test_spec_differential_matrix(params, tname, tkw, dname, dkw, mname,
                                  mkw, spec_k):
    kw = dict(tkw); kw.update(dkw); kw.update(mkw)
    adapters = names = None
    if mname == "lora":
        reg, adapter_names = _adapters(2)
        adapters = reg
        names = [adapter_names[0], None, adapter_names[1]]
    _assert_identical(params, spec_k=spec_k, adapters=adapters,
                      names=names, **kw)
