"""Overload robustness: admission control, preemption by paged swap-out,
atomic step semantics, and the chaos fault-injection harness.

The invariants under test mirror docs/ARCHITECTURE.md's "Request lifecycle
& overload behavior": every submitted request reaches exactly one terminal
state, shedding follows policy (reject / evict / expire), preempted
requests restore token-identically (fast path and recompute path), and a
failed wave or a dry pool leaves the engine exactly as if the step never
started (no leaked blocks, no leaked adapter pins).
"""

import dataclasses
import itertools

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.serve.engine import ServeEngine
from repro.serve.paged_cache import PagedKVCache
from repro.serve.scheduler import (WaitQueue, arrival_times, parse_arrival,
                                   pick_victim)
from repro.models.model import get_model

CFG = ModelConfig(name="s", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, vocab_pad_multiple=64, dtype="float32")

MIXED = [np.arange(8), np.arange(12) + 3, np.arange(31) + 7,
         np.arange(12) + 40]


@pytest.fixture(scope="module")
def params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Scheduler units: queue policies, deadlines, victims, arrivals
# ---------------------------------------------------------------------------

class _Req:
    _rid = itertools.count()

    def __init__(self, priority=0, deadline_s=None, t_submit=0.0):
        self.rid = next(self._rid)
        self.priority = priority
        self.deadline_s = deadline_s
        self.t_submit = t_submit


def test_queue_orders_by_priority_then_fifo():
    q = WaitQueue()
    lo, hi, lo2 = _Req(0), _Req(5), _Req(0)
    for r in (lo, hi, lo2):
        assert q.offer(r).admitted
    assert q.take(3) == [hi, lo, lo2]


def test_queue_reject_policy_sheds_newcomer():
    q = WaitQueue(max_queue=1, policy="reject")
    first, second = _Req(), _Req(9)
    assert q.offer(first).admitted
    dec = q.offer(second)
    assert not dec.admitted and dec.evicted is None and not dec.must_block
    assert list(q) == [first]


def test_queue_evict_policy_sheds_strictly_lower():
    q = WaitQueue(max_queue=1, policy="evict")
    lo = _Req(priority=1)
    q.offer(lo)
    dec = q.offer(_Req(priority=5))
    assert dec.admitted and dec.evicted is lo
    # an equal-priority newcomer must NOT evict (strict inequality)
    dec = q.offer(_Req(priority=5))
    assert not dec.admitted and dec.evicted is None


def test_queue_block_policy_signals_must_block():
    q = WaitQueue(max_queue=1, policy="block")
    q.offer(_Req())
    assert q.offer(_Req()).must_block
    # push_front bypasses the bound: preempted requests always requeue
    q.push_front(_Req(priority=3))
    assert len(q) == 2


def test_queue_deadline_expiry():
    q = WaitQueue()
    keep = _Req(deadline_s=100.0, t_submit=0.0)
    drop = _Req(deadline_s=1.0, t_submit=0.0)
    q.offer(keep)
    q.offer(drop)
    assert q.expire(now=5.0) == [drop]
    assert list(q) == [keep]


def test_pick_victim_lowest_priority_then_youngest():
    a, b, c = _Req(priority=2), _Req(priority=0), _Req(priority=0)
    assert pick_victim([a, None, b, c]) == 3     # lowest prio, largest rid
    assert pick_victim([None, None]) is None
    assert pick_victim([a], below_priority=2) is None   # strict inequality
    assert pick_victim([a], below_priority=3) == 0


def test_arrival_parsing_and_times():
    assert parse_arrival("fixed:2.0") == ("fixed", 2.0)
    assert parse_arrival("poisson:0.5") == ("poisson", 0.5)
    for bad in ("poisson:", "burst:1", "poisson:-1", "poisson:0"):
        with pytest.raises(ValueError):
            parse_arrival(bad)
    fixed = arrival_times("fixed:2.0", 4)
    np.testing.assert_allclose(fixed, [0.5, 1.0, 1.5, 2.0])
    pois = arrival_times("poisson:2.0", 64, seed=1)
    assert np.all(np.diff(pois) >= 0) and pois[0] >= 0
    np.testing.assert_array_equal(pois, arrival_times("poisson:2.0", 64,
                                                      seed=1))


# ---------------------------------------------------------------------------
# Pager: plan-then-commit admission, read-only decode planning
# ---------------------------------------------------------------------------

def _pager(**kw):
    args = dict(n_slots=2, n_blocks=12, block_size=4, max_blocks_per_slot=4)
    args.update(kw)
    return PagedKVCache(**args)


def test_pager_admit_rolls_back_on_exhaustion():
    """The regression this PR fixes: alloc()/append_block() raising
    mid-admission used to leak every block acquired before the failure."""
    p = _pager()
    held = [p.alloc() for _ in range(9)]        # 11 usable, keep 2 free
    before = p.blocks_in_use
    assert not p.admit(0, [], 3)                # needs 3, only 2 available
    assert p.blocks_in_use == before            # nothing leaked
    assert p.slot_blocks(0) == []
    assert p.admit(0, [], 2)                    # exactly what's left: fine
    p.release_slot(0)
    for b in held:
        p._release_block(b)
    p.check_consistency()


def test_pager_admit_rejects_oversized_wave():
    p = _pager()
    assert not p.admit(0, [], 5)                # > max_blocks_per_slot
    assert p.blocks_in_use == 0


def test_pager_plan_decode_is_readonly():
    p = _pager()
    assert p.admit(0, [], 2)
    before = p.blocks_in_use
    appends, cows = p.plan_decode(0, pos0=7, n=4)   # crosses into block 2
    assert (appends, cows) == (1, 0)
    assert p.blocks_in_use == before            # planning commits nothing
    p.check_consistency()


def test_pager_check_consistency_external_blocks():
    p = _pager()
    b = p.alloc()
    with pytest.raises(AssertionError):
        p.check_consistency()                   # ownerless ref=1 block
    p.check_consistency(external=[b])           # accounted: passes
    p._release_block(b)
    p.check_consistency()


# ---------------------------------------------------------------------------
# Engine: preempt -> swap-out -> restore token identity
# ---------------------------------------------------------------------------

def _generate(cfg, params, prompts, max_new=10, **kw):
    eng = ServeEngine(cfg, params, n_slots=2, max_len=64, paged=True,
                      kv_block_size=8, **kw)
    return eng.generate(prompts, max_new=max_new), eng


def _generate_preempted(cfg, params, prompts, max_new=10, evict=False, **kw):
    """Drive manually, forcibly preempting one running slot after the
    first step (so it holds generated tokens + a partial tail block)."""
    eng = ServeEngine(cfg, params, n_slots=2, max_len=64, paged=True,
                      kv_block_size=8, **kw)
    for pr in prompts:
        eng.submit(np.asarray(pr, np.int32), max_new=max_new)
    steps = 0
    while eng.step():
        steps += 1
        if steps == 1:
            vic = next(i for i, s in enumerate(eng.slots)
                       if s is not None and not s.done)
            eng._preempt_slot(vic)
            if evict:
                eng.pager.evict_prefixes()      # destroy the published KV
        assert steps < 500, "preempted run failed to converge"
    toks = [list(r.tokens) for r in sorted(eng.finished,
                                           key=lambda r: r.rid)]
    return toks, eng


@pytest.mark.parametrize("mode", ["fp32", "int8", "reuse", "fused",
                                  "chunk1"])
def test_preempt_restore_token_identity(params, mode):
    cfg = CFG
    kw = {}
    if mode == "int8":
        kw["quantize"] = True
    elif mode == "reuse":
        kw.update(quantize=True, impl="reuse")
    elif mode == "fused":
        kw.update(quantize=True, fuse_qkv=True)
    elif mode == "chunk1":
        kw["decode_chunk"] = 1
    want, _ = _generate(cfg, params, MIXED[:2], **kw)
    got, eng = _generate_preempted(cfg, params, MIXED[:2], **kw)
    assert got == want
    assert eng.stats.preempted >= 1 and eng.stats.restored >= 1
    eng.pager.check_consistency()


def test_fast_restore_used_when_prefix_survives(params):
    want, _ = _generate(CFG, params, MIXED[:2])
    got, eng = _generate_preempted(CFG, params, MIXED[:2])
    assert got == want
    assert eng.stats.fast_restores >= 1         # no recompute needed


def test_recompute_restore_after_eviction_storm(params):
    """Evicting the preempted request's published KV forces the recompute
    path — still token-identical, but through a fresh prefill."""
    want, _ = _generate(CFG, params, MIXED[:2])
    got, eng = _generate_preempted(CFG, params, MIXED[:2], evict=True)
    assert got == want
    assert eng.stats.fast_restores == 0
    assert eng.stats.restored >= 1


@pytest.mark.slow
def test_preempt_restore_identity_int8kv(params):
    cfg = dataclasses.replace(CFG, quant_kv=True)
    want, _ = _generate(cfg, params, MIXED, quantize=True)
    got, eng = _generate_preempted(cfg, params, MIXED, quantize=True)
    assert got == want and eng.stats.preempted >= 1


# ---------------------------------------------------------------------------
# Engine: admission policies, deadlines, pool-exhaust rollback
# ---------------------------------------------------------------------------

def test_engine_reject_policy_is_nonraising(params):
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, paged=True,
                      kv_block_size=8, max_queue=1, admission="reject")
    eng.submit(np.asarray(MIXED[0], np.int32), max_new=4)
    eng.step()                                  # seat it; queue empties
    eng.submit(np.asarray(MIXED[1], np.int32), max_new=4)   # queued
    shed = eng.submit(np.asarray(MIXED[2], np.int32), max_new=4)
    eng.run()
    by_rid = {r.rid: r for r in eng.finished}
    assert by_rid[shed].finish_reason == "rejected"
    assert by_rid[shed].tokens == []
    assert sum(1 for r in eng.finished
               if r.finish_reason == "rejected") == 1
    assert len(eng.finished) == 3
    assert not eng.queue and all(s is None for s in eng.slots)


def test_engine_evict_policy_prefers_low_priority(params):
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, paged=True,
                      kv_block_size=8, max_queue=1, admission="evict")
    for i in range(2):
        eng.submit(np.asarray(MIXED[i], np.int32), max_new=4)
    eng.step()                                  # both seated
    victim = eng.submit(np.asarray(MIXED[2], np.int32), max_new=4,
                        priority=0)             # queued
    vip = eng.submit(np.asarray(MIXED[3], np.int32), max_new=4,
                     priority=7)                # evicts the queued prio-0
    eng.run()
    by_rid = {r.rid: r for r in eng.finished}
    assert by_rid[victim].finish_reason == "rejected"
    assert by_rid[vip].finish_reason not in ("rejected", "expired")
    assert len(by_rid[vip].tokens) == 4


def test_engine_deadline_expires_queued_request(params):
    clock = itertools.count()                   # 1 "second" per call
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, paged=True,
                      kv_block_size=8, clock=lambda: float(next(clock)))
    for pr in MIXED[:2]:
        eng.submit(np.asarray(pr, np.int32), max_new=4)
    doomed = eng.submit(np.asarray(MIXED[2], np.int32), max_new=4,
                        deadline_s=0.0)
    eng.run()
    by_rid = {r.rid: r for r in eng.finished}
    assert by_rid[doomed].finish_reason == "expired"
    assert by_rid[doomed].tokens == []
    assert sum(1 for r in eng.finished
               if r.finish_reason not in ("rejected", "expired")) == 2


def test_engine_pool_exhaust_stalls_then_recovers(params):
    """With the whole pool stolen, admission must roll back cleanly
    (blocks_in_use returns to its pre-wave value), the stall guard must
    refuse to spin forever, and returning the blocks must let the same
    queued request complete."""
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, paged=True,
                      kv_block_size=8)
    held = [eng.pager.alloc() for _ in range(len(eng.pager._free))]
    before = eng.pager.blocks_in_use
    eng.submit(np.asarray(MIXED[0], np.int32), max_new=4)
    with pytest.raises(RuntimeError, match="stalled"):
        eng.run()
    assert eng.pager.blocks_in_use == before    # admission left no trace
    assert len(eng.queue) == 1                  # request survived
    for b in held:
        eng.pager._release_block(b)
    eng.run()
    assert [r.finish_reason for r in eng.finished] not in (["rejected"],
                                                           ["expired"])
    assert len(eng.finished) == 1 and len(eng.finished[0].tokens) == 4


# ---------------------------------------------------------------------------
# Adapter pins: released on every exit path
# ---------------------------------------------------------------------------

def _lora_engine(params, fault_hook=None):
    from repro.launch.serve import make_synthetic_adapters
    reg, names = make_synthetic_adapters(CFG, n=1)
    # decode_chunk=1 keeps requests mid-decode across steps, so pins are
    # demonstrably held while running (chunk 8 would finish max_new=8 in
    # one dispatch and release the pin before the test can look)
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, quantize=True,
                      decode_chunk=1, adapters=reg, fault_hook=fault_hook)
    return eng, reg, names[0]


def test_adapter_pin_released_on_cancel(params):
    eng, reg, name = _lora_engine(params)
    rid = eng.submit(np.asarray(MIXED[0], np.int32), max_new=8,
                     adapter=name)
    eng.step()                                  # running, pin held
    with pytest.raises(RuntimeError):
        reg.evict(name)                         # pinned: must refuse
    eng._cancel(rid)
    reg.evict(name)                             # pin released: evictable
    assert not any(reg._refs)


def test_adapter_pin_survives_fault_then_releases(params):
    calls = {"n": 0}

    def hook(phase):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("injected prefill fault")

    eng, reg, name = _lora_engine(params, fault_hook=hook)
    eng.submit(np.asarray(MIXED[0], np.int32), max_new=4, adapter=name)
    with pytest.raises(RuntimeError, match="injected"):
        eng.step()                              # wave requeued, pin kept
    eng.run()                                   # retry succeeds
    assert len(eng.finished) == 1 and len(eng.finished[0].tokens) == 4
    assert not any(reg._refs)                   # drained: pin released
    reg.evict(name)


# ---------------------------------------------------------------------------
# Chaos harness smoke
# ---------------------------------------------------------------------------

def test_chaos_dispatch_faults_scenario():
    from repro.serve import chaos
    rep, = chaos.run(scenarios=["dispatch_faults"], smoke=True)
    assert rep.ok, rep.errors
    assert rep.faults_injected > 0 and rep.lost == 0 and rep.mismatched == 0


@pytest.mark.slow
def test_chaos_all_scenarios():
    from repro.serve import chaos
    for rep in chaos.run(smoke=True):
        assert rep.ok, (rep.scenario, rep.errors)
