"""SSD/Mamba2 and xLSTM cell validation: chunked-parallel vs naive
recurrence, chunk-size invariance, decode==forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import ssm as S
from repro.models import xlstm as X


def naive_ssd(x, la, b, c):
    B, T, H, P = x.shape
    N = b.shape[-1]
    h = np.zeros((B, H, P, N))
    ys = []
    for t in range(T):
        h = h * np.exp(la[:, t])[..., None, None] + \
            np.einsum("bhp,bhn->bhpn", x[:, t], b[:, t])
        ys.append(np.einsum("bhpn,bhn->bhp", h, c[:, t]))
    return np.stack(ys, 1), h


@pytest.mark.parametrize("seq,chunk", [(13, 4), (32, 8), (7, 16), (64, 64)])
def test_ssd_chunked_vs_naive(seq, chunk):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (2, seq, 3, 5))
    la = -jax.nn.softplus(jax.random.normal(ks[1], (2, seq, 3)))
    b = jax.random.normal(ks[2], (2, seq, 3, 4))
    c = jax.random.normal(ks[3], (2, seq, 3, 4))
    y, h = S.ssd_chunked(x, la, b, c, chunk=chunk)
    y_ref, h_ref = naive_ssd(*map(np.asarray, (x, la, b, c)))
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-4, atol=1e-5)


def test_ssd_chunk_size_invariance():
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (1, 24, 2, 4))
    la = -jax.nn.softplus(jax.random.normal(ks[1], (1, 24, 2)))
    b = jax.random.normal(ks[2], (1, 24, 2, 3))
    c = jax.random.normal(ks[3], (1, 24, 2, 3))
    y1, h1 = S.ssd_chunked(x, la, b, c, chunk=4)
    y2, h2 = S.ssd_chunked(x, la, b, c, chunk=24)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-5)


MCFG = ModelConfig(name="m", family="hybrid", n_layers=1, d_model=64,
                   n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128,
                   ssm_state=16, ssm_head_dim=16, dtype="float32")


def test_mamba2_decode_matches_forward():
    p = S.init_mamba2(jax.random.PRNGKey(2), MCFG)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 20, 64))
    y_full, (cv_T, h_T) = S.mamba2_fwd(p, x, MCFG, return_state=True)
    cv, st = S.init_mamba_state(MCFG, 2)
    ys = []
    for t in range(20):
        yt, (cv, st) = S.mamba2_step(p, x[:, t:t + 1], MCFG, cv, st)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(h_T),
                               rtol=1e-4, atol=1e-5)


XCFG = ModelConfig(name="x", family="ssm", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=128,
                   vocab_pad_multiple=64, xlstm_slstm_every=2,
                   dtype="float32", remat=False)


def test_mlstm_decode_matches_chunkwise_forward():
    p = X.init_mlstm(jax.random.PRNGKey(4), XCFG)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 64))
    y_full = X.mlstm_fwd(p, x, XCFG)
    state = X.init_mlstm_state(XCFG, 2)
    ys = []
    for t in range(16):
        yt, state = X.mlstm_step(p, x[:, t:t + 1], XCFG, state)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=2e-3, atol=2e-3)


def test_slstm_step_matches_forward():
    p = X.init_slstm(jax.random.PRNGKey(6), XCFG)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 12, 64))
    y_full = X.slstm_fwd(p, x, XCFG)
    state = X.init_slstm_state(XCFG, 2)
    ys = []
    for t in range(12):
        yt, state = X.slstm_step(p, x[:, t:t + 1], XCFG, state)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-5)


def test_mlstm_state_conversion_roundtrip():
    """Chunkwise-emitted state continues correctly in the step path."""
    p = X.init_mlstm(jax.random.PRNGKey(8), XCFG)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 20, 64))
    # full pass over 20 tokens
    y_full = X.mlstm_fwd(p, x, XCFG)
    # chunkwise over first 12, then step through the rest
    _, state = X.mlstm_fwd(p, x[:, :12], XCFG, return_state=True)
    ys = []
    for t in range(12, 20):
        yt, state = X.mlstm_step(p, x[:, t:t + 1], XCFG, state)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full[:, 12:]), rtol=2e-3,
                               atol=2e-3)


def test_ssd_decay_stability_long_sequence():
    """No overflow/NaN over a long sequence with strong decays (f32)."""
    key = jax.random.PRNGKey(10)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (1, 512, 2, 4))
    la = -jax.nn.softplus(jax.random.normal(ks[1], (1, 512, 2)) - 3.0)
    b = jax.random.normal(ks[2], (1, 512, 2, 4))
    c = jax.random.normal(ks[3], (1, 512, 2, 4))
    y, h = S.ssd_chunked(x, la, b, c, chunk=128)
    assert bool(jnp.all(jnp.isfinite(y))) and bool(jnp.all(jnp.isfinite(h)))
