"""Tensor-parallel serving equivalence (PR 7 tentpole gate).

Two families of tests, both on the 8 forced host CPU devices:

1. Engine token identity: ``ServeEngine(..., mesh=...)`` must generate
   byte-identical token streams to the single-device engine, for every
   orthogonal serving feature (fp32 / int8 / reuse-LUT / fused-QKV /
   multi-LoRA / paged KV) at mesh (1, 2) (head-sharded KV: n_kv_heads=2
   divides model=2) and mesh (1, 8) (sequence-sharded KV: 2 % 8 != 0, so
   the rules fall back to cache_seq="model" and decode routes through
   ``kernels.sharded_decode``). The fast subset runs in tier-1; the full
   matrix is ``slow``-marked and runs in CI's multi_device lane.

2. ``decode_attention_seqsharded`` goldens (int8-KV codes + scales)
   against BOTH dense ``decode_attention_ref`` and
   ``paged_decode_attention_ref`` on the scattered-equivalent pool, plus
   the length-0-row exact-zero convention the online-softmax kernels
   share (l == 0 -> acc / max(l, eps) == 0, not NaN).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ref import decode_attention_ref, paged_decode_attention_ref
from repro.kernels.sharded_decode import decode_attention_seqsharded
from repro.launch.mesh import make_host_mesh
from repro.models.model import get_model

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, vocab_pad_multiple=64, dtype="float32")

PROMPT_LENS = (5, 9, 3, 12, 7, 4)

# engine kwargs per serving feature; "lora" is synthesized in _generate
MODES = {
    "fp32": {},
    "int8": dict(quantize=True),
    "reuse": dict(quantize=True, impl="reuse"),
    "fused": dict(quantize=True, fuse_qkv=True),
    "lora": dict(quantize=True),
    "paged": dict(quantize=True, paged=True, kv_block_size=8),
}


@pytest.fixture(scope="module")
def base_params(eight_cpu_devices):
    api = get_model(CFG)
    return api.init(jax.random.PRNGKey(0))


def _generate(params, mesh, mode):
    from repro.launch.serve import make_synthetic_adapters
    from repro.serve.engine import ServeEngine

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, CFG.vocab_size, size=n).astype(np.int32)
               for n in PROMPT_LENS]
    reg, names = None, [None] * len(prompts)
    if mode == "lora":
        reg, ns = make_synthetic_adapters(CFG, 2)
        names = [None if i % 3 == 0 else ns[i % 2]
                 for i in range(len(prompts))]
    eng = ServeEngine(CFG, params, n_slots=2, max_len=64, mesh=mesh,
                      adapters=reg, **MODES[mode])
    return eng.generate(prompts, max_new=8, adapters=names)


def _assert_token_identical(params, mode, model_size):
    base = _generate(params, None, mode)
    got = _generate(params, make_host_mesh(1, model_size), mode)
    assert got == base, (
        f"mesh (1, {model_size}) {mode} tokens diverge from single-device")


# fast subset (tier-1): one head-sharded mode pair at mesh 2
@pytest.mark.parametrize("mode", ["fp32", "int8"])
def test_engine_token_identity_mesh2(base_params, mode):
    _assert_token_identical(base_params, mode, 2)


# full matrix: remaining features x {head-sharded, seq-sharded} meshes
@pytest.mark.slow
@pytest.mark.parametrize("mode", ["reuse", "fused", "lora", "paged"])
def test_engine_token_identity_mesh2_full(base_params, mode):
    _assert_token_identical(base_params, mode, 2)


@pytest.mark.slow
@pytest.mark.parametrize("mode", sorted(MODES))
def test_engine_token_identity_mesh8(base_params, mode):
    _assert_token_identical(base_params, mode, 8)


def test_mesh1_is_single_device_program(base_params):
    """A (1, 1) mesh resolves every spec to full replication, so the
    engine compiles the exact unsharded computation (size-1 axes are
    skipped by resolve_spec) — tokens trivially identical."""
    _assert_token_identical(base_params, "int8", 1)


# ---------------------------------------------------------------------------
# decode_attention_seqsharded goldens (satellite 4)
# ---------------------------------------------------------------------------

def _seqsharded_case(lengths, seed=0):
    """Random int8-KV decode state: caches hold codes, scales ride along.

    Returns (inputs dict, expected updated numpy caches/scales)."""
    b, s, h, hk, d = len(lengths), 32, 4, 2, 16
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(b, h, d)).astype(np.float32)
    k = rng.integers(-127, 128, size=(b, s, hk, d)).astype(np.int8)
    v = rng.integers(-127, 128, size=(b, s, hk, d)).astype(np.int8)
    ks = rng.uniform(0.01, 0.05, size=(b, s, hk, 1)).astype(np.float32)
    vs = rng.uniform(0.01, 0.05, size=(b, s, hk, 1)).astype(np.float32)
    nk = rng.integers(-127, 128, size=(b, hk, d)).astype(np.int8)
    nv = rng.integers(-127, 128, size=(b, hk, d)).astype(np.int8)
    nks = rng.uniform(0.01, 0.05, size=(b, hk, 1)).astype(np.float32)
    nvs = rng.uniform(0.01, 0.05, size=(b, hk, 1)).astype(np.float32)
    length = np.asarray(lengths, np.int32)
    pos = length - 1                       # write slot; -1 when length == 0
    exp = {"k": k.copy(), "v": v.copy(), "ks": ks.copy(), "vs": vs.copy()}
    for i, p in enumerate(pos):
        if p >= 0:
            exp["k"][i, p], exp["v"][i, p] = nk[i], nv[i]
            exp["ks"][i, p], exp["vs"][i, p] = nks[i], nvs[i]
    inputs = dict(q=q, k=k, v=v, ks=ks, vs=vs, nk=nk, nv=nv, nks=nks,
                  nvs=nvs, pos=pos, length=length)
    return inputs, exp


def _run_seqsharded(inputs, model_size=4):
    mesh = make_host_mesh(1, model_size)
    i = {k: jnp.asarray(a) for k, a in inputs.items()}
    return decode_attention_seqsharded(
        i["q"], i["k"], i["v"], i["nk"], i["nv"], i["pos"], i["length"],
        mesh, seq_axes=("model",), batch_axes=(),
        k_scale=i["ks"], v_scale=i["vs"],
        new_k_scale=i["nks"], new_v_scale=i["nvs"])


def test_seqsharded_int8_matches_dense_and_paged_refs(eight_cpu_devices):
    """Golden: seq-sharded fused update+attend == dense ref on the
    manually scattered cache == paged ref on the block-pool layout."""
    inputs, exp = _seqsharded_case([5, 32, 17, 1])
    out, k2, v2, ks2, vs2 = _run_seqsharded(inputs)

    # the local masked scatter is exact (int8 codes + f32 scales)
    np.testing.assert_array_equal(np.asarray(k2), exp["k"])
    np.testing.assert_array_equal(np.asarray(v2), exp["v"])
    np.testing.assert_array_equal(np.asarray(ks2), exp["ks"])
    np.testing.assert_array_equal(np.asarray(vs2), exp["vs"])

    dense = decode_attention_ref(
        jnp.asarray(inputs["q"]), jnp.asarray(exp["k"]), jnp.asarray(exp["v"]),
        jnp.asarray(inputs["length"]),
        k_scale=jnp.asarray(exp["ks"]), v_scale=jnp.asarray(exp["vs"]))
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=1e-4, atol=1e-6)

    # identity block tables: row b's sequence lives in blocks
    # 1 + b*nb .. 1 + b*nb + nb - 1 (block 0 is the trash block)
    b, s, hk, d = exp["k"].shape
    bs = 8
    nb = s // bs

    def pool(cache):
        trash = np.zeros((1, bs) + cache.shape[2:], cache.dtype)
        blocks = cache.reshape(b * nb, bs, *cache.shape[2:])
        return jnp.asarray(np.concatenate([trash, blocks]))

    tables = jnp.asarray(
        1 + np.arange(b * nb, dtype=np.int32).reshape(b, nb))
    paged = paged_decode_attention_ref(
        jnp.asarray(inputs["q"]), pool(exp["k"]), pool(exp["v"]), tables,
        jnp.asarray(inputs["length"]),
        k_scale=pool(exp["ks"]), v_scale=pool(exp["vs"]))
    np.testing.assert_allclose(np.asarray(out), np.asarray(paged),
                               rtol=1e-4, atol=1e-6)


def test_seqsharded_length0_row_is_exact_zero(eight_cpu_devices):
    """length == 0 rows produce EXACT zeros (l == 0 -> acc/max(l, eps)),
    never NaN, and write nothing into any shard's cache rows."""
    inputs, exp = _seqsharded_case([0, 3])
    out, k2, v2, ks2, vs2 = _run_seqsharded(inputs, model_size=2)
    out = np.asarray(out)
    assert np.all(out[0] == 0.0), "length-0 row must be exactly zero"
    assert not np.any(np.isnan(out))
    # row 0's pos is -1: no shard owns it, the cache is untouched
    np.testing.assert_array_equal(np.asarray(k2)[0], inputs["k"][0])
    np.testing.assert_array_equal(np.asarray(ks2)[0], inputs["ks"][0])
    # row 1 still behaves
    dense = decode_attention_ref(
        jnp.asarray(inputs["q"]), jnp.asarray(exp["k"]), jnp.asarray(exp["v"]),
        jnp.asarray(inputs["length"]),
        k_scale=jnp.asarray(exp["ks"]), v_scale=jnp.asarray(exp["vs"]))
    np.testing.assert_allclose(out[1], np.asarray(dense)[1],
                               rtol=1e-4, atol=1e-6)
