"""Scan-decode equivalence: decode_steps(n) must be token-for-token
identical to n sequential api.decode calls — fp and deploy-quantized,
across all four families, plus stop-mask semantics and the Pallas
interpret-mode path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.axllm_linear import deploy_quantize
from repro.core.quantization import QuantConfig
from repro.models.model import get_model, make_batch
from repro.serve.decode import decode_steps

DENSE = ModelConfig(name="d", family="dense", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                    head_dim=16, vocab_pad_multiple=64, dtype="float32")
SSM = ModelConfig(name="x", family="ssm", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=0, vocab_size=256, vocab_pad_multiple=64,
                  xlstm_slstm_every=2, dtype="float32", remat=False)
HYBRID = ModelConfig(name="h", family="hybrid", n_layers=5, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                     head_dim=16, vocab_pad_multiple=64, ssm_state=16,
                     ssm_head_dim=16, hybrid_attn_every=2, dtype="float32",
                     remat=False)
AUDIO = ModelConfig(name="a", family="audio", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
                    head_dim=16, vocab_pad_multiple=64, act="gelu",
                    is_encoder_decoder=True, n_enc_layers=1, enc_seq=9,
                    d_feat=4, dtype="float32", remat=False)
FAMILIES = {"dense": DENSE, "ssm": SSM, "hybrid": HYBRID, "audio": AUDIO}

MAX_LEN = 32
B, PROMPT, N = 2, 6, 5


def _prefill(cfg, params, api, impl="auto"):
    cache = api.init_cache(B, MAX_LEN)
    batch = make_batch(cfg, 0, B, PROMPT)
    if cfg.is_encoder_decoder:
        logits, cache = api.prefill(params, batch, cache)
    else:
        logits, cache = api.prefill(params, {"tokens": batch["tokens"]},
                                    cache)
    last = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    return last, cache


def _sequential(cfg, api, params, last, cache, n):
    toks = []
    for _ in range(n):
        logits, cache = api.decode(params, last, cache)
        last = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
        toks.append(np.asarray(last))
    return np.stack(toks)


def _chunked(cfg, api, params, last, cache, n):
    out = decode_steps(
        api.decode, params, last, cache, jax.random.PRNGKey(0),
        jnp.zeros((B,), bool), jnp.ones((B,), jnp.int32),
        jnp.full((B,), n + 10, jnp.int32), n=n,
        vocab_size=cfg.vocab_size, max_len=MAX_LEN)
    assert bool(np.asarray(out.valid).all())
    return np.asarray(out.tokens)


@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("quantized", [False, True],
                         ids=["fp", "axllm-int8"])
def test_scan_decode_matches_sequential(family, quantized):
    cfg = FAMILIES[family]
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    if quantized:
        params = deploy_quantize(params, QuantConfig(
            bits=8, mode="affine", granularity="per_channel"))
    last, cache = _prefill(cfg, params, api)
    # the scan donates nothing here: hand each path its own cache copy
    cache2 = jax.tree_util.tree_map(jnp.array, cache)
    seq = _sequential(cfg, api, params, last, cache, N)
    got = _chunked(cfg, api, params, last, cache2, N)
    np.testing.assert_array_equal(got, seq)


def test_scan_decode_interpret_mode():
    """Quantized dense decode through the Pallas kernels in interpret mode:
    the chunked scan must match the sequential interpret-mode loop."""
    cfg = DENSE
    api = get_model(cfg, impl="pallas_interpret")
    params = api.init(jax.random.PRNGKey(0))
    params = deploy_quantize(params, QuantConfig(
        bits=8, mode="affine", granularity="per_channel"))
    last, cache = _prefill(cfg, params, api)
    cache2 = jax.tree_util.tree_map(jnp.array, cache)
    seq = _sequential(cfg, api, params, last, cache, 3)
    got = _chunked(cfg, api, params, last, cache2, 3)
    np.testing.assert_array_equal(got, seq)


def test_stop_mask_freezes_slot_on_eos():
    """EOS mid-chunk: the slot's valid mask must become (and stay) False
    and its last token must freeze while other slots keep decoding."""
    cfg = DENSE
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    last, cache = _prefill(cfg, params, api)
    free = _sequential(cfg, api, params, last,
                       jax.tree_util.tree_map(jnp.array, cache), N)
    eos = int(free[1, 0])          # row 0 emits this at step 1
    out = decode_steps(
        api.decode, params, last, cache, jax.random.PRNGKey(0),
        jnp.zeros((B,), bool), jnp.ones((B,), jnp.int32),
        jnp.full((B,), N + 10, jnp.int32), n=N,
        vocab_size=cfg.vocab_size, max_len=MAX_LEN, eos_id=eos)
    valid = np.asarray(out.valid)
    toks = np.asarray(out.tokens)
    stopped_at = int(np.argmax(toks[:, 0] == eos))
    assert valid[: stopped_at + 1, 0].all()
    assert not valid[stopped_at + 1:, 0].any()      # prefix semantics
    assert (toks[stopped_at:, 0] == eos).all()      # frozen last token
    assert bool(np.asarray(out.stop_mask)[0])
    if not (free[:, 1] == eos).any():               # other slot unaffected
        assert valid[:, 1].all()
        np.testing.assert_array_equal(toks[:, 1], free[:, 1])


def test_stop_mask_max_new_and_cache_full():
    cfg = DENSE
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    last, cache = _prefill(cfg, params, api)
    # per-slot budgets: slot 0 may emit 2 more tokens, slot 1 four more
    out = decode_steps(
        api.decode, params, last, cache, jax.random.PRNGKey(0),
        jnp.zeros((B,), bool), jnp.ones((B,), jnp.int32),
        jnp.asarray([3, 5], jnp.int32), n=6,
        vocab_size=cfg.vocab_size, max_len=MAX_LEN)
    valid = np.asarray(out.valid)
    assert valid[:, 0].sum() == 2 and valid[:, 1].sum() == 4
    assert np.asarray(out.stop_mask).all()
    assert np.asarray(out.gen).tolist() == [3, 5]
    # cache-full: pos starts at PROMPT, so max_len = PROMPT + 2 stops
    # both rows after exactly 2 emitted tokens regardless of max_new
    _, cache = _prefill(cfg, params, api)
    out = decode_steps(
        api.decode, params, last, cache, jax.random.PRNGKey(0),
        jnp.zeros((B,), bool), jnp.ones((B,), jnp.int32),
        jnp.full((B,), 99, jnp.int32), n=6,
        vocab_size=cfg.vocab_size, max_len=PROMPT + 2)
    assert np.asarray(out.valid).sum(0).tolist() == [2, 2]


def test_sampled_chunk_invariance():
    """Non-greedy sampling splits one key per step on device, so the token
    stream must not depend on how the steps are chunked."""
    cfg = DENSE
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    last, cache = _prefill(cfg, params, api)

    def draw(chunks):
        l, c = last, jax.tree_util.tree_map(jnp.array, cache)
        rng = jax.random.PRNGKey(42)
        stop = jnp.zeros((B,), bool)
        gen = jnp.ones((B,), jnp.int32)
        budget = jnp.full((B,), 99, jnp.int32)
        toks = []
        for n in chunks:
            out = decode_steps(api.decode, params, l, c, rng, stop, gen,
                               budget, n=n, vocab_size=cfg.vocab_size,
                               max_len=MAX_LEN, greedy=False)
            l, c, rng, stop, gen = (out.last, out.cache, out.rng,
                                    out.stop_mask, out.gen)
            toks.append(np.asarray(out.tokens))
        return np.concatenate(toks, axis=0)

    np.testing.assert_array_equal(draw([6]), draw([1] * 6))
    np.testing.assert_array_equal(draw([6]), draw([2, 3, 1]))
