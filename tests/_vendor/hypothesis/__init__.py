"""Minimal stand-in for the `hypothesis` property-testing API.

Loaded ONLY when the real hypothesis is not installed (tests/conftest.py
appends this directory to sys.path after an ImportError probe — the
container image has no hypothesis; CI installs the real pin and never
sees this shim). Implements the subset this repo's tests use:

    @given(strategy, ...) / @settings(deadline=..., max_examples=...)
    settings.register_profile / load_profile, HealthCheck
    strategies: integers, floats, booleans, sampled_from, composite, just

Each @given test runs `max_examples` times with draws from a PRNG seeded
by the test's qualified name — deterministic across runs and processes,
no shrinking, no database.
"""

from __future__ import annotations

import functools
import hashlib
import inspect
import random

from . import strategies  # noqa: F401

__version__ = "0.0.0-repro-shim"

_DEFAULTS = {"max_examples": 25, "deadline": None,
             "suppress_health_check": ()}


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    function_scoped_fixture = "function_scoped_fixture"


class settings:
    """Decorator + profile registry (class-level, like the real one)."""

    _profiles = {"default": dict(_DEFAULTS)}
    _active = dict(_DEFAULTS)

    def __init__(self, parent=None, **kwargs):
        self.kwargs = dict(parent.kwargs) if isinstance(parent, settings) \
            else {}
        self.kwargs.update(kwargs)

    def __call__(self, fn):
        merged = dict(getattr(fn, "_shim_settings", {}))
        merged.update(self.kwargs)
        fn._shim_settings = merged
        return fn

    @classmethod
    def register_profile(cls, name, parent=None, **kwargs):
        base = dict(cls._profiles.get("default", _DEFAULTS))
        if parent is not None and parent in cls._profiles:
            base.update(cls._profiles[parent])
        base.update(kwargs)
        cls._profiles[name] = base

    @classmethod
    def load_profile(cls, name):
        cls._active = dict(cls._profiles[name])


def _seed_for(fn) -> int:
    name = f"{fn.__module__}:{fn.__qualname__}".encode()
    return int.from_bytes(hashlib.blake2b(name, digest_size=8).digest(),
                          "little")


def given(*arg_strategies, **kw_strategies):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = dict(settings._active)
            conf.update(getattr(fn, "_shim_settings", {}))
            rng = random.Random(_seed_for(fn))
            for _ in range(int(conf.get("max_examples") or 25)):
                drawn = [s.example(rng) for s in arg_strategies]
                drawn_kw = {k: s.example(rng)
                            for k, s in kw_strategies.items()}
                try:
                    fn(*args, *drawn, **kwargs, **drawn_kw)
                except _UnsatisfiedAssumption:
                    continue  # assume() rejected this example; draw again

        # strategy-filled params must not look like pytest fixtures: strip
        # them (the trailing positionals + keyword names) from the
        # signature pytest introspects, and drop __wrapped__ so pytest
        # doesn't unwrap back to the original
        del wrapper.__wrapped__
        params = list(inspect.signature(fn).parameters.values())
        n_args = len(arg_strategies)
        keep = params[:len(params) - n_args] if n_args else params
        keep = [p for p in keep if p.name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(keep)
        # parity with the real attribute (pytest plugins introspect
        # fn.hypothesis.inner_test)
        wrapper.hypothesis = type("_Hypothesis", (),
                                  {"inner_test": staticmethod(fn)})()
        return wrapper

    return decorate


def assume(condition) -> bool:
    """A failed assume skips the current example: the given() loop above
    catches _UnsatisfiedAssumption and moves to the next draw."""
    if not condition:
        raise _UnsatisfiedAssumption()
    return True


class _UnsatisfiedAssumption(Exception):
    pass
