"""Strategy subset for the hypothesis shim (see __init__.py)."""

from __future__ import annotations

import math
from typing import Any, Callable, Sequence


class SearchStrategy:
    def __init__(self, draw_fn: Callable[[Any], Any]):
        self._draw = draw_fn

    def example(self, rng) -> Any:
        return self._draw(rng)

    def map(self, f: Callable) -> "SearchStrategy":
        return SearchStrategy(lambda rng: f(self._draw(rng)))

    def filter(self, pred: Callable, max_tries: int = 100
               ) -> "SearchStrategy":
        def draw(rng):
            for _ in range(max_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return SearchStrategy(draw)


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
    # bias toward the magnitude spread (log-uniform) when the range is
    # positive and wide — matches how the tests use this (scale factors)
    if min_value > 0 and max_value / min_value > 100:
        lo, hi = math.log(min_value), math.log(max_value)
        return SearchStrategy(lambda rng: math.exp(rng.uniform(lo, hi)))
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def sampled_from(options: Sequence) -> SearchStrategy:
    opts = list(options)
    return SearchStrategy(lambda rng: opts[rng.randrange(len(opts))])


def lists(elements: SearchStrategy, min_size: int = 0, max_size: int = 10
          ) -> SearchStrategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]
    return SearchStrategy(draw)


def composite(f: Callable) -> Callable[..., SearchStrategy]:
    def builder(*args, **kwargs) -> SearchStrategy:
        def draw_fn(rng):
            def draw(strategy: SearchStrategy):
                return strategy.example(rng)
            return f(draw, *args, **kwargs)
        return SearchStrategy(draw_fn)
    return builder
