"""MoE dispatch: sort-based capacity dispatch vs the dense oracle, expert
padding exactness, capacity-drop semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import moe as M


def _cfg(**kw):
    base = dict(name="m", family="moe", n_layers=1, d_model=32, n_heads=4,
                n_kv_heads=4, d_ff=48, vocab_size=128, head_dim=8,
                n_experts=6, top_k=2, expert_pad_to=8, capacity_factor=8.0,
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def _params_and_x(cfg, seed=0, t=32):
    p = M.init_moe(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, t, cfg.d_model))
    return p, x


def test_dispatch_matches_dense_oracle():
    cfg = _cfg()
    p, x = _params_and_x(cfg)
    y1 = M.moe_ffn(p, x, cfg)
    y2 = M.moe_ffn_dense_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


def test_shared_and_dense_residual_paths():
    for kw in (dict(n_shared_experts=2), dict(moe_dense_residual=True)):
        cfg = _cfg(**kw)
        p, x = _params_and_x(cfg, seed=2)
        y1 = M.moe_ffn(p, x, cfg)
        y2 = M.moe_ffn_dense_oracle(p, x, cfg)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-5)


def test_padded_experts_never_selected():
    cfg = _cfg()
    p, x = _params_and_x(cfg, seed=3)
    x2 = x.reshape(-1, cfg.d_model)
    _, experts = M._route(p, x2, cfg)
    assert int(jnp.max(experts)) < cfg.n_experts  # dummies masked to -inf


def test_capacity_drop_reduces_output_not_crashes():
    """With a tiny capacity factor, overflow tokens drop (output differs
    from the oracle only by dropped contributions — norm can only shrink)."""
    cfg = _cfg(capacity_factor=0.1)
    p, x = _params_and_x(cfg, seed=4, t=64)
    y_drop = M.moe_ffn(p, x, cfg)
    cfg_full = _cfg(capacity_factor=16.0)
    y_full = M.moe_ffn(p, x, cfg_full)
    assert bool(jnp.all(jnp.isfinite(y_drop)))
    assert float(jnp.linalg.norm(y_drop)) <= \
        float(jnp.linalg.norm(y_full)) + 1e-3


def test_router_weights_normalized():
    cfg = _cfg()
    p, x = _params_and_x(cfg, seed=5)
    w, e = M._route(p, x.reshape(-1, cfg.d_model), cfg)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)


def test_grad_flows_through_dispatch():
    cfg = _cfg()
    p, x = _params_and_x(cfg, seed=6)

    def loss(p):
        return jnp.sum(M.moe_ffn(p, x, cfg) ** 2)

    g = jax.grad(loss)(p)
    gnorm = sum(float(jnp.abs(a).sum())
                for a in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
