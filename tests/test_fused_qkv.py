"""Fused projections: qconcat exactness (fp/int8/packed-int4, every
granularity), the fused-vs-unfused kernel path (ref + Pallas interpret),
and model-level fuse_params equivalence for all four families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.axllm_linear import concat_weights, deploy_quantize
from repro.core.quantization import (QuantConfig, dequantize, qconcat,
                                     quantize)
from repro.kernels import ops
from repro.models.model import get_model, make_batch


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# qconcat
# ---------------------------------------------------------------------------

QCFGS = [
    QuantConfig(8, "affine", "per_channel"),
    QuantConfig(8, "affine", "per_tensor"),
    QuantConfig(8, "affine", "per_group", group_size=64),
    QuantConfig(8, "codebook", "per_channel"),
    QuantConfig(4, "affine", "per_channel", pack=True),
    QuantConfig(4, "codebook", "per_channel", pack=True),
    QuantConfig(4, "affine", "per_channel", pack=False),
]


@pytest.mark.parametrize("qcfg", QCFGS,
                         ids=lambda c: f"{c.bits}b-{c.mode}-{c.granularity}"
                         f"{'-packed' if c.pack and c.bits == 4 else ''}")
def test_qconcat_dequant_exact(qcfg):
    """dequantize(qconcat(a, b, c)) == concat(dequantize each) exactly:
    scales travel with their columns, no requantization happens."""
    rng = np.random.default_rng(0)
    k = 128
    parts = [quantize(_rand(rng, (k, n)), qcfg) for n in (64, 32, 32)]
    fused = qconcat(parts)
    assert fused.shape == (k, 128)
    want = jnp.concatenate([dequantize(p) for p in parts], axis=-1)
    np.testing.assert_array_equal(np.asarray(dequantize(fused)),
                                  np.asarray(want))


def test_qconcat_per_tensor_becomes_per_channel():
    rng = np.random.default_rng(1)
    qcfg = QuantConfig(8, "affine", "per_tensor")
    a = quantize(_rand(rng, (64, 32)), qcfg)
    b = quantize(_rand(rng, (64, 16)) * 5.0, qcfg)   # different scale
    fused = qconcat([a, b])
    assert fused.granularity == "per_channel"
    want = jnp.concatenate([dequantize(a), dequantize(b)], axis=-1)
    np.testing.assert_array_equal(np.asarray(dequantize(fused)),
                                  np.asarray(want))


def test_qconcat_stacked_leading_dims():
    """Stacked-layer weights ([L, K, N], the scan layout) concat exactly."""
    rng = np.random.default_rng(2)
    qcfg = QuantConfig(8, "affine", "per_channel")
    a = quantize(_rand(rng, (3, 64, 32)), qcfg)
    b = quantize(_rand(rng, (3, 64, 16)), qcfg)
    fused = qconcat([a, b])
    assert fused.shape == (3, 64, 48)
    want = jnp.concatenate([dequantize(a), dequantize(b)], axis=-1)
    np.testing.assert_array_equal(np.asarray(dequantize(fused)),
                                  np.asarray(want))


def test_qconcat_rejects_mismatches():
    rng = np.random.default_rng(3)
    a8 = quantize(_rand(rng, (64, 32)),
                  QuantConfig(8, "affine", "per_channel"))
    a4 = quantize(_rand(rng, (64, 32)),
                  QuantConfig(4, "affine", "per_channel"))
    ag = quantize(_rand(rng, (64, 32)),
                  QuantConfig(8, "affine", "per_group", group_size=32))
    ak = quantize(_rand(rng, (128, 32)),
                  QuantConfig(8, "affine", "per_channel"))
    with pytest.raises(ValueError, match="mismatch"):
        qconcat([a8, a4])
    with pytest.raises(ValueError, match="per_group"):
        qconcat([a8, ag])
    with pytest.raises(ValueError, match="K/leading"):
        qconcat([a8, ak])
    with pytest.raises(TypeError, match="quantize first"):
        concat_weights([a8, _rand(rng, (64, 32))])


def test_concat_weights_mixed_error_branch():
    """concat_weights on a QTensor/dense mix raises TypeError in either
    order (fuse after deploy_quantize, never across the boundary); the
    all-dense path still concatenates plain arrays."""
    rng = np.random.default_rng(4)
    dense_a, dense_b = _rand(rng, (64, 32)), _rand(rng, (64, 16))
    qt = quantize(dense_a, QuantConfig(8, "affine", "per_channel"))
    for mix in ([qt, dense_b], [dense_b, qt], [dense_a, qt, dense_b]):
        with pytest.raises(TypeError, match="mix of QTensor and dense"):
            concat_weights(mix)
    fused = concat_weights([dense_a, dense_b])
    assert fused.shape == (64, 48)
    np.testing.assert_array_equal(
        np.asarray(fused), np.asarray(jnp.concatenate([dense_a, dense_b],
                                                      axis=-1)))


# ---------------------------------------------------------------------------
# Fused matmul: one [K, N1+N2+N3] launch == three separate launches
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
@pytest.mark.parametrize("qcfg", [
    QuantConfig(8, "affine", "per_channel"),
    QuantConfig(4, "affine", "per_channel", pack=True),
], ids=["int8", "int4-packed"])
def test_fused_matmul_matches_separate(impl, qcfg):
    rng = np.random.default_rng(4)
    k = 256
    x = _rand(rng, (8, k))
    parts = [quantize(_rand(rng, (k, n)), qcfg) for n in (128, 64, 64)]
    fused = qconcat(parts)
    ys = [ops.axllm_matmul(x, p, impl=impl) for p in parts]
    y_fused = ops.axllm_matmul(x, fused, impl=impl)
    np.testing.assert_allclose(np.asarray(y_fused),
                               np.asarray(jnp.concatenate(ys, -1)),
                               rtol=2e-5, atol=2e-4)


def test_fused_dense_matmul_matches_separate():
    rng = np.random.default_rng(5)
    x = _rand(rng, (8, 64))
    ws = [_rand(rng, (64, n)) for n in (32, 16, 16)]
    y_fused = jnp.dot(x, concat_weights(ws))
    want = jnp.concatenate([jnp.dot(x, w) for w in ws], -1)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Model-level: fuse_params preserves outputs per family
# ---------------------------------------------------------------------------

from tests.test_decode_steps import FAMILIES  # noqa: E402  (shared configs)


@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("quantized", [False, True],
                         ids=["fp", "axllm-int8"])
def test_fuse_params_forward_equivalence(family, quantized):
    cfg = FAMILIES[family]
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    if quantized:
        params = deploy_quantize(params, QuantConfig(
            bits=8, mode="affine", granularity="per_channel"))
    fused = api.fuse_params(params)
    batch = make_batch(cfg, 0, 2, 8)
    y0 = api.forward(params, batch)
    y1 = api.forward(fused, batch)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y0, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_fuse_params_moe_shared_experts():
    """MoE: attention + shared-expert MLP fuse; routed experts keep their
    einsum layout untouched."""
    cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      head_dim=16, vocab_pad_multiple=64, n_experts=8,
                      top_k=2, n_shared_experts=1, expert_pad_to=8,
                      capacity_factor=8.0, dtype="float32", remat=False)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    fused = api.fuse_params(params)
    ffn = fused["layers"]["ffn"]
    assert "gate_up" in ffn["shared"] and "expert_gate" in ffn
    batch = make_batch(cfg, 0, 2, 8)
    np.testing.assert_allclose(np.asarray(api.forward(fused, batch)),
                               np.asarray(api.forward(params, batch)),
                               rtol=2e-4, atol=2e-4)


def test_fuse_params_qkv_bias_and_qk_norm():
    """qwen2-style qkv_bias and chameleon-style qk_norm ride through the
    fused projection."""
    import dataclasses
    cfg = dataclasses.replace(FAMILIES["dense"], qkv_bias=True,
                              qk_norm=True)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    # give the biases non-zero values so the test has teeth
    params = jax.tree_util.tree_map(
        lambda a: a + 0.1 if a.ndim == 1 else a, params)
    fused = api.fuse_params(params)
    attn = jax.tree_util.tree_map(lambda a: a[0], fused["layers"]["attn"])
    assert "wqkv" in attn and "wqkv_bias" in attn and "wq" not in attn
    batch = make_batch(cfg, 0, 2, 8)
    np.testing.assert_allclose(np.asarray(api.forward(fused, batch)),
                               np.asarray(api.forward(params, batch)),
                               rtol=2e-4, atol=2e-4)


def test_fused_engine_decode_matches_unfused():
    """End-to-end: a fused+quantized+chunked engine serves the same tokens
    as the unfused per-token engine."""
    from repro.serve.engine import ServeEngine
    cfg = FAMILIES["dense"]
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    prompts = [np.arange(8), np.arange(12) + 3, np.arange(31) + 7]
    ref = ServeEngine(cfg, params, n_slots=2, max_len=64, quantize=True,
                      decode_chunk=1).generate(prompts, max_new=6)
    got = ServeEngine(cfg, params, n_slots=2, max_len=64, quantize=True,
                      decode_chunk=8, fuse_qkv=True).generate(
                          prompts, max_new=6)
    assert got == ref
