"""Reuse-rate analytics invariants (paper §III.b / Fig. 8)."""

import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.core import reuse as R


@st.composite
def code_matrices(draw):
    n = draw(st.integers(1, 32))
    m = draw(st.integers(1, 512))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.default_rng(seed)
    return rng.integers(-127, 128, size=(n, m)).astype(np.int32)


@given(code_matrices(), st.sampled_from([None, 64, 256]))
@settings(deadline=None, max_examples=30)
def test_rate_in_unit_interval(codes, seg):
    r = R.reuse_rate(codes, seg)
    assert 0.0 <= r < 1.0
    # rate == 1 - unique/total exactly
    uniq = R.segment_unique_counts(codes, seg).sum()
    assert abs(r - (1 - uniq / codes.size)) < 1e-12


@given(code_matrices())
@settings(deadline=None, max_examples=20)
def test_bigger_buffer_no_worse(codes):
    """Unbounded buffers reuse at least as much as segmented ones."""
    assert R.reuse_rate(codes, None) >= R.reuse_rate(codes, 64) - 1e-12


def test_constant_matrix_max_reuse():
    codes = np.full((4, 256), 7)
    assert R.reuse_rate(codes, None) == 1 - 4 / codes.size


def test_all_distinct_no_reuse():
    codes = np.arange(128)[None, :]  # 128 distinct cells
    assert R.reuse_rate(codes, None) == 0.0


def test_sign_folding_halves_cells():
    codes = np.concatenate([np.arange(1, 65), -np.arange(1, 65)])[None, :]
    assert R.reuse_rate(codes, None, fold_sign=True) == 0.5
    assert R.reuse_rate(codes, None, fold_sign=False) == 0.0


def test_reuse_grows_with_row_length():
    """Paper: 'the reuse rate grows with matrix size'."""
    rng = np.random.default_rng(0)
    rates = []
    for m in (256, 1024, 4096):
        w = rng.standard_normal((64, m)).astype(np.float32)
        scale = np.abs(w).max(axis=0) / 127
        codes = np.round(w / scale).astype(np.int32)
        rates.append(R.reuse_rate(codes, None))
    assert rates[0] < rates[1] < rates[2]


def test_expected_unique_matches_empirical():
    rng = np.random.default_rng(1)
    seg = 256
    w = rng.standard_normal((512, seg)).astype(np.float32)
    scale = np.abs(w).max() / 127  # per-tensor: matches the gaussian model
    codes = np.clip(np.round(w / scale), -127, 127).astype(np.int32)
    emp = R.segment_unique_counts(codes, seg).mean()
    ana = R.expected_unique(seg, 128, "gaussian")
    assert abs(emp - ana) / ana < 0.15  # analytic within 15%


def test_lora_row_overlap_high_for_matched_dist():
    """Paper §V: ~90% of A's row values already occur in the W row."""
    rng = np.random.default_rng(2)
    w = rng.standard_normal((64, 768)).astype(np.float32)
    a = rng.standard_normal((64, 16)).astype(np.float32)
    wc = np.round(w / (np.abs(w).max() / 127)).astype(np.int32)
    ac = np.round(a / (np.abs(a).max() / 127)).astype(np.int32)
    ov = R.lora_row_overlap(wc, ac)
    assert ov > 0.8


def test_lora_overlap_bounds():
    wc = np.zeros((4, 8), np.int32)
    ac = np.zeros((4, 2), np.int32)
    assert R.lora_row_overlap(wc, ac) == 1.0
    ac2 = np.full((4, 2), 99, np.int32)
    assert R.lora_row_overlap(wc, ac2) == 0.0


@given(code_matrices(), st.sampled_from([32, 64, 256]))
@settings(deadline=None, max_examples=20)
def test_histogram_mass_conservation(codes, seg):
    """Per-segment histograms over RC cells partition the segment: total
    mass equals codes.size, and unique counts are the nonzero bins."""
    c = R.fold_codes(codes)
    n, m = c.shape
    n_seg = -(-m // seg)
    uniq = R.segment_unique_counts(codes, seg)
    total = 0
    for s in range(n_seg):
        block = c[:, s * seg:(s + 1) * seg]
        for row in range(n):
            hist = np.bincount(block[row], minlength=256)
            assert hist.sum() == block.shape[1]
            assert (hist > 0).sum() == uniq[row, s]
            total += hist.sum()
    assert total == codes.size
