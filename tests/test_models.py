"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config and runs forward + one train step on CPU, asserting
output shapes and finiteness; serving paths are cross-checked against the
full forward (cache correctness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, REGISTRY
from repro.models.model import get_model, make_batch
from repro.optim import adamw
from repro.train.loop import make_train_step


def _reduced(name):
    return REGISTRY[name].reduced(dtype="float32", remat=False)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = _reduced(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 0, 2, 16)
    logits = api.forward(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    ocfg = adamw.AdamWConfig(lr=1e-3)
    opt = adamw.init(params, ocfg)
    step = jax.jit(make_train_step(api, ocfg, total_steps=10, warmup=2))
    # step index 1: inside warmup the LR is step/warmup, so index 0 is a
    # deliberate no-op — parameters must move from index 1 on
    p2, o2, metrics = step(params, opt, batch, 1)
    assert bool(jnp.isfinite(metrics["loss"]))
    # parameters actually moved
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_prefill_matches_forward(arch):
    cfg = _reduced(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg, 1, 2, 12)
    logits = api.forward(params, batch)
    cache = api.init_cache(2, 24)
    lp, cache = api.prefill(params, batch, cache)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(logits[:, -1]),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode_matches_forward(arch):
    cfg = _reduced(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(2))
    batch = make_batch(cfg, 2, 2, 8)
    cache = api.init_cache(2, 24)
    lp, cache = api.prefill(params, batch, cache)
    nxt = jnp.argmax(lp[:, : cfg.vocab_size], -1).astype(jnp.int32)
    ld, cache = api.decode(params, nxt, cache)
    tokens = jnp.concatenate([batch["tokens"], nxt[:, None]], 1)
    ext = dict(batch, tokens=tokens)
    lf = api.forward(params, ext)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lf[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_exact_assigned_configs_are_registered():
    expected = {
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }
    for name, (nl, d, h, hk, dff, v) in expected.items():
        c = REGISTRY[name]
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (nl, d, h, hk, dff, v), name
    # arch-specific features
    assert REGISTRY["arctic-480b"].n_experts == 128
    assert REGISTRY["arctic-480b"].top_k == 2
    assert REGISTRY["arctic-480b"].moe_dense_residual
    assert REGISTRY["qwen2-moe-a2.7b"].n_experts == 60
    assert REGISTRY["qwen2-moe-a2.7b"].top_k == 4
    assert REGISTRY["qwen2-moe-a2.7b"].n_shared_experts == 4
    assert REGISTRY["zamba2-1.2b"].ssm_state == 64
    assert REGISTRY["chameleon-34b"].qk_norm
    assert REGISTRY["qwen2-72b"].qkv_bias
    assert REGISTRY["whisper-small"].is_encoder_decoder


def test_long_context_flags():
    for name in ASSIGNED:
        cfg = REGISTRY[name]
        if name in ("xlstm-1.3b", "zamba2-1.2b"):
            assert cfg.supports_long_context
        else:
            assert not cfg.supports_long_context
