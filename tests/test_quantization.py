"""Quantization substrate: round-trip bounds, packing, codebooks, tree
conversion (hypothesis property tests + exact checks)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import quantization as Q

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")


@st.composite
def weight_matrices(draw, max_dim=64):
    n = draw(st.integers(2, max_dim))
    m = draw(st.integers(2, max_dim)) * 2  # even for int4 packing
    seed = draw(st.integers(0, 2 ** 31 - 1))
    scale = draw(st.floats(1e-3, 1e3))
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((n, m)) * scale).astype(np.float32)


@given(weight_matrices())
def test_affine_roundtrip_bound(w):
    """|deq(q(w)) - w| <= scale/2 elementwise (half-step rounding error)."""
    cfg = Q.QuantConfig(bits=8, mode="affine", granularity="per_channel")
    qt = Q.quantize(w, cfg)
    deq = np.asarray(Q.dequantize(qt))
    step = np.asarray(qt.scale) / cfg.qmax
    assert np.all(np.abs(deq - w) <= step / 2 + 1e-6 * np.abs(w).max())


@given(weight_matrices())
def test_codes_within_range(w):
    for bits in (8, 4):
        cfg = Q.QuantConfig(bits=bits, mode="affine",
                            granularity="per_channel", pack=False)
        qt = Q.quantize(w, cfg)
        codes = np.asarray(Q.decode_codes(qt))
        assert codes.max() <= cfg.qmax and codes.min() >= -cfg.qmax


@given(weight_matrices())
def test_quantize_idempotent(w):
    """Quantizing an already-quantized weight is exact (fixed point)."""
    cfg = Q.QuantConfig(bits=8, mode="affine", granularity="per_channel")
    deq1 = Q.dequantize(Q.quantize(w, cfg))
    deq2 = Q.dequantize(Q.quantize(np.asarray(deq1), cfg))
    np.testing.assert_allclose(np.asarray(deq1), np.asarray(deq2),
                               rtol=1e-5, atol=1e-7)


def test_int4_pack_roundtrip():
    rng = np.random.default_rng(0)
    codes = rng.integers(-8, 8, size=(16, 32)).astype(np.int8)
    packed = Q.pack_int4(jnp.asarray(codes))
    assert packed.shape == (16, 16) and packed.dtype == jnp.uint8
    un = np.asarray(Q.unpack_int4(packed, 32))
    np.testing.assert_array_equal(un, codes)


def test_nf4_codebook_properties():
    cb = np.asarray(Q.nf4_codebook())
    assert cb.shape == (16,)
    assert np.all(np.isfinite(cb))
    assert np.max(np.abs(cb)) == pytest.approx(1.0)
    assert 0.0 in cb  # exact zero level
    assert np.all(np.diff(cb) > 0)  # sorted, distinct


def test_per_group_scales_shape():
    w = np.random.default_rng(1).standard_normal((256, 32)).astype(np.float32)
    cfg = Q.QuantConfig(bits=8, granularity="per_group", group_size=64)
    qt = Q.quantize(w, cfg)
    assert qt.scale.shape == (4, 1, 32)
    deq = np.asarray(Q.dequantize(qt))
    assert np.abs(deq - w).max() <= np.abs(w).max() / 127 + 1e-6


def test_stacked_layers_get_per_layer_scales():
    """Regression: [L, in, out] stacks must NOT share scales across L
    (broke lax.scan leading-dim consistency)."""
    w = np.random.default_rng(2).standard_normal((3, 16, 8)).astype(np.float32)
    qt = Q.quantize(w, Q.QuantConfig(8, "affine", "per_channel"))
    assert qt.scale.shape == (3, 1, 8)
    qt_t = Q.quantize(w, Q.QuantConfig(8, "affine", "per_tensor"))
    assert qt_t.scale.shape == (3, 1, 1)


def test_quantize_tree_predicate():
    params = {
        "layers": {
            "ln1": {"scale": jnp.ones((4,))},
            "attn": {"wq": jnp.ones((4, 4)), "wq_bias": jnp.zeros((4,))},
            "ffn": {"gate": jnp.ones((4, 8)), "conv_w": jnp.ones((4, 4))},
            "router": jnp.ones((4, 2)),
        },
        "embed": {"embedding": jnp.ones((10, 4))},
    }
    out = Q.quantize_tree(params, Q.QuantConfig())
    assert isinstance(out["layers"]["attn"]["wq"], Q.QTensor)
    assert isinstance(out["layers"]["ffn"]["gate"], Q.QTensor)
    assert not isinstance(out["layers"]["ln1"]["scale"], Q.QTensor)
    assert not isinstance(out["layers"]["ffn"]["conv_w"], Q.QTensor)
    assert not isinstance(out["layers"]["router"], Q.QTensor)
    assert not isinstance(out["embed"]["embedding"], Q.QTensor)
    assert Q.tree_reuse_surface(out) == 4 * 4 + 4 * 8


def test_qtensor_pytree_roundtrip():
    w = np.random.default_rng(3).standard_normal((8, 8)).astype(np.float32)
    qt = Q.quantize(w, Q.QuantConfig(4, "codebook", "per_channel"))
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(np.asarray(Q.dequantize(qt)),
                                  np.asarray(Q.dequantize(qt2)))


# ---------------------------------------------------------------------------
# Per-group round-trip + code-histogram invariants (reuse-cache contract)
# ---------------------------------------------------------------------------

@st.composite
def group_weight_matrices(draw):
    """[in, out] with the in dim a multiple of the group size."""
    g = draw(st.sampled_from([32, 64]))
    n_groups = draw(st.integers(1, 6))
    m = draw(st.integers(2, 48))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    scale = draw(st.floats(1e-3, 1e3))
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal((g * n_groups, m)) * scale).astype(np.float32)
    return w, g


@given(group_weight_matrices())
def test_per_group_roundtrip_bound(wg):
    """|deq(q(w)) - w| <= group_scale/(2*qmax) elementwise: each group's
    rounding error is half its own quantization step."""
    w, g = wg
    cfg = Q.QuantConfig(bits=8, mode="affine", granularity="per_group",
                        group_size=g)
    qt = Q.quantize(w, cfg)
    deq = np.asarray(Q.dequantize(qt))
    n_in, n_out = w.shape
    # scale [G, 1, out] -> per-element step [in, out]
    step = np.repeat(np.asarray(qt.scale)[:, 0, :], g, axis=0) / cfg.qmax
    assert np.all(np.abs(deq - w) <= step / 2 + 1e-6 * np.abs(w).max())


@given(group_weight_matrices(), st.sampled_from([8, 4]))
def test_segment_code_histograms_sum_to_segment_length(wg, bits):
    """Within every (row, segment) block, the per-cell code histogram must
    sum to the segment length — every element lands in exactly one RC cell.
    This is the invariant core/reuse.py's unique-counting (and therefore
    the Result Cache hit accounting) is built on."""
    w, g = wg
    cfg = Q.QuantConfig(bits=bits, mode="affine", granularity="per_group",
                        group_size=g, pack=False)
    from repro.core.reuse import fold_codes
    codes = np.asarray(Q.decode_codes(Q.quantize(w, cfg))).T  # rows stream
    cells = fold_codes(codes)                                  # |code| fold
    n, m = cells.shape
    for seg in (64, 256, m):
        n_seg = -(-m // seg)
        for s in range(n_seg):
            block = cells[:, s * seg:(s + 1) * seg]
            hist = np.apply_along_axis(
                lambda r: np.bincount(r, minlength=256), 1, block)
            assert hist.shape == (n, 256)
            np.testing.assert_array_equal(hist.sum(axis=1), block.shape[1])
        # and the unique counts derived from those histograms match reuse.py
        from repro.core.reuse import segment_unique_counts
        uniq = segment_unique_counts(codes, seg)
        assert uniq.shape == (n, n_seg)
        assert np.all(uniq >= 1) and np.all(uniq <= min(seg, 256))
