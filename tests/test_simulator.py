"""AxLLM cycle-model validation against the paper's published numbers
(§V), plus structural invariants and the exact-event-model cross-check."""

import numpy as np
import pytest

from repro.core import reuse as R
from repro.core import simulator as S
from repro.core.energy import power_report
from repro.core.shiftadd import (ShiftAddConfig, compare_vs_axllm,
                                 reconstruction_error, binarize, reconstruct,
                                 shiftadd_matmul)


@pytest.fixture(scope="module")
def distilbert_report():
    return S.simulate_model(S.PAPER_MODELS["distilbert"], S.SimConfig())


# ---------------------------------------------------------------------------
# Paper validation (the reproduction floor)
# ---------------------------------------------------------------------------

def test_distilbert_absolute_cycles(distilbert_report):
    """Paper: AxLLM 85.11M vs baseline 159.34M cycles."""
    ax = distilbert_report.cycles_axllm / 1e6
    base = distilbert_report.cycles_baseline / 1e6
    assert ax == pytest.approx(85.11, rel=0.03)
    assert base == pytest.approx(159.34, rel=0.03)


def test_distilbert_speedup(distilbert_report):
    assert distilbert_report.speedup == pytest.approx(1.87, rel=0.03)


def test_reuse_rate_bands(distilbert_report):
    """Paper Fig. 8: >=87% min with unbounded buffers; ~70% avg at 256."""
    assert distilbert_report.reuse_rate == pytest.approx(0.70, abs=0.04)
    codes = S.gaussian_codes(np.random.default_rng(0), 768, 768)
    assert R.reuse_rate(codes, None) >= 0.85
    llama = S.gaussian_codes(np.random.default_rng(0), 4096, 4096)
    assert R.reuse_rate(llama, None) >= 0.95  # grows with size


def test_speedups_converge_across_models():
    """Paper: 'all models use the same buffer size, the reuse rate, and
    hence the speedup, converge to similar values' (~1.7x average)."""
    sps = []
    for name in ("distilbert", "bert-base", "bert-large"):
        rep = S.simulate_model(S.PAPER_MODELS[name], S.SimConfig())
        sps.append(rep.speedup)
    assert max(sps) - min(sps) < 0.15
    assert all(1.6 <= s <= 2.0 for s in sps)


def test_power_reduction_matches_paper(distilbert_report):
    """Paper §V: 0.94 W -> 0.67 W (28% power reduction)."""
    pr = power_report(distilbert_report)
    assert pr["power_baseline_w"] == pytest.approx(0.94, abs=1e-6)
    assert pr["power_reduction"] == pytest.approx(0.287, abs=0.035)


def test_shiftadd_comparison_matches_paper():
    """Paper §V: AxLLM 29% faster than ShiftAddLLM on DistilBERT."""
    r = compare_vs_axllm(S.PAPER_MODELS["distilbert"])
    assert r["axllm_over_shiftadd"] == pytest.approx(1.29, abs=0.05)


def test_lora_adapter_speedup_and_overlap():
    """Paper §V: ~90% A-row overlap; adapter speedup ~1.8x."""
    rng = np.random.default_rng(0)
    w = S.gaussian_codes(rng, 768, 768)
    a = S.gaussian_codes(rng, 768, 16)
    out = S.simulate_lora(w, a, S.SimConfig())
    assert out["row_overlap"] > 0.85
    assert out["adapter_speedup"] == pytest.approx(1.8, abs=0.4)


def test_hazard_rate_small():
    """Paper §IV: RAW-hazard likelihood ~2% (we measure the raw windowed
    rate; head-of-line damping makes effective stalls lower)."""
    rng = np.random.default_rng(0)
    codes = S.gaussian_codes(rng, 256, 768)
    rep = S.simulate_matrix(codes, S.SimConfig(), measure_hazards=True)
    assert rep.hazard_rate < 0.08


# ---------------------------------------------------------------------------
# Structural invariants
# ---------------------------------------------------------------------------

def test_axllm_never_slower_than_baseline():
    rng = np.random.default_rng(1)
    for m in (64, 256, 1024):
        codes = S.gaussian_codes(rng, 64, m)
        rep = S.simulate_matrix(codes, S.SimConfig())
        assert rep.cycles_axllm <= rep.cycles_baseline
        assert rep.mults + rep.rc_hits == rep.total_ops


def test_cycles_lower_bounded_by_uniques():
    rng = np.random.default_rng(2)
    codes = S.gaussian_codes(rng, 64, 256)
    cfg = S.SimConfig()
    rep = S.simulate_matrix(codes, cfg)
    # per segment, wall time >= max unique count across lanes
    uniq = R.segment_unique_counts(codes, cfg.buf)
    assert rep.cycles_axllm >= uniq.max()


def test_exact_event_model_brackets_analytic():
    """The queue-level event model must fall between the balls-in-bins
    lower-throughput model and the ideal max-load bound for realistic
    segments (and match the §IV degenerate case)."""
    rng = np.random.default_rng(3)
    cfg = S.SimConfig()
    codes = S.gaussian_codes(rng, 64, 256)
    cells = R.fold_codes(codes, True)
    for row in cells[:8]:
        u = len(set(row.tolist()))
        hits = len(row) - u
        exact = S.simulate_segment_exact(row, cfg)
        lo = max(len(row) / cfg.slices, u)              # ideal overlap
        hi = len(row) + cfg.drain + u * cfg.mult_latency  # full serial
        assert lo <= exact <= hi


def test_degenerate_single_value_reverts_to_serial():
    """Paper §IV worst case: all fetches target one RC slice -> non-parallel
    throughput."""
    cfg = S.SimConfig()
    cells = np.full(256, 5, dtype=np.int64)
    exact = S.simulate_segment_exact(cells, cfg)
    assert exact >= 250  # ~1/cycle, no slice parallelism


def test_calibration_stability():
    """The single calibrated constant reproduces the paper's absolute
    number; guard against accidental drift."""
    cfg = S.SimConfig()
    assert cfg.collision_efficiency == pytest.approx(0.86)
    assert cfg.hit_throughput == pytest.approx(3.44)
    assert cfg.hit_throughput_ballsbins == pytest.approx(2.734, abs=0.01)


# ---------------------------------------------------------------------------
# ShiftAdd numeric baseline
# ---------------------------------------------------------------------------

def test_shiftadd_reconstruction_converges_with_bits():
    rng = np.random.default_rng(4)
    w = rng.standard_normal((64, 64)).astype(np.float32)
    errs = [reconstruction_error(w, q) for q in (2, 4, 8)]
    assert errs[0] > errs[1] > errs[2]


def test_shiftadd_matmul_matches_reconstruction():
    rng = np.random.default_rng(5)
    w = rng.standard_normal((32, 16))
    x = rng.standard_normal((4, 32))
    alphas, bits = binarize(w, 8)
    y1 = shiftadd_matmul(x, alphas, bits)
    y2 = x @ reconstruct(alphas, bits)
    np.testing.assert_allclose(y1, y2, rtol=1e-10)


def test_axllm_exactness_advantage():
    """AxLLM is exact w.r.t. the int8 model; ShiftAdd approximates."""
    rng = np.random.default_rng(6)
    w = rng.standard_normal((128, 128)).astype(np.float32)
    sa_err = reconstruction_error(w, 8)
    scale = np.abs(w).max(axis=0) / 127
    int8_err = np.linalg.norm(w - np.round(w / scale) * scale) \
        / np.linalg.norm(w)
    assert int8_err < sa_err / 3
