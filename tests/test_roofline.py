"""Roofline analysis unit tests: HLO collective parsing, trip-count
extrapolation, analytic MODEL_FLOPS sanity."""

import pytest

from repro.configs import get_config
from repro.roofline import analysis as ra

HLO_SAMPLE = """
  %ag = bf16[16,512]{1,0} all-gather(bf16[1,512]{1,0} %p), replica_groups=...
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), to_apply=%add
  %rs.1 = f32[64,32]{1,0} reduce-scatter(f32[512,32]{1,0} %y), dimensions={0}
  %cp = u8[128]{0} collective-permute(u8[128]{0} %z), source_target_pairs=...
  %a2a = bf16[8,8,64]{2,1,0} all-to-all(bf16[8,8,64]{2,1,0} %w), dimensions={0}
  %ag2 = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-gather-start(f32[2,4] %q, f32[2,4] %r)
  %not_a_collective = f32[10]{0} add(f32[10]{0} %a, f32[10]{0} %b)
"""


def test_parse_collectives_kinds_and_bytes():
    coll = ra.parse_collectives(HLO_SAMPLE)
    assert coll["all-gather"]["count"] == 2
    # 16*512*2 bytes + tuple (4*4*4)*2
    assert coll["all-gather"]["bytes"] == 16 * 512 * 2 + 2 * 4 * 4 * 4
    # all-reduce doubled (RS+AG ring phases)
    assert coll["all-reduce"]["bytes"] == 2 * 1024 * 4
    assert coll["reduce-scatter"]["bytes"] == 64 * 32 * 4
    assert coll["collective-permute"]["bytes"] == 128
    assert coll["all-to-all"]["bytes"] == 8 * 8 * 64 * 2
    assert "add" not in coll


def test_total_collective_bytes():
    coll = ra.parse_collectives(HLO_SAMPLE)
    assert ra.total_collective_bytes(coll) == sum(
        v["bytes"] for v in coll.values())


def test_extrapolate_linear():
    # base=10, delta=5 -> n=48: 10-5 + 48*5? no: cost1=15, cost2=20
    assert ra.extrapolate(15.0, 20.0, 48) == pytest.approx(10 + 48 * 5)
    # 1-group == full model when n_groups == 1
    assert ra.extrapolate(7.0, 9.0, 1) == pytest.approx(7.0)


def test_roofline_terms_dominance():
    t = ra.roofline_terms(197e12 * 256, 1e9, 1e9, 256)   # 1s compute
    assert t["dominant"] == "compute"
    assert t["compute_s"] == pytest.approx(1.0)
    t = ra.roofline_terms(1e12, 819e9 * 256 * 2, 1e9, 256)
    assert t["dominant"] == "memory"
    assert t["memory_s"] == pytest.approx(2.0)
    t = ra.roofline_terms(1e12, 1e9, 50e9 * 256 * 3, 256)
    assert t["dominant"] == "collective"
    assert t["collective_s"] == pytest.approx(3.0)


def test_model_flops_scaling():
    cfg = get_config("granite-3-8b")
    f_train = ra.model_flops(cfg, "train", 4096, 256)
    f_prefill = ra.model_flops(cfg, "prefill", 4096, 256)
    # train = fwd + 2x bwd
    assert f_train == pytest.approx(3 * f_prefill)
    # decode is ~tokens-fraction of prefill compute
    f_dec = ra.model_flops(cfg, "decode", 4096, 256)
    assert f_dec < f_prefill / 1000
    # dense: 6ND dominates; check order of magnitude
    n = cfg.n_params()
    assert f_train > 6 * n * 4096 * 256
    assert f_train < 10 * n * 4096 * 256


def test_moe_uses_active_params():
    cfg = get_config("arctic-480b")
    f = ra.model_flops(cfg, "prefill", 1024, 1)
    n_active = cfg.n_active_params()
    assert f < 2 * cfg.n_params() * 1024 * 0.2   # far below dense-equivalent
    assert f > 2 * n_active * 1024               # at least active matmuls


def test_shape_bytes_parsing():
    assert ra._shape_bytes("bf16[16,512]{1,0}") == 16 * 512 * 2
    assert ra._shape_bytes("(f32[2,2], s8[4])") == 16 + 4
    assert ra._shape_bytes("u4[100]") == 50
    assert ra._shape_bytes("pred[8]") == 8


def test_useful_bytes_floor_sane():
    cfg = get_config("qwen2-72b")
    # decode: KV cache dominates at 32k x batch 128 (bf16)
    b = ra.useful_hbm_bytes(cfg, "decode", 32768, 128,
                            weight_bytes_per_param=1.0)
    kv = 128 * 2 * 80 * 32768 * 8 * 128 * 2
    assert b > kv and b < kv * 1.5
    # int8 KV halves the floor's cache share
    b8 = ra.useful_hbm_bytes(cfg, "decode", 32768, 128,
                             weight_bytes_per_param=1.0, kv_bytes=1.0)
    assert b8 < b * 0.6
    # ssm decode floor is tiny (state, not KV)
    x = get_config("xlstm-1.3b")
    bx = ra.useful_hbm_bytes(x, "decode", 524288, 1)
    assert bx < 3 * x.n_params()  # weights dominate, no 500k cache
