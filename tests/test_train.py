"""Training substrate: optimizer semantics, grad accumulation equivalence,
checkpoint round-trips (sync + async), LR schedule."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data.pipeline import make_dataset
from repro.models.model import get_model, make_batch
from repro.optim import adamw
from repro.train import checkpoint as C
from repro.train.loop import make_train_step

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, vocab_pad_multiple=64, dtype="float32")


@pytest.fixture(scope="module")
def setup():
    api = get_model(CFG)
    params = api.init(jax.random.PRNGKey(0))
    return api, params


def _run(api, params, ocfg, steps=25, accum=1):
    import dataclasses
    cfg = dataclasses.replace(CFG, grad_accum=accum)
    api2 = get_model(cfg)
    opt = adamw.init(params, ocfg)
    fn = jax.jit(make_train_step(api2, ocfg, total_steps=100, warmup=5))
    ds = make_dataset(cfg, batch=8, seq=32, seed=0)
    p, o = params, opt
    losses = []
    for s in range(steps):
        b = jax.tree_util.tree_map(jnp.asarray, ds.batch_at(s))
        p, o, m = fn(p, o, b, s)
        losses.append(float(m["loss"]))
    return p, losses


def test_loss_decreases(setup):
    api, params = setup
    _, losses = _run(api, params, adamw.AdamWConfig(lr=1e-3))
    assert losses[-1] < losses[0] - 0.3


def test_int8_moments_track_f32(setup):
    """Blockwise-int8 Adam moments stay close to the f32 trajectory."""
    api, params = setup
    _, l32 = _run(api, params, adamw.AdamWConfig(lr=1e-3))
    _, l8 = _run(api, params, adamw.AdamWConfig(lr=1e-3, int8_moments=True))
    assert l8[-1] < l8[0] - 0.3
    assert abs(l8[-1] - l32[-1]) < 0.3


def test_grad_accum_matches_full_batch(setup):
    """accum=2 over the same global batch = one full-batch step (mean CE is
    linear in microbatch means here since microbatches are equal-sized)."""
    api, params = setup
    ocfg = adamw.AdamWConfig(lr=1e-3)
    p1, l1 = _run(api, params, ocfg, steps=3, accum=1)
    p2, l2 = _run(api, params, ocfg, steps=3, accum=2)
    np.testing.assert_allclose(l1[-1], l2[-1], rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


def test_gradient_clipping_shrinks_update(setup):
    """Adam normalizes gradient scale, but with clip << eps-scale the
    epsilon dominates sqrt(v) and the clipped step must be strictly
    smaller; the reported grad_norm must be the pre-clip norm."""
    api, params = setup
    b = make_batch(CFG, 0, 8, 32)

    def delta(clip):
        ocfg = adamw.AdamWConfig(lr=1e-3, clip_norm=clip, weight_decay=0.0)
        opt = adamw.init(params, ocfg)
        fn = jax.jit(make_train_step(api, ocfg, total_steps=100, warmup=1))
        p2, _, m = fn(params, opt, b, 5)
        d = sum(float(jnp.sum(jnp.abs(a - c)))
                for a, c in zip(jax.tree_util.tree_leaves(params),
                                jax.tree_util.tree_leaves(p2)))
        return d, float(m["grad_norm"])

    d_clip, gn1 = delta(1e-12)
    d_free, gn2 = delta(1e9)
    assert d_clip < d_free * 0.5
    assert gn1 == pytest.approx(gn2, rel=1e-5)  # norm reported pre-clip


def test_warmup_cosine_schedule():
    s = adamw.warmup_cosine(jnp.asarray(0), 10, 100)
    assert float(s) == 0.0
    s = adamw.warmup_cosine(jnp.asarray(10), 10, 100)
    assert float(s) == pytest.approx(1.0)
    s_end = adamw.warmup_cosine(jnp.asarray(100), 10, 100)
    assert float(s_end) == pytest.approx(0.1, abs=1e-6)


def test_checkpoint_roundtrip(setup):
    api, params = setup
    ocfg = adamw.AdamWConfig(int8_moments=True)
    opt = adamw.init(params, ocfg)
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 7, (params, opt), extra={"cfg": "t"})
        (p2, o2), step = C.restore(d, (params, opt))
        assert step == 7
        for a, b in zip(jax.tree_util.tree_leaves((params, opt)),
                        jax.tree_util.tree_leaves((p2, o2))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(setup):
    api, params = setup
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            C.save(d, s, params, keep=2)
        steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(steps) == 2
        assert C.latest_step(d) == 5


def test_async_saver(setup):
    api, params = setup
    saver = C.AsyncSaver()
    with tempfile.TemporaryDirectory() as d:
        saver.save(d, 3, params)
        saver.wait()
        p2, step = C.restore(d, params)
        assert step == 3


def test_quantized_params_checkpoint_roundtrip(setup):
    """QTensor leaves survive save/restore (serve-side checkpoints)."""
    from repro.core.axllm_linear import deploy_quantize
    from repro.core.quantization import QuantConfig, dequantize, QTensor
    api, params = setup
    qp = deploy_quantize(params, QuantConfig())
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 1, qp)
        qp2, _ = C.restore(d, qp)
    leaves1 = jax.tree_util.tree_leaves(qp, is_leaf=lambda x: isinstance(x, QTensor))
    leaves2 = jax.tree_util.tree_leaves(qp2, is_leaf=lambda x: isinstance(x, QTensor))
    for a, b in zip(leaves1, leaves2):
        if isinstance(a, QTensor):
            np.testing.assert_array_equal(np.asarray(a.codes),
                                          np.asarray(b.codes))
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
