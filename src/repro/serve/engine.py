"""Continuous-batching serving engine (the AxLLM deployment surface).

`ServeEngine(..., quantize=True)` converts trained params post-training
(zero setup, paper §I) to int8 codes; every linear then runs the fused
dequant-matmul path. The scheduler keeps `n_slots` request slots full:

Scheduler contract
------------------
- **Admission (prefill waves).** Every `step()` first admits queued
  requests into free slots. Attention-family models (`api.ragged_prefill`)
  take mixed-length prompts in ONE right-padded batch: causal masking
  keeps real tokens from seeing the pads, logits are gathered at each
  row's last real position, and the per-row cache cursor is set to the
  true length (pad KV beyond the cursor is dead and overwritten by
  decode). Recurrent families (ssm/hybrid) fold every position into
  state, so the wave is split into equal-length sub-batches — slots still
  fill in the same step.
- **Cache layout.** Slot insertion is driven by `api.cache_spec`, a
  pytree (same treedef as the cache) giving the batch axis of every leaf.
  This replaces shape-guessing (`shape[i] == n_slots`), which silently
  corrupted the cache whenever `n_slots` collided with a stacked-layer /
  head dim (e.g. xLSTM superblocks).
- **Hot loops.** Prefill is jitted and bucketed by `(wave_size,
  padded_len)`. Ragged families round both up to powers of two, so a
  steady mixed stream hits a handful of compiles
  (`stats.prefill_compiles`); recurrent families bucket wave size only —
  padded_len is the exact group length, i.e. one compile per distinct
  prompt length. Decode is one jitted chunked-scan dispatch over all
  slots with the cache buffer donated (see "Chunked decode" below).
- **Chunked decode (the hot loop's hot loop).** `step()` dispatches ONE
  on-device `lax.scan` of up to `decode_chunk` decode steps
  (`repro.serve.decode.decode_steps`): sampling, PRNG splitting and the
  per-slot stop masks all run on device, and the cache is donated into
  the scan carry. The host syncs once per chunk ([n, B] tokens + validity
  mask) instead of once per token. The chunk length is clamped to the
  largest per-slot remaining budget (and to `run()`'s step budget), so a
  wave that needs 3 tokens never pays for 8. `decode_chunk=1` reproduces
  the per-token scheduler exactly (same tokens, same stats); larger
  chunks trade admission latency (slots freed mid-chunk only refill at
  the chunk boundary) for dispatch amortization.
- **Fused projections.** `fuse_qkv=True` (engine arg or `cfg.fuse_qkv`)
  rewrites the deployed params through `api.fuse_params` after
  quantization: wq/wk/wv concatenate into one `[d, (H+2Hk)·hd]` wqkv
  QTensor (`qconcat` — exact, scales travel with their columns), gate/up
  into gate_up, so every attention/MLP block makes one pass over its
  activations with one codebook residency.
- **Stop conditions.** Per-slot: EOS token (`eos_id`, engine arg or
  `cfg.eos_id`), `max_new` tokens, or cache-full (`prompt + generated`
  reaching `max_len` — flagged `truncated`). The same three conditions
  are evaluated on device inside the chunk (the mask freezes finished
  rows) and re-derived on the host at harvest; finished slots free at
  the chunk boundary and refill on the next step.
- **Long prompts.** `long_prompt="truncate"` keeps the last
  `max_len - 1` prompt tokens (flagging `prompt_truncated`);
  `"reject"` raises at `submit()`. Nothing silently overflows the cache.
- **Multi-LoRA serving (the paper's dual-pipeline claim).** Built with
  `adapters=AdapterRegistry`, the engine serves mixed batches of the
  frozen base model and up to `max_loras` registered LoRA fine-tunes in
  the same waves and decode chunks: `submit(..., adapter="name")` pins a
  registered adapter, a per-slot `[B]` adapter-index array (−1 = base)
  threads through every prefill wave and the chunked decode scan, and
  each attention block adds the gathered low-rank bf16 delta on top of
  the untouched (quantized, fused included) base matmul — no parameter
  rewrites, no per-adapter engine. The stacked A/B tensors are jit
  *arguments*, so hot `add`/`evict` between waves reuses every compile.
  Recurrent families reject registries at engine init.
- **Paged KV cache + prefix reuse (`paged=True`).** Attention families
  can swap the dense per-slot `[n_slots, max_len]` cache for a shared
  block pool `[n_layers, num_blocks, kv_block_size]` with per-slot block
  tables (`repro.serve.paged_cache.PagedKVCache` owns the free list,
  refcounts and radix prefix index; `repro.models.attention` owns the
  device layout). `submit()` prompts are matched against the radix index
  at admission: the longest cached *full-block* prefix is taken by
  reference (refcount++) and prefill runs only on the un-cached suffix —
  rows position-offset by their hit, one joint softmax over
  [gathered prefix ‖ suffix] (`ops.prefix_attention`). Decode reads KV
  through the block table in the paged flash-decode kernel and writes to
  uniquely owned blocks (copy-on-write resolves sharing at chunk
  boundaries, batched into one device copy — a defensive invariant:
  current flows keep written blocks unshared by construction, so
  `cow_copies` stays 0 until a sharing mode like forked sampling lands).
  Finished requests publish
  their full blocks back into the index; when the pool runs dry, LRU
  index-only blocks are evicted. This extends the paper's
  computation-reuse principle from weight products to whole KV rows:
  shared system prompts / few-shot templates prefill once, not per
  request. Paged decode is token-identical to the dense path
  (tests/test_paged.py). Recurrent families reject `paged=True`.
- **Overload robustness (admission control + preemption).** The wait
  queue is a bounded priority queue (`repro.serve.scheduler.WaitQueue`):
  `submit(..., priority=, deadline_s=)` applies the engine's admission
  policy when it is full (`"block"` backpressure / `"reject"` load
  shedding / `"evict"` priority shedding — shed requests finish with
  `finish_reason="rejected"`, nothing raises), and requests whose queue
  wait exceeds their deadline expire (`"expired"`). When the block pool
  runs dry mid-flight, or a strictly-higher-priority request is waiting,
  the engine *preempts* the lowest-priority running slot instead of
  failing: full KV blocks are published into the radix index and the
  partial tail block is buffered on host (`SwapState`), the slot's
  blocks are released, and the request re-enters the queue keeping its
  original rid. Restore is a fast path (uncapped index `lookup` + tail
  scatter into a fresh block, straight back to decode) when every full
  block survived, else a recompute through the normal prefill path on
  `prompt ++ tokens` — both resume bit-identically to an uninterrupted
  decode. Admission itself is atomic (`PagedKVCache.admit`,
  plan-then-commit) and each decode window's block budget is reserved
  before any pool mutation (`plan_decode`/`can_allocate`), so no
  exception can leave blocks half-allocated; an engine-level
  `fault_hook` (see `repro.serve.chaos`) fires right before each jitted
  prefill/decode dispatch, and any exception there rolls admission back,
  requeues the wave (adapter pins intact) and leaves the decode step
  idempotently retryable.
- **Chunked prefill (`prefill_budget=N`, paged only).** Bounded step
  time, Sarathi-style: every `step()` spends at most N prompt tokens on
  prefill work (first chunks and continuations combined), so a long
  prompt is consumed over several steps *interleaved with decode chunks*
  instead of stalling every running stream behind one all-or-nothing
  wave. A partially prefilled slot carries a block-aligned
  `prefill_cursor` (non-final chunks are floored to whole KV blocks),
  allocates blocks per chunk (`PagedKVCache.extend`, all-or-nothing) and
  publishes each consumed chunk into the radix index immediately; its
  block-table row is masked to the trash block for decode dispatches
  (the scan writes KV unconditionally for every row). Mid-prefill
  preemption publishes the consumed prefix and re-admits through the
  normal prefill path, where the radix match re-hits it —
  token-identical, no swap state. Budgeted waves draw their shape from
  a small fixed lattice — pow2 width buckets up to `n_slots`, pow2
  length buckets capped by the budget — so compile count is bounded and
  independent of arrival pattern without padding single-request chunks
  to the full slot set. Greedy output is bit-identical to unbudgeted
  serving.
- **Streaming + cancellation.** `submit(on_token=...)` fires the
  callback per token at chunk harvest (prefill first-token, decode
  chunk, speculative round); `stream()` wraps submit + step into a
  generator. `cancel(rid)` — or the callback raising `StopStream` —
  tears a request down mid-stream: slot, KV blocks, and adapter pin
  released, published prefix blocks kept for other requests,
  `finish_reason="cancelled"` with the partial tokens retained.
  `t_first` is stamped at actual first-token *emission* (the TTFT base).
- **Execution deadlines.** Beyond `deadline_s` (queue wait),
  `submit(ttft_deadline_s=, itl_deadline_s=)` bound time-to-first-token
  and the inter-token gap *while running*: a request that blows either
  finishes with `finish_reason="expired"`, keeps its partial tokens,
  and frees every resource — checked each step against the injectable
  clock, wherever the request sits (queued, mid-prefill, or decoding).
- **Speculative decoding (`speculate=True`).** The quantization ladder
  doubles as a draft model: `core.quantization.derive_draft_params`
  re-quantizes the raw weights to `draft_bits` (affine/codebook, or the
  shift-add reparameterization via `draft_mode="shiftadd"`) once at
  init, and each round the draft proposes up to `spec_k` greedy tokens
  from its own private dense cache, the serving-precision target
  verifies all of them in ONE teacher-forced chunked-scan dispatch
  (`repro.serve.decode.verify_steps`), and the engine emits the longest
  agreeing prefix plus the target's correction token
  (`repro.serve.speculative`). Output is bit-identical to target-only
  greedy by construction — acceptance only moves throughput. Rollback
  of optimistically written KV is a host cursor reset (dense) or
  `PagedKVCache.truncate` (paged, whole trailing blocks back to the
  pool, published prefixes untouched). Requires `greedy=True` and an
  attention family; preempted speculating slots restore by recompute
  (the fast swap path would miss the draft cache).
- **Stats.** `engine.stats` tracks admitted/finished/truncated requests,
  decode steps/tokens, prefill waves/tokens/compiles (plus wall time),
  LoRA-carrying requests, mean slot occupancy, — in paged mode —
  `prefix_hit_tokens` / `blocks_in_use` / `cow_copies`, and — under
  speculation — drafted/accepted token counts with `acceptance_rate`
  and `accepted_tokens_per_step` (emitted per slot-round, > 1 means
  drafting beats one-token-per-step);
  `stats.as_dict()` feeds `benchmarks/serve_bench.py`.

`generate()` returns token lists for all submitted prompts; requests
still in flight when `max_steps` runs out come back with their partial
tokens and `truncated=True` (`return_requests=True` exposes the flags).
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.axllm_linear import deploy_quantize
from repro.core.quantization import QuantConfig, derive_draft_params
from repro.dist import sharding as shd
from repro.models.model import ModelAPI, get_model
from repro.serve.adapters import AdapterRegistry
from repro.serve.decode import decode_steps, verify_steps
from repro.serve.paged_cache import TRASH_BLOCK, PagedKVCache
from repro.serve.scheduler import WaitQueue, pick_victim, prefill_chunk
from repro.serve.speculative import accept_length, round_k


class StopStream(Exception):
    """Raise from an ``on_token`` callback to cancel the stream.

    The engine catches it at the emission site and tears the request
    down exactly like :meth:`ServeEngine.cancel`: slot freed, KV blocks
    released (published prefixes survive in the radix index), adapter
    pin dropped, ``finish_reason="cancelled"``. Tokens appended before
    the raise stay on the request.
    """


@dataclasses.dataclass
class SwapState:
    """Host-side remainder of a preempted slot's KV (paged mode).

    Full blocks are published into the radix index at swap-out (base
    requests), so the swap state only carries what the index cannot:
    the partial tail block's KV rows, copied to host. A restore first
    tries the fast path (uncapped index lookup + tail scatter back into
    a fresh block — no recompute); if any full block was LRU-evicted
    meanwhile it falls back to recomputing the whole KV through the
    normal prefill path, which is what dense mode and LoRA requests
    (whose adapter-specific KV is never indexed) always do.
    """
    seq_len: int                      # KV positions covered at swap-out
    full_blocks: int                  # seq_len // block_size
    tail: Optional[dict] = None      # pool-leaf name -> host [L, bs, ...]


@dataclasses.dataclass
class Request:
    """One serving request: prompt in, generated ``tokens`` out.

    adapter: name of a registered LoRA adapter to decode with (None =
    base model). The engine acquires the adapter at ``submit`` and
    releases it when the request finishes, so a named adapter cannot be
    evicted out from under an in-flight request.
    """
    rid: int
    prompt: np.ndarray            # [S] int32 (post long-prompt policy)
    max_new: int = 32
    tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False           # generation cut short (cache/steps)
    prompt_truncated: bool = False    # prompt clipped by long_prompt policy
    adapter: Optional[str] = None     # LoRA adapter name (None = base)
    priority: int = 0                 # larger = admitted first, may preempt
    deadline_s: Optional[float] = None    # max queue wait before expiry
    finish_reason: Optional[str] = None   # eos / max_new / cache_full /
                                          # rejected / expired / cancelled
    t_submit: float = 0.0             # engine-clock submit time
    t_first: Optional[float] = None   # first-token *emission* time (TTFT)
    t_last: Optional[float] = None    # last-token time (ITL base)
    preemptions: int = 0              # times swapped out of a slot
    _swap: Optional[SwapState] = None     # host tail KV while preempted
    # streaming: per-token callback fired at chunk harvest; raising
    # StopStream from it cancels the request mid-stream
    on_token: Optional[object] = None
    # execution deadlines (beyond deadline_s's queue-wait bound)
    ttft_deadline_s: Optional[float] = None   # submit -> first emission
    itl_deadline_s: Optional[float] = None    # max gap between tokens
    # chunked prefill: a seated slot may hold only a prefix of its prompt
    prefilling: bool = False          # seated but prompt not fully consumed
    prefill_cursor: int = 0           # admission-seq tokens consumed so far
    _emitted: int = 0                 # tokens already streamed to on_token
    _admitted: bool = False           # counted in stats.admitted (vs restore)


@dataclasses.dataclass
class EngineStats:
    admitted: int = 0
    finished: int = 0
    truncated: int = 0
    steps: int = 0                    # device decode steps executed
    decode_tokens: int = 0            # valid tokens harvested
    decode_chunks: int = 0            # host round-trips (dispatches)
    prefill_waves: int = 0
    prefill_tokens: int = 0
    prefill_compiles: int = 0
    prefill_wall_s: float = 0.0       # host wall time inside prefill waves
    lora_requests: int = 0            # admitted requests carrying an adapter
    occupancy_sum: float = 0.0        # sum over steps of active/n_slots
    # paged-KV mode (prefix reuse): prompt tokens whose KV came from the
    # radix index instead of being recomputed, live pool blocks, and
    # copy-on-write block copies performed before decode chunks
    prefix_hit_tokens: int = 0
    blocks_in_use: int = 0
    cow_copies: int = 0
    # robustness: admission-control and preemption outcomes
    rejected: int = 0                 # shed by the admission policy
    expired: int = 0                  # deadline passed (queued or mid-run)
    preempted: int = 0                # swap-outs of running slots
    restored: int = 0                 # re-admissions after preemption
    fast_restores: int = 0            # restores that skipped recompute
    # streaming + chunked prefill
    cancelled: int = 0                # torn down by cancel()/StopStream
    prefill_chunks: int = 0           # budgeted prefill chunks executed
    preempted_prefill: int = 0        # preemptions of mid-prefill slots
    # speculative decoding (speculate=True): draft/verify round outcomes
    spec_rounds: int = 0              # engine-level draft+verify rounds
    spec_slot_rounds: int = 0         # sum over rounds of speculating slots
    drafted_tokens: int = 0           # draft proposals checked by the target
    accepted_draft_tokens: int = 0    # proposals the target agreed with
    spec_emitted_tokens: int = 0      # tokens appended by spec rounds

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / self.steps if self.steps else 0.0

    @property
    def tokens_per_step(self) -> float:
        return self.decode_tokens / self.steps if self.steps else 0.0

    @property
    def acceptance_rate(self) -> float:
        """Fraction of draft proposals the target confirmed."""
        return (self.accepted_draft_tokens / self.drafted_tokens
                if self.drafted_tokens else 0.0)

    @property
    def accepted_tokens_per_step(self) -> float:
        """Tokens emitted per slot-round (one draft+verify round of one
        slot). Always >= 1 when rounds ran — each round emits at least
        the target's own token — and > 1 iff speculation accepted
        anything, which is the serve-bench gate for the feature paying
        for itself."""
        return (self.spec_emitted_tokens / self.spec_slot_rounds
                if self.spec_slot_rounds else 0.0)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["mean_occupancy"] = self.mean_occupancy
        d["tokens_per_step"] = self.tokens_per_step
        d["acceptance_rate"] = self.acceptance_rate
        d["accepted_tokens_per_step"] = self.accepted_tokens_per_step
        return d


def _sample_tokens(logits, rng, *, greedy: bool, vocab_size: int):
    """On-device sampling: greedy/sampled is jit-static, and the sampled
    path threads a freshly split PRNG key per call instead of re-seeding
    from host state. Returns (tokens [B] int32, advanced key)."""
    if logits.ndim == 3:              # [B, S, V]: sample the last position
        logits = logits[:, -1, :]
    logits = logits[..., :vocab_size]
    if greedy:
        return jnp.argmax(logits, -1).astype(jnp.int32), rng
    rng, k = jax.random.split(rng)
    return jax.random.categorical(k, logits).astype(jnp.int32), rng


def _pow2_bucket(n: int, lo: int, hi: int) -> int:
    """Smallest power of two >= n, floored at lo, capped at hi.

    >>> _pow2_bucket(5, 1, 16)
    8
    >>> _pow2_bucket(3, 8, 64)      # floored at lo
    8
    >>> _pow2_bucket(100, 8, 64)    # capped at hi
    64
    """
    b = lo
    while b < n:
        b *= 2
    return min(b, hi)


class ServeEngine:
    """Continuous-batching scheduler over ``n_slots`` request slots.

    Construction deploys ``params`` for serving: ``quantize=True``
    converts weight matrices to ``quant_bits`` AxLLM codes
    (`deploy_quantize`; ``quant_bits=None`` falls back to
    ``cfg.quant_bits``, ``quant_mode`` picks affine vs codebook
    alphabets), ``fuse_qkv`` rewrites them through
    ``api.fuse_params`` (wqkv / gate_up), and ``adapters`` attaches an
    :class:`~repro.serve.adapters.AdapterRegistry` for multi-LoRA
    serving (attention families only). ``decode_chunk`` sets the
    on-device scan length per decode dispatch; ``eos_id`` /
    ``long_prompt`` / ``max_len`` define the stop conditions (see the
    module docstring for the full scheduler contract).

    ``paged=True`` swaps the dense per-slot cache for the block-paged
    pool with radix-tree prefix reuse: ``kv_block_size`` tokens per
    block (power of two), ``num_blocks`` pool blocks (default
    ``2 * n_slots * ceil(max_len / kv_block_size) + 2`` — a full dense
    equivalent per slot, the trash block, a copy-on-write spare, and as
    much again for retained prefixes), ``prefix_cache=False`` keeps the
    paging but disables the radix index.

    ``mesh`` (a `jax.sharding.Mesh`, e.g. from
    :func:`repro.launch.mesh.make_host_mesh`) turns on tensor-parallel
    serving: quantized params are placed column-parallel (wqkv/gate_up)
    / row-parallel (wo/down) over the mesh's "model" axis, the KV cache
    (dense or paged pool) shards along kv-heads when they divide the
    axis — otherwise along the sequence dim, which routes decode through
    the fused shard_map kernel ``decode_attention_seqsharded`` — and
    every prefill/decode dispatch traces under the mesh context so GSPMD
    partitions the whole hot path. A mesh of total size 1 compiles to
    exactly the single-device program. Tokens are identical to unmeshed
    serving across quantize/reuse/fused/LoRA/paged modes
    (tests/test_sharded_serve.py).

    Serve with ``submit(prompt, max_new, adapter=...)`` + ``step()`` /
    ``run()``, or the one-shot ``generate(prompts, ...)``.
    """

    def __init__(self, cfg, params, *, n_slots: int = 4, max_len: int = 512,
                 quantize: bool = False, quant_bits: Optional[int] = None,
                 quant_mode: str = "affine",
                 impl: str = "auto", greedy: bool = True, seed: int = 0,
                 eos_id: Optional[int] = None,
                 long_prompt: str = "truncate",
                 decode_chunk: Optional[int] = None,
                 fuse_qkv: Optional[bool] = None,
                 adapters: Optional[AdapterRegistry] = None,
                 paged: bool = False, kv_block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 prefix_cache: bool = True,
                 mesh=None,
                 max_queue: Optional[int] = None,
                 admission: str = "block",
                 clock=None,
                 fault_hook=None,
                 speculate: bool = False, spec_k: int = 4,
                 draft_bits: int = 4, draft_mode: str = "affine",
                 prefill_budget: Optional[int] = None):
        if cfg.is_encoder_decoder:
            raise NotImplementedError(
                "ServeEngine drives token-only prefill; encoder-decoder "
                "serving needs a frames ingress (future PR)")
        if long_prompt not in ("truncate", "reject"):
            raise ValueError(f"long_prompt must be 'truncate' or 'reject', "
                             f"got {long_prompt!r}")
        if max_len < 2:
            raise ValueError("max_len must be >= 2 (prompt + 1 decode step)")
        self.cfg = cfg
        self.api: ModelAPI = get_model(cfg, impl=impl)
        raw_params = params               # pre-quantization, for the draft
        if quantize:
            bits = cfg.quant_bits if quant_bits is None else quant_bits
            params = deploy_quantize(
                params, QuantConfig(bits=bits, mode=quant_mode,
                                    granularity="per_channel"))
        fuse = cfg.fuse_qkv if fuse_qkv is None else fuse_qkv
        if fuse:
            if self.api.fuse_params is None:
                raise ValueError(f"family {cfg.family!r} has no fused-"
                                 f"projection rewrite")
            params = self.api.fuse_params(params)
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.greedy = greedy
        self.eos_id = eos_id if eos_id is not None else cfg.eos_id
        self.long_prompt = long_prompt
        dc = cfg.decode_chunk if decode_chunk is None else decode_chunk
        if dc < 1:
            raise ValueError(f"decode_chunk must be >= 1, got {dc}")
        self.decode_chunk = dc
        self.registry = adapters
        if adapters is not None:
            self._validate_adapters(adapters)
        # per-slot LoRA row into registry.stacked; -1 = base-only. Threaded
        # through every prefill wave and decode chunk as a [B] jit argument.
        self.adapter_slots = np.full((n_slots,), -1, np.int32)
        self.rng = jax.random.PRNGKey(seed)
        self.paged = paged
        self.kv_block_size = kv_block_size
        self.prefix_cache = prefix_cache
        self.prefill_budget = prefill_budget
        if prefill_budget is not None:
            if not paged:
                raise ValueError(
                    "prefill_budget requires paged=True: chunked prefill "
                    "allocates and publishes KV one block at a time, which "
                    "the dense per-slot cache cannot express")
            if speculate:
                raise ValueError(
                    "prefill_budget is incompatible with speculate=True: "
                    "the draft cache is dense and prefills whole sequences "
                    "in one wave, so a mid-prefill slot would enter a "
                    "speculative round with no draft KV behind its cursor — "
                    "serve chunked prefill without speculation (or "
                    "speculation without a budget)")
            if prefill_budget < kv_block_size:
                raise ValueError(
                    f"prefill_budget={prefill_budget} is below one KV block "
                    f"(kv_block_size={kv_block_size}): a non-final chunk is "
                    "floored to whole blocks, so no chunk could ever make "
                    "progress")
        # per-step chunked-prefill ledger (reset at the top of _step)
        self._prefill_left = prefill_budget
        self._prefill_progress = False
        if paged:
            if self.api.init_paged_cache is None:
                raise ValueError(
                    f"family {cfg.family!r} has no paged KV cache path: "
                    "recurrent/enc-dec state folding exposes no "
                    "per-position KV to page — serve it with paged=False "
                    "(attention families only)")
            self.max_blocks = math.ceil(max_len / kv_block_size)
            self.num_blocks = num_blocks if num_blocks is not None \
                else 2 * n_slots * self.max_blocks + 2
            self.pager = PagedKVCache(
                n_slots=n_slots, n_blocks=self.num_blocks,
                block_size=kv_block_size,
                max_blocks_per_slot=self.max_blocks,
                prefix_cache=prefix_cache)
            self.cache = self.api.init_paged_cache(
                n_slots, self.num_blocks, kv_block_size, self.max_blocks)
            self._pool_leaves = [
                k for k, ax in self.api.paged_cache_spec.items() if ax == 1]
            self._copier = jax.jit(self._copy_blocks, donate_argnums=(0,))
        else:
            self.pager = None
            self.cache = self.api.init_cache(n_slots, max_len)
        self._validate_cache_spec()
        self.speculate = speculate
        self.spec_k = spec_k
        self.draft_bits = draft_bits
        self.draft_mode = draft_mode
        self.draft_params = None
        self.draft_cache = None
        if speculate:
            if not greedy:
                raise ValueError(
                    "speculate=True requires greedy=True: the accept rule "
                    "compares the target's deterministic argmax against "
                    "the draft's — sampled verification needs a "
                    "rejection-sampling scheme this engine does not "
                    "implement")
            if self.api.init_paged_cache is None:
                raise ValueError(
                    f"family {cfg.family!r} has no speculative path: "
                    "rollback needs position-addressable KV (truncate a "
                    "cursor / block table); recurrent state folding "
                    "cannot rewind k rejected positions (attention "
                    "families only)")
            if spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {spec_k}")
            # the draft is derived from the ORIGINAL params: deriving it
            # from already-quantized target weights would compound two
            # quantization errors and crater the acceptance rate
            draft = derive_draft_params(raw_params, bits=draft_bits,
                                        mode=draft_mode)
            if fuse:
                draft = self.api.fuse_params(draft)
            self.draft_params = draft
            # the draft cache is ALWAYS dense, even when the target pages:
            # draft KV is private scratch (never shared, never published,
            # never swapped), so block bookkeeping would buy nothing
            self.draft_cache = self.api.init_cache(n_slots, max_len)
        self.mesh = mesh
        self._rules = None
        if mesh is not None:
            self._rules = shd.serve_rules_for(
                mesh, getattr(cfg, "n_kv_heads", 1) or 1)
            self._place_on_mesh()
        self.slots: List[Optional[Request]] = [None] * n_slots
        # bounded priority wait queue; max_queue=None + "block" reproduces
        # the pre-robustness unbounded FIFO for closed-loop callers
        self.queue = WaitQueue(max_queue, admission)
        # injectable clock (deadlines/TTFT) and fault hook (chaos harness:
        # called with "prefill"/"decode" right before each jit dispatch)
        self._clock = time.monotonic if clock is None else clock
        self.fault_hook = fault_hook
        self.finished: List[Request] = []
        self._rid = 0
        self.stats = EngineStats()
        self._chunk_fns = {}          # (n, greedy) -> jit scan-decode fn
        self._spec_fns = {}           # k -> (jit draft scan, jit verify scan)
        self._prefill_cache = {}      # (wave_bucket, padded_len) -> jit fn
        self._writer = jax.jit(self._write_wave, donate_argnums=(0,))
        self._sampler = jax.jit(_sample_tokens,
                                static_argnames=("greedy", "vocab_size"))

    def _validate_cache_spec(self):
        if self.paged:
            spec = self.api.paged_cache_spec
            # pool leaves carry the block axis (shared, no batch dim);
            # pos/block_tables stay slot-leading
            for name, ax in spec.items():
                want = self.num_blocks if ax == 1 else self.n_slots
                got = self.cache[name].shape[ax]
                if got != want:
                    raise ValueError(
                        f"paged_cache_spec says axis {ax} of {name!r} is "
                        f"the {'block' if ax == 1 else 'slot'} axis but "
                        f"shape {self.cache[name].shape} has {got} != "
                        f"{want} there")
            return
        spec = self.api.cache_spec
        if spec is None:
            raise ValueError("ModelAPI.cache_spec missing: the engine needs "
                             "the batch axis of every cache leaf")

        def check(leaf, ax):
            if leaf.shape[ax] != self.n_slots:
                raise ValueError(
                    f"cache_spec says batch axis {ax} but leaf shape "
                    f"{leaf.shape} has {leaf.shape[ax]} != n_slots="
                    f"{self.n_slots} there")
            return leaf

        jax.tree_util.tree_map(check, self.cache, spec)

    def _mesh_ctx(self):
        """Sharding context for jit trace/dispatch sites: binds the
        engine's (mesh, rules) so `shard()` constraints and the
        seq-sharded decode routing see the serving layout. No-op without
        a mesh — the single-device program is untouched."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return shd.activate(self.mesh, self._rules)

    def _place_on_mesh(self):
        """Commit params / KV cache / stacked LoRA tensors to the mesh.

        Params use `param_specs` inference (column-parallel wqkv/gate_up,
        row-parallel wo/down — one all-reduce per block under GSPMD);
        the cache uses `cache_specs` (dense: kv-heads or sequence dim per
        the rule set) or `paged_cache_specs` (pool sharded along heads
        only; the pager's block address space stays whole per shard, so
        block tables and copy-on-write copies are shard-oblivious).
        Stacked adapters place with replicated A / out-sharded B."""
        mesh, rules = self.mesh, self._rules
        pspecs = shd.param_specs(self.params, mesh, rules)
        self.params = jax.tree_util.tree_map(jax.device_put, self.params,
                                             pspecs)
        if self.paged:
            cspecs = shd.paged_cache_specs(self.cache, mesh, rules)
        else:
            cspecs = shd.cache_specs(self.cache, mesh, self.n_slots,
                                     self.max_len, rules=rules)
        self.cache = jax.tree_util.tree_map(jax.device_put, self.cache,
                                            cspecs)
        if self.registry is not None:
            self.registry.place(
                shd.adapter_specs(self.registry.stacked, mesh, rules))
        if self.speculate:
            # the draft rides the same layout rules: same param paths
            # (column/row-parallel projections) and a dense cache placed
            # exactly like a dense target cache would be
            dspecs = shd.param_specs(self.draft_params, mesh, rules)
            self.draft_params = jax.tree_util.tree_map(
                jax.device_put, self.draft_params, dspecs)
            dcspecs = shd.cache_specs(self.draft_cache, mesh, self.n_slots,
                                      self.max_len, rules=rules)
            self.draft_cache = jax.tree_util.tree_map(
                jax.device_put, self.draft_cache, dcspecs)

    def _constrain_wave(self, wave_cache, batch: int):
        """Pin a prefill wave cache (traced, inside jit) to the engine
        cache's layout, so the slot-scatter in `_write_wave` moves shards
        instead of rematerializing the wave on one device. Identity
        without a mesh."""
        if self.mesh is None:
            return wave_cache
        specs = shd.cache_specs(wave_cache, self.mesh, batch, self.max_len,
                                rules=self._rules)
        return jax.tree_util.tree_map(jax.lax.with_sharding_constraint,
                                      wave_cache, specs)

    def _copy_blocks(self, cache, src, dst):
        """Copy pool blocks ``src`` onto ``dst`` on every pool leaf — the
        device half of copy-on-write (one batched dispatch per chunk)."""
        new = dict(cache)
        for name in self._pool_leaves:
            new[name] = cache[name].at[:, dst].set(cache[name][:, src])
        return new

    def _validate_adapters(self, reg: AdapterRegistry):
        """Adapter-aware deployment validation: the family must expose the
        LoRA delta-pipeline hooks and the registry must have been built
        against a dimensionally identical config (the stacked A/B tensors
        scan with this model's layers)."""
        if not self.api.supports_lora:
            raise ValueError(
                f"family {self.cfg.family!r} has no multi-LoRA serving "
                "path: its recurrent state folding offers no per-slot "
                "projection hook for the delta pipeline (attention "
                "families only)")
        from repro.serve.adapters import target_dims
        if reg.cfg.n_layers != self.cfg.n_layers:
            raise ValueError(
                f"adapter registry built for n_layers={reg.cfg.n_layers} "
                f"but engine serves n_layers={self.cfg.n_layers}")
        for t in reg.targets:
            if target_dims(reg.cfg, t) != target_dims(self.cfg, t):
                raise ValueError(
                    f"adapter registry target {t!r} dims "
                    f"{target_dims(reg.cfg, t)} != model dims "
                    f"{target_dims(self.cfg, t)}")

    # -- request management ---------------------------------------------------
    def _now(self) -> float:
        return self._clock()

    def submit(self, prompt, max_new: int = 32,
               adapter: Optional[str] = None, priority: int = 0,
               deadline_s: Optional[float] = None,
               on_token=None,
               ttft_deadline_s: Optional[float] = None,
               itl_deadline_s: Optional[float] = None) -> int:
        """Queue a prompt ([S] ints) for generation; returns a request id.

        adapter: name of a registered LoRA adapter to serve this request
        with (requires the engine's ``adapters=AdapterRegistry``; unknown
        names raise KeyError here, not mid-stream). The adapter is pinned
        until the request finishes.

        priority: larger admits first; a strictly-higher-priority arrival
        may preempt a running lower-priority slot (swap-out/restore).
        deadline_s: max seconds the request may *wait in the queue*; past
        it the request finishes with ``finish_reason="expired"`` and no
        tokens. When the queue is at ``max_queue`` the engine's admission
        policy decides: "block" drives ``step()`` until a position frees,
        "reject" / "evict" shed a request (``finish_reason="rejected"``)
        without raising — read the outcome off the finished list/stats.

        on_token: streaming callback ``f(request, token)`` fired for each
        token as the engine harvests it (chunk boundaries, not at finish).
        Raising :class:`StopStream` from it cancels the request mid-stream
        — slot, KV blocks, and adapter pin released, tokens appended so
        far kept, ``finish_reason="cancelled"``. Any other exception
        propagates out of ``step()``.
        ttft_deadline_s / itl_deadline_s: *execution* deadlines enforced
        mid-run (``deadline_s`` only bounds queue wait): a request that
        has not emitted its first token ``ttft_deadline_s`` seconds after
        submit, or whose gap since the last harvested token exceeds
        ``itl_deadline_s``, finishes with ``finish_reason="expired"``
        keeping its partial tokens; slot and blocks are freed."""
        if adapter is not None and self.registry is None:
            raise ValueError(
                "submit(adapter=...) needs an engine built with "
                "adapters=AdapterRegistry")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        cap = self.max_len - 1            # leave >= 1 decode position
        prompt_truncated = False
        if prompt.size > cap:
            if self.long_prompt == "reject":
                raise ValueError(
                    f"prompt length {prompt.size} exceeds max_len-1={cap}; "
                    f"resubmit shorter or use long_prompt='truncate'")
            prompt = prompt[-cap:]        # keep the most recent context
            prompt_truncated = True
        if adapter is not None:
            self.registry.acquire(adapter)    # KeyError on unknown name
        req = Request(self._rid, prompt, max_new,
                      prompt_truncated=prompt_truncated, adapter=adapter,
                      priority=priority, deadline_s=deadline_s,
                      t_submit=self._now(), on_token=on_token,
                      ttft_deadline_s=ttft_deadline_s,
                      itl_deadline_s=itl_deadline_s)
        self._rid += 1
        dec = self.queue.offer(req)
        while dec.must_block:
            # backpressure: drain the engine until a queue position frees
            if not self.step():
                raise RuntimeError(
                    "admission blocked with a drained engine: the wait "
                    "queue is full but nothing in it can make progress")
            dec = self.queue.offer(req)
        if dec.evicted is not None:
            self._finish(dec.evicted, "rejected")
        if not dec.admitted:
            self._finish(req, "rejected")
        return req.rid

    def _free_slots(self):
        return [i for i, s in enumerate(self.slots) if s is None]

    # -- admission sequences ---------------------------------------------------
    def _admission_seq(self, r: Request) -> np.ndarray:
        """Tokens a (re-)admission feeds through prefill: the prompt plus
        everything generated so far. Fresh requests (no tokens yet)
        prefill just the prompt; a recompute-restored request re-enters
        with its full generated prefix, so prefill's last-position logits
        sample exactly the token uninterrupted decode would have."""
        if not r.tokens:
            return r.prompt
        return np.concatenate([r.prompt, np.asarray(r.tokens, np.int32)])

    def _kv_seq(self, r: Request) -> np.ndarray:
        """Tokens whose KV a running slot currently holds: prompt ++
        tokens[:-1] (the last sampled token's KV is written by the NEXT
        decode step). Keys the radix-index publish at finish/swap-out."""
        return np.concatenate([r.prompt, np.asarray(r.tokens[:-1],
                                                    np.int32)])

    # -- preemption (swap-out) and restore -------------------------------------
    def _preempt_slot(self, i: int):
        """Swap a running request out of slot ``i`` without losing work.

        Paged mode releases the slot's blocks *through the radix index*:
        full blocks are published keyed by the KV sequence (so a later
        fast restore — or any other request sharing the prefix — finds
        them), and the partial tail block's rows are copied to a host
        swap buffer. Dense mode just abandons the slot rows (restore
        recomputes). The request re-enters the queue with its original
        rid, i.e. ahead of its priority class."""
        r = self.slots[i]
        if self.paged and r.prefilling:
            # a mid-prefill victim's consumed prefix is whole blocks (the
            # cursor is block-aligned): publish them and drop the slot —
            # no host tail to save, no SwapState. Re-admission goes back
            # through the normal prefill path, where the radix match
            # re-hits the published prefix, so the restore is
            # token-identical without carrying any device state.
            if r.adapter is None:
                self.pager.insert(self._admission_seq(r)[:r.prefill_cursor],
                                  self.pager.slot_blocks(i))
            r.prefilling = False
            r.prefill_cursor = 0
            self.pager.release_slot(i)
            self.stats.blocks_in_use = self.pager.blocks_in_use
            self.stats.preempted_prefill += 1
        elif self.paged:
            seq = self._kv_seq(r)
            bs = self.kv_block_size
            full = len(seq) // bs
            blocks = self.pager.slot_blocks(i)
            if r.adapter is None and full:
                self.pager.insert(seq, blocks[:full])
            tail = None
            if len(seq) % bs and full < len(blocks):
                tb = blocks[full]
                tail = {name: np.asarray(self.cache[name][:, tb])
                        for name in self._pool_leaves}
            r._swap = SwapState(seq_len=len(seq), full_blocks=full,
                                tail=tail)
            self.pager.release_slot(i)
            self.stats.blocks_in_use = self.pager.blocks_in_use
        self.slots[i] = None
        self.adapter_slots[i] = -1
        r.preemptions += 1
        self.stats.preempted += 1
        self.queue.push_front(r)

    def _try_fast_restore(self, r: Request, slot: int) -> bool:
        """Re-seat a swapped-out request without recompute: every full KV
        block must still be in the radix index (uncapped ``lookup``) and
        the partial tail, if any, in the host swap buffer. On success the
        slot re-enters decode directly — no prefill dispatch. Returns
        False (recompute path) if anything was evicted meanwhile."""
        sw = r._swap
        if sw is None or not self.paged:
            return False
        if self.speculate:
            # the draft cache is not swapped out (private scratch), so a
            # fast restore would resume with stale draft KV; the recompute
            # path rebuilds target AND draft token-identically instead
            return False
        if r.adapter is not None and sw.full_blocks:
            return False               # LoRA KV is never in the index
        hit = self.pager.lookup(self._kv_seq(r)) if sw.full_blocks else []
        if len(hit) < sw.full_blocks:
            return False                # prefix (partly) evicted
        hit = hit[:sw.full_blocks]
        tail_len = sw.seq_len % self.kv_block_size
        if tail_len and sw.tail is None:
            return False
        if not self.pager.admit(slot, hit, 1 if tail_len else 0):
            return False                # pool dry even after eviction
        if tail_len:
            tb = int(self.pager.tables[slot, sw.full_blocks])
            for name in self._pool_leaves:
                self.cache[name] = self.cache[name].at[:, tb].set(
                    jnp.asarray(sw.tail[name], self.cache[name].dtype))
        r._swap = None
        self.slots[slot] = r
        self.adapter_slots[slot] = (self.registry.index_of(r.adapter)
                                    if r.adapter is not None else -1)
        self.stats.restored += 1
        self.stats.fast_restores += 1
        return True

    def _priority_preempt(self):
        """Make room for strictly-higher-priority queued requests: for
        each waiting request beyond what free slots absorb, preempt the
        lowest-priority running slot strictly below it (never an equal —
        two peers must not thrash)."""
        if not self.queue:
            return
        nfree = len(self._free_slots())
        waiting = sorted(self.queue,
                         key=lambda q: (-q.priority, q.rid))[nfree:]
        for req in waiting:
            victim = pick_victim(self.slots, below_priority=req.priority)
            if victim is None:
                break
            self._preempt_slot(victim)

    # -- execution deadlines (TTFT / inter-token) -------------------------------
    def _deadline_passed(self, r: Request, now: float) -> bool:
        if r.ttft_deadline_s is not None and r.t_first is None \
                and now - r.t_submit > r.ttft_deadline_s:
            return True
        if r.itl_deadline_s is not None and r.t_last is not None \
                and now - r.t_last > r.itl_deadline_s:
            return True
        return False

    def _expire_deadlines(self):
        """Enforce per-request TTFT and inter-token deadlines mid-run.

        ``deadline_s`` (queue-wait) is checked by ``WaitQueue.expire``;
        this sweep covers the *execution* deadlines everywhere a request
        can be: still queued (a preempted request counts), mid-prefill,
        or decoding in a slot. An expired runner keeps its partial tokens
        (``finish_reason="expired"``), publishes its reusable KV prefix
        and frees slot/blocks/pin — the books stay balanced."""
        now = self._now()
        dead = [r for r in self.queue if self._deadline_passed(r, now)]
        for r in dead:
            self.queue.remove(r)
            self._finish(r, "expired")
        for i, r in enumerate(self.slots):
            if r is not None and self._deadline_passed(r, now):
                self._teardown_slot(i)
                self._finish(r, "expired")

    def _teardown_slot(self, i: int):
        """Release slot ``i``'s resources without finishing its request:
        publish the reusable KV prefix (full blocks of the sequence the
        slot actually holds — ``_kv_seq`` for a decoding slot, the
        block-aligned prefix cursor for a mid-prefill one), release the
        slot's pool blocks, and clear the slot row. Callers own the
        ``_finish`` bookkeeping."""
        r = self.slots[i]
        if self.paged:
            if r.adapter is None:
                seq = (self._admission_seq(r)[:r.prefill_cursor]
                       if r.prefilling else self._kv_seq(r))
                self.pager.insert(seq, self.pager.slot_blocks(i))
            self.pager.release_slot(i)
            self.stats.blocks_in_use = self.pager.blocks_in_use
        self.slots[i] = None
        self.adapter_slots[i] = -1

    # -- streaming (per-token emission + cancellation) ---------------------------
    def _emit(self, r: Request, now: float) -> bool:
        """Stream tokens appended since the last harvest to ``on_token``.

        Stamps ``t_first`` at the first *actual emission* (the TTFT base
        — previously over-stated by stamping at wave granularity) and
        advances the per-request emission cursor. Returns True when the
        callback raised :class:`StopStream`: the caller must tear the
        request down as cancelled unless a stop reason already finished
        it. Any other callback exception propagates."""
        new = r.tokens[r._emitted:]
        if not new:
            return False
        if r.t_first is None:
            r.t_first = now
        if r.on_token is None:
            r._emitted = len(r.tokens)
            return False
        for t in new:
            r._emitted += 1
            try:
                r.on_token(r, int(t))
            except StopStream:
                # the client consumed exactly ``_emitted`` tokens; drop
                # the rest of this harvest so the cancelled request's
                # token list matches what was actually streamed
                del r.tokens[r._emitted:]
                return True
        return False

    def cancel(self, rid: int) -> bool:
        """Tear down a queued or in-flight request mid-stream.

        The request finishes with ``finish_reason="cancelled"`` keeping
        the tokens emitted so far; its slot, KV blocks (published full
        prefix blocks stay in the radix index for other requests), and
        adapter pin are all released. Returns True when the request was
        live and is now cancelled; False when it already finished
        (cancel lost the race — the result stands). Unknown rids raise
        KeyError."""
        for i, s in enumerate(self.slots):
            if s is not None and s.rid == rid:
                self._teardown_slot(i)
                self._finish(s, "cancelled")
                return True
        for r in list(self.queue):
            if r.rid == rid:
                self.queue.remove(r)
                self._finish(r, "cancelled")
                return True
        if any(r.rid == rid for r in self.finished):
            return False
        raise KeyError(f"request {rid} not found")

    def stream(self, prompt, max_new: int = 32, **kw):
        """Generator yielding tokens for one request as they are produced.

        Submits the prompt and drives ``step()`` internally, yielding
        each harvested token. Closing the generator early (``break``,
        ``.close()``, GC) cancels the request and releases every
        resource it held — the teardown path a disappearing client
        needs. Extra keyword arguments pass through to :meth:`submit`;
        a caller ``on_token`` is composed in front of the stream's own
        buffering (and may still raise :class:`StopStream`)."""
        buf: List[int] = []
        user_cb = kw.pop("on_token", None)

        def tap(req, tok):
            if user_cb is not None:
                user_cb(req, tok)       # StopStream propagates to the engine
            buf.append(tok)

        rid = self.submit(prompt, max_new, on_token=tap, **kw)
        try:
            while True:
                while buf:
                    yield buf.pop(0)
                if any(r.rid == rid for r in self.finished):
                    return
                if not self.step():
                    return              # drained with the request resolved
        finally:
            if not any(r.rid == rid for r in self.finished):
                self.cancel(rid)

    # -- prefill waves ---------------------------------------------------------
    def _admit(self):
        for r in self.queue.expire(self._now()):
            self._finish(r, "expired")
        self._expire_deadlines()
        self._priority_preempt()
        self._continue_prefill()
        free = self._free_slots()
        if not free or not self.queue:
            return
        take = self.queue.take(len(free))
        pending = []
        for r in take:
            if self.paged and r._swap is not None and free \
                    and self._try_fast_restore(r, free[0]):
                free.pop(0)
                continue
            pending.append(r)
        if not pending:
            return
        if self.api.ragged_prefill:
            groups = [pending]
        else:
            by_len = {}
            for r in pending:
                by_len.setdefault(len(self._admission_seq(r)), []).append(r)
            groups = list(by_len.values())
        t0 = time.perf_counter()
        gi = -1
        try:
            for gi, group in enumerate(groups):
                if self.paged:
                    self._prefill_group_paged(group, free)
                else:
                    self._prefill_group(group, free)
            jax.block_until_ready(
                self.cache["k"] if "k" in self.cache
                else jax.tree_util.tree_leaves(self.cache)[0])
        except Exception:
            # the failing group requeued itself (its prefill handler owns
            # rollback); untouched later groups must requeue here or
            # they'd be lost with their adapter pins held forever
            for group in groups[gi + 1:]:
                for r in group:
                    self.queue.push_front(r)
            raise
        finally:
            self.stats.prefill_wall_s += time.perf_counter() - t0

    def _get_prefill(self, wave_bucket: int, padded_len: int):
        """Jitted prefill for one (wave, padded_len) bucket. With an
        adapter registry the callable additionally takes the stacked A/B
        pytree and the wave's [wb] adapter-index row as jit arguments, so
        hot add/evict never invalidates the compile cache."""
        key = (wave_bucket, padded_len)
        if key not in self._prefill_cache:
            api, max_len = self.api, self.max_len
            lora = self.registry is not None
            scaling = self.registry.scaling if lora else None
            ragged = api.ragged_prefill

            def fn(params, toks, lengths, stacked=None, aidx=None):
                cache = api.init_cache(toks.shape[0], max_len)
                kw = {}
                if ragged:
                    kw["lengths"] = lengths
                if lora:
                    kw.update(adapters=stacked, adapter_idx=aidx,
                              lora_scaling=scaling)
                logits, wave_cache = api.prefill(params, {"tokens": toks},
                                                 cache, **kw)
                return logits, self._constrain_wave(wave_cache,
                                                    toks.shape[0])

            self._prefill_cache[key] = jax.jit(fn)
            self.stats.prefill_compiles += 1
        return self._prefill_cache[key]

    def _prefill_group(self, group: List[Request], free: List[int]):
        w = len(group)
        wb = _pow2_bucket(w, 1, self.n_slots)
        seqs = [self._admission_seq(r) for r in group]
        lens = [len(s) for s in seqs]
        if self.api.ragged_prefill:
            pl = _pow2_bucket(max(lens), min(8, self.max_len), self.max_len)
        else:
            pl = lens[0]                  # equal-length group, exact
        toks = np.zeros((wb, pl), np.int32)
        lengths = np.ones((wb,), np.int32)
        aidx = np.full((wb,), -1, np.int32)
        for i, (r, seq) in enumerate(zip(group, seqs)):
            toks[i, : len(seq)] = seq
            lengths[i] = len(seq)
            if r.adapter is not None:
                aidx[i] = self.registry.index_of(r.adapter)
        fn = self._get_prefill(wb, pl)
        try:
            if self.fault_hook is not None:
                self.fault_hook("prefill")
            if self.registry is not None:
                logits, wave_cache = fn(self.params, jnp.asarray(toks),
                                        jnp.asarray(lengths),
                                        self.registry.stacked,
                                        jnp.asarray(aidx))
            else:
                logits, wave_cache = fn(self.params, jnp.asarray(toks),
                                        jnp.asarray(lengths))
        except Exception:
            # nothing was mutated yet (no slot/cache writes): requeue the
            # whole group so no request — or its adapter pin — is lost
            for r in group:
                self.queue.push_front(r)
            raise
        first = self._sample(logits)
        now = self._now()
        src, dst = [], []
        for i, r in enumerate(group):
            r.tokens.append(int(first[i]))
            if not r._admitted:
                r._admitted = True
                self.stats.admitted += 1
                if r.adapter is not None:
                    self.stats.lora_requests += 1
            else:
                self.stats.restored += 1    # recompute restore
            r.t_last = now
            r._swap = None
            self.stats.prefill_tokens += int(lengths[i])
            want_cancel = self._emit(r, now)
            reason = self._stop_reason(r)
            if reason is None and want_cancel:
                reason = "cancelled"
            if reason is not None:
                self._finish(r, reason)   # EOS/max_new on the first token
                continue
            slot = free.pop(0)
            self.slots[slot] = r
            self.adapter_slots[slot] = aidx[i]
            src.append(i)
            dst.append(slot)
        if src:
            self.cache = self._writer(self.cache, wave_cache,
                                      jnp.asarray(src, jnp.int32),
                                      jnp.asarray(dst, jnp.int32))
            if self.speculate:
                # the draft prefills the same wave (its logits are unused:
                # the first token always comes from the target above), so
                # seated slots start each spec round with draft KV covering
                # exactly the target's positions. Same jitted fn — params
                # are jit arguments, the draft's structure traces once.
                if self.registry is not None:
                    _, dwave = fn(self.draft_params, jnp.asarray(toks),
                                  jnp.asarray(lengths),
                                  self.registry.stacked, jnp.asarray(aidx))
                else:
                    _, dwave = fn(self.draft_params, jnp.asarray(toks),
                                  jnp.asarray(lengths))
                self.draft_cache = self._writer(self.draft_cache, dwave,
                                                jnp.asarray(src, jnp.int32),
                                                jnp.asarray(dst, jnp.int32))
        self.stats.prefill_waves += 1

    def _write_wave(self, cache, wave_cache, src, dst):
        """Copy wave rows `src` into engine slots `dst` on each leaf's
        declared batch axis (api.cache_spec)."""
        def put(full, one, ax):
            vals = jnp.take(one, src, axis=ax)
            idx = (slice(None),) * ax + (dst,)
            return full.at[idx].set(vals.astype(full.dtype))
        return jax.tree_util.tree_map(put, cache, wave_cache,
                                      self.api.cache_spec)

    # -- paged prefill (block pool + prefix reuse) -----------------------------
    def _get_paged_prefill(self, wave_bucket: int, padded_len: int,
                           n_prefix_blocks: int):
        """Jitted paged prefill for one (wave, suffix_pad, prefix_blocks)
        bucket: gather the rows' cached prefix KV out of the pool through
        their prefix block tables, run the suffix-only prefill wave, and
        scatter the new suffix KV into the rows' freshly allocated blocks
        — one dispatch, pool donated. ``n_prefix_blocks == 0`` is the
        no-hit fast path (no gather, plain ragged prefill)."""
        key = ("paged", wave_bucket, padded_len, n_prefix_blocks)
        if key not in self._prefill_cache:
            api, bs = self.api, self.kv_block_size
            quant_kv = self.cfg.quant_kv
            pool_leaves = self._pool_leaves
            n_suffix_blocks = padded_len // bs
            lora = self.registry is not None
            scaling = self.registry.scaling if lora else None

            def fn(cache, params, toks, lengths, prefix_len, pbt, sbt,
                   stacked=None, aidx=None):
                wave = api.init_cache(toks.shape[0], padded_len)
                kw = {"lengths": lengths}
                if n_prefix_blocks:
                    def gather(name):
                        g = jnp.take(cache[name], pbt, axis=1)
                        return g.reshape(g.shape[0], g.shape[1],
                                         n_prefix_blocks * bs, *g.shape[4:])
                    prefix = {"k": gather("k"), "v": gather("v"),
                              "len": prefix_len}
                    if quant_kv:
                        prefix["k_scale"] = gather("k_scale")
                        prefix["v_scale"] = gather("v_scale")
                    kw["prefix"] = prefix
                if lora:
                    kw.update(adapters=stacked, adapter_idx=aidx,
                              lora_scaling=scaling)
                logits, wave_cache = api.prefill(params, {"tokens": toks},
                                                 wave, **kw)
                wave_cache = self._constrain_wave(wave_cache, toks.shape[0])
                new_cache = dict(cache)
                for name in pool_leaves:
                    w = wave_cache[name]          # [L, wb, pl, hk, x]
                    w = w.reshape(w.shape[0], w.shape[1], n_suffix_blocks,
                                  bs, *w.shape[3:])
                    new_cache[name] = cache[name].at[:, sbt].set(
                        w.astype(cache[name].dtype))
                return logits, new_cache

            self._prefill_cache[key] = jax.jit(fn, donate_argnums=(0,))
            self.stats.prefill_compiles += 1
        return self._prefill_cache[key]

    def _prefill_group_paged(self, group: List[Request], free: List[int]):
        """Admit one wave through the paged pool: match each sequence's
        longest cached full-block prefix in the radix index, allocate
        blocks for the un-cached suffix, prefill ONLY the suffix (rows
        position-offset by their hit), and publish the sequence's full
        blocks back into the index so later requests reuse them.

        Admission is atomic per request (``pager.admit``, plan-then-
        commit): a request the pool cannot hold — even after LRU
        eviction — is *deferred* back to the queue with zero blocks
        held, never half-admitted. Deferral, not preemption: blocks come
        back when a running slot finishes, and preempting here would
        thrash (the victim would immediately compete for the same
        blocks). An exception during the prefill dispatch rolls every
        admitted request's blocks back and requeues the wave."""
        pgr, bs = self.pager, self.kv_block_size
        budgeted = self.prefill_budget is not None
        admitted, slots_for = [], []    # slots are assigned up front: block
        seqs, hits, hit_toks = [], [], []   # ownership needs a table
        takes = []                      # suffix tokens consumed THIS wave
        for r in group:
            seq = self._admission_seq(r)
            # LoRA requests bypass the prefix index: adapters targeting
            # wk/wv make the KV adapter-specific, so sharing it across
            # adapters (or with the base model) would be silently wrong
            hit, ht = pgr.match(seq) if r.adapter is None else ([], 0)
            take = len(seq) - ht
            if budgeted:
                take = prefill_chunk(take, self._prefill_left, bs)
                if take == 0:
                    self.queue.push_front(r)   # step's budget spent
                    continue
            slot = free[0]
            if not pgr.admit(slot, hit, math.ceil(take / bs)):
                self.queue.push_front(r)     # defer: pool dry right now
                continue
            free.pop(0)
            if budgeted:
                self._prefill_left -= take
            admitted.append(r)
            slots_for.append(slot)
            seqs.append(seq)
            hits.append(hit)
            hit_toks.append(ht)
            takes.append(take)
        if not admitted:
            return
        w = len(admitted)
        max_ctx = self.max_blocks * bs
        wb = _pow2_bucket(w, 1, self.n_slots)
        if budgeted:
            # chunk length is bounded by the budget, so (wb, pl) comes
            # from a small fixed lattice — O(log slots x log budget)
            # compiles (first chunks here, continuations in
            # _continue_prefill) regardless of arrival pattern, without
            # padding a lone chunk to the full slot set
            pl = _pow2_bucket(max(takes), bs,
                              min(max_ctx, _pow2_bucket(
                                  self.prefill_budget, bs, max_ctx)))
        else:
            pl = _pow2_bucket(max(takes), bs, max_ctx)
        npb_max = max((len(h) for h in hits), default=0)
        npb = _pow2_bucket(npb_max, 1, self.max_blocks) if npb_max else 0
        toks = np.zeros((wb, pl), np.int32)
        lengths = np.ones((wb,), np.int32)
        prefix_len = np.zeros((wb,), np.int32)
        pbt = np.zeros((wb, max(npb, 1)), np.int32)
        sbt = np.zeros((wb, pl // bs), np.int32)
        aidx = np.full((wb,), -1, np.int32)
        for i, (r, slot) in enumerate(zip(admitted, slots_for)):
            chunk = seqs[i][hit_toks[i]: hit_toks[i] + takes[i]]
            toks[i, : len(chunk)] = chunk
            lengths[i] = len(chunk)
            prefix_len[i] = hit_toks[i]
            nh = len(hits[i])
            pbt[i, :nh] = hits[i]
            nsb = math.ceil(len(chunk) / bs)
            sbt[i, :nsb] = pgr.tables[slot, nh: nh + nsb]
            if r.adapter is not None:
                aidx[i] = self.registry.index_of(r.adapter)
        fn = self._get_paged_prefill(wb, pl, npb)
        args = [self.cache, self.params, jnp.asarray(toks),
                jnp.asarray(lengths), jnp.asarray(prefix_len),
                jnp.asarray(pbt), jnp.asarray(sbt)]
        if self.registry is not None:
            args += [self.registry.stacked, jnp.asarray(aidx)]
        try:
            if self.fault_hook is not None:
                self.fault_hook("prefill")
            logits, self.cache = fn(*args)
        except Exception:
            # roll the wave back: every admitted request's blocks return
            # to the pool and the requests (pins intact) requeue
            for r, slot in zip(admitted, slots_for):
                pgr.release_slot(slot)
                self.queue.push_front(r)
            self.stats.blocks_in_use = pgr.blocks_in_use
            raise
        first = self._sample(logits)
        now = self._now()
        for i, (r, slot) in enumerate(zip(admitted, slots_for)):
            if not r._admitted:
                r._admitted = True
                self.stats.admitted += 1
                if r.adapter is not None:
                    self.stats.lora_requests += 1
            else:
                self.stats.restored += 1    # recompute restore
            r._swap = None
            self.stats.prefill_tokens += int(lengths[i])
            self.stats.prefix_hit_tokens += hit_toks[i]
            if budgeted:
                self.stats.prefill_chunks += 1
                self._prefill_progress = True
            if hit_toks[i] + takes[i] < len(seqs[i]):
                # partial first chunk: the slot seats mid-prefill with a
                # block-aligned cursor and NO token (the chunk's last-
                # position logits are mid-prompt and discarded — greedy
                # output stays bit-identical to an unbudgeted prefill).
                # Publish the consumed whole blocks now so concurrent
                # requests (and a preemption/restore) reuse them.
                r.prefilling = True
                r.prefill_cursor = hit_toks[i] + takes[i]
                if r.adapter is None:
                    pgr.insert(seqs[i][:r.prefill_cursor],
                               pgr.slot_blocks(slot))
                self.slots[slot] = r
                self.adapter_slots[slot] = aidx[i]
                continue
            r.tokens.append(int(first[i]))
            r.prefilling = False
            r.prefill_cursor = len(seqs[i])
            r.t_last = now
            # publish the sequence's full blocks now: requests in later
            # waves reuse this prefill while the slot is still decoding
            # (base model only — LoRA KV is adapter-specific, see above)
            if r.adapter is None:
                pgr.insert(seqs[i], pgr.slot_blocks(slot))
            want_cancel = self._emit(r, now)
            reason = self._stop_reason(r)
            if reason is None and want_cancel:
                reason = "cancelled"
            if reason is not None:
                pgr.release_slot(slot)
                self._finish(r, reason)   # EOS/max_new on the first token
                free.append(slot)         # reusable by the next group
                continue
            self.slots[slot] = r
            self.adapter_slots[slot] = aidx[i]
        if self.speculate:
            self._draft_prefill_paged(admitted, slots_for, seqs)
        self.stats.prefill_waves += 1
        self.stats.blocks_in_use = pgr.blocks_in_use

    def _continue_prefill(self):
        """Advance every mid-prefill slot by one budgeted chunk.

        Runs at the top of admission, before new requests compete for
        the step's prefill budget: in-flight prompts finish sooner,
        which frees slots faster. All continuations batch into ONE wave
        through the same jitted paged-prefill bucket the first chunks
        use (prefix = the slot's own consumed blocks, suffix = the next
        chunk), so arrival patterns never grow the compile space."""
        if self.prefill_budget is None:
            return
        bs = self.kv_block_size
        items = []                      # (slot, request, seq, take)
        for i, r in enumerate(self.slots):
            if r is None or not r.prefilling:
                continue
            seq = self._admission_seq(r)
            take = prefill_chunk(len(seq) - r.prefill_cursor,
                                 self._prefill_left, bs)
            if take == 0:
                continue                # step budget exhausted
            self._prefill_left -= take
            items.append((i, r, seq, take))
        if not items:
            return
        t0 = time.perf_counter()
        try:
            self._continue_prefill_wave(items)
        finally:
            self.stats.prefill_wall_s += time.perf_counter() - t0

    def _continue_prefill_wave(self, items):
        """One continuation wave. Block allocation is all-or-nothing per
        slot (``pager.extend``); when the pool cannot cover the wave's
        plan, victims are preempted (mid-prefill slots included) until it
        can or one slot remains. A fault during the dispatch rolls the
        extension back to the cursor (``pager.truncate``) — the slots
        stay seated and a retried step re-runs the identical chunk."""
        pgr, bs = self.pager, self.kv_block_size
        while True:
            need = sum(math.ceil(take / bs) for _, _, _, take in items)
            if pgr.can_allocate(need):
                break
            if sum(s is not None for s in self.slots) <= 1:
                break                   # per-slot extend() defers below
            victim = pick_victim(self.slots)
            self._preempt_slot(victim)
            items = [it for it in items if it[0] != victim]
            if not items:
                return
        ran = []
        for slot, r, seq, take in items:
            if pgr.extend(slot, math.ceil(take / bs)):
                ran.append((slot, r, seq, take))
            # else: chunk deferred to the next step, slot stays seated
        if not ran:
            return
        max_ctx = self.max_blocks * bs
        # same bucket lattice as budgeted admission waves: pow2 width,
        # pow2 length capped by the budget
        wb = _pow2_bucket(len(ran), 1, self.n_slots)
        pl = _pow2_bucket(max(t for *_, t in ran), bs,
                          min(max_ctx, _pow2_bucket(self.prefill_budget,
                                                    bs, max_ctx)))
        npb = _pow2_bucket(max(r.prefill_cursor // bs
                               for _, r, _, _ in ran), 1, self.max_blocks)
        toks = np.zeros((wb, pl), np.int32)
        lengths = np.ones((wb,), np.int32)
        prefix_len = np.zeros((wb,), np.int32)
        pbt = np.zeros((wb, npb), np.int32)
        sbt = np.zeros((wb, pl // bs), np.int32)
        aidx = np.full((wb,), -1, np.int32)
        for j, (slot, r, seq, take) in enumerate(ran):
            cur = r.prefill_cursor      # block-aligned by construction
            toks[j, :take] = seq[cur: cur + take]
            lengths[j] = take
            prefix_len[j] = cur
            nh = cur // bs
            pbt[j, :nh] = pgr.tables[slot, :nh]
            nsb = math.ceil(take / bs)
            sbt[j, :nsb] = pgr.tables[slot, nh: nh + nsb]
            aidx[j] = self.adapter_slots[slot]
        fn = self._get_paged_prefill(wb, pl, npb)
        args = [self.cache, self.params, jnp.asarray(toks),
                jnp.asarray(lengths), jnp.asarray(prefix_len),
                jnp.asarray(pbt), jnp.asarray(sbt)]
        if self.registry is not None:
            args += [self.registry.stacked, jnp.asarray(aidx)]
        try:
            if self.fault_hook is not None:
                self.fault_hook("prefill")
            logits, self.cache = fn(*args)
        except Exception:
            # roll the extension back to the cursor: the slots stay
            # seated mid-prefill and a retried step re-plans the exact
            # same chunk (deterministic, so retry is token-identical)
            for slot, r, _, _ in ran:
                pgr.truncate(slot, r.prefill_cursor)
            self.stats.blocks_in_use = pgr.blocks_in_use
            raise
        first = self._sample(logits)
        now = self._now()
        for j, (slot, r, seq, take) in enumerate(ran):
            r.prefill_cursor += take
            self.stats.prefill_tokens += take
            self.stats.prefill_chunks += 1
            self._prefill_progress = True
            if r.adapter is None:
                pgr.insert(seq[:r.prefill_cursor], pgr.slot_blocks(slot))
            if r.prefill_cursor < len(seq):
                continue                # still mid-prompt
            # final chunk: the wave's last-position logits are the real
            # end-of-prompt logits — sample the first token and hand the
            # slot to decode
            r.prefilling = False
            r.tokens.append(int(first[j]))
            r.t_last = now
            want_cancel = self._emit(r, now)
            reason = self._stop_reason(r)
            if reason is None and want_cancel:
                reason = "cancelled"
            if reason is not None:
                pgr.release_slot(slot)
                self._finish(r, reason)
                self.slots[slot] = None
                self.adapter_slots[slot] = -1
        self.stats.prefill_waves += 1
        self.stats.blocks_in_use = pgr.blocks_in_use

    def _draft_prefill_paged(self, admitted, slots_for, seqs):
        """Draft-side prefill for a paged admission wave: the draft cache
        is dense, so it cannot ride the suffix-only paged dispatch —
        instead the FULL sequence of every seated request prefills through
        the plain dense path (prefix hits save target compute only; the
        draft recomputes its whole KV, which is the cheap model by
        construction). Runs after the target wave committed: a request the
        target deferred or finished at prefill never reaches here."""
        keep = [(i, slot) for i, (r, slot) in enumerate(zip(admitted,
                                                            slots_for))
                if self.slots[slot] is r]
        if not keep:
            return
        wb = _pow2_bucket(len(keep), 1, self.n_slots)
        pl = _pow2_bucket(max(len(seqs[i]) for i, _ in keep),
                          min(8, self.max_len), self.max_len)
        toks = np.zeros((wb, pl), np.int32)
        lengths = np.ones((wb,), np.int32)
        aidx = np.full((wb,), -1, np.int32)
        for j, (i, slot) in enumerate(keep):
            toks[j, : len(seqs[i])] = seqs[i]
            lengths[j] = len(seqs[i])
            aidx[j] = self.adapter_slots[slot]
        fn = self._get_prefill(wb, pl)
        if self.registry is not None:
            _, dwave = fn(self.draft_params, jnp.asarray(toks),
                          jnp.asarray(lengths), self.registry.stacked,
                          jnp.asarray(aidx))
        else:
            _, dwave = fn(self.draft_params, jnp.asarray(toks),
                          jnp.asarray(lengths))
        src = jnp.asarray(list(range(len(keep))), jnp.int32)
        dst = jnp.asarray([slot for _, slot in keep], jnp.int32)
        self.draft_cache = self._writer(self.draft_cache, dwave, src, dst)

    # -- sampling --------------------------------------------------------------
    def _sample(self, logits):
        toks, self.rng = self._sampler(jnp.asarray(logits), self.rng,
                                       greedy=self.greedy,
                                       vocab_size=self.cfg.vocab_size)
        return np.asarray(toks)

    # -- stop conditions -------------------------------------------------------
    def _stop_reason(self, r: Request) -> Optional[str]:
        if self.eos_id is not None and r.tokens[-1] == self.eos_id:
            return "eos"
        if len(r.tokens) >= r.max_new:
            return "max_new"
        # next decode would write at pos = prompt + generated - 1
        if len(r.prompt) + len(r.tokens) - 1 >= self.max_len:
            r.truncated = True
            return "cache_full"
        return None

    def _finish(self, r: Request, reason: str):
        """Terminal bookkeeping for every outcome. ``finished`` (the list)
        holds all of them; ``stats.finished`` counts only generation
        outcomes (eos/max_new/cache_full) — rejected requests produced no
        tokens, and expired/cancelled ones may carry a partial stream;
        all three are tallied separately."""
        r.done = True
        r.finish_reason = reason
        r._swap = None
        r.prefilling = False
        if r.adapter is not None:
            self.registry.release(r.adapter)   # unpin: evict becomes legal
        self.finished.append(r)
        if reason == "rejected":
            self.stats.rejected += 1
            return
        if reason == "expired":
            self.stats.expired += 1
            return
        if reason == "cancelled":
            self.stats.cancelled += 1
            return
        self.stats.finished += 1
        if r.truncated:
            self.stats.truncated += 1

    # -- decode ----------------------------------------------------------------
    def _get_chunk_fn(self, n: int):
        """Jitted scan-decode for chunk length n (cache donated).

        With an adapter registry the callable takes the stacked A/B pytree
        and the per-slot [B] adapter-index row as leading jit arguments
        (so registry hot-swaps reuse the compile cache) and the wrapped
        ``api.decode`` runs the gathered LoRA delta pipeline alongside the
        untouched base path every scan step."""
        key = (n, self.greedy)
        if key not in self._chunk_fns:
            api, cfg = self.api, self.cfg
            eos_id, max_len, greedy = self.eos_id, self.max_len, self.greedy
            if self.registry is None:
                def fn(params, last, cache, rng, stop, gen, max_new):
                    return decode_steps(
                        api.decode, params, last, cache, rng, stop, gen,
                        max_new, n=n, vocab_size=cfg.vocab_size,
                        max_len=max_len, eos_id=eos_id, greedy=greedy)

                self._chunk_fns[key] = jax.jit(fn, donate_argnums=(2,))
            else:
                scaling = self.registry.scaling

                def fn(params, stacked, aidx, last, cache, rng, stop, gen,
                       max_new):
                    def dec(p, t, c):
                        return api.decode(p, t, c, adapters=stacked,
                                          adapter_idx=aidx,
                                          lora_scaling=scaling)
                    return decode_steps(
                        dec, params, last, cache, rng, stop, gen,
                        max_new, n=n, vocab_size=cfg.vocab_size,
                        max_len=max_len, eos_id=eos_id, greedy=greedy)

                self._chunk_fns[key] = jax.jit(fn, donate_argnums=(4,))
        return self._chunk_fns[key]

    # -- speculative decode ----------------------------------------------------
    def _get_spec_fns(self, k: int):
        """Jitted (draft, verify) pair for draft length ``k``.

        The draft scan is ``decode_steps`` with every stop condition
        defused (no eos, budget/pos bounds vacuous): proposals past a
        real stop are garbage the host's per-token ``_stop_reason``
        discards while appending, and a free-running scan is what makes
        a retried round bit-deterministic. It runs k+1 steps — one more
        than the proposals used — so draft KV lands at the same
        ``pos .. pos+k`` the verify scan writes, keeping the two caches
        position-aligned even on an all-accept round. The verify scan is
        :func:`repro.serve.decode.verify_steps` over the target. Both
        donate their cache. ``k`` is compile-time (bucketed by
        ``round_k``), mirroring ``_get_chunk_fn``'s per-length cache."""
        if k not in self._spec_fns:
            api, cfg = self.api, self.cfg
            vs = cfg.vocab_size
            no_stop_len = self.max_len + 2    # pos bound can never fire
            if self.registry is None:
                def draft_fn(dparams, last, dcache, rng, stop):
                    b = last.shape[0]
                    return decode_steps(
                        api.decode, dparams, last, dcache, rng, stop,
                        jnp.zeros((b,), jnp.int32),
                        jnp.full((b,), 1 << 30, jnp.int32),
                        n=k + 1, vocab_size=vs, max_len=no_stop_len,
                        eos_id=None, greedy=True)

                def verify_fn(params, last, drafts, cache):
                    return verify_steps(api.decode, params, last, drafts,
                                        cache, vocab_size=vs)

                self._spec_fns[k] = (
                    jax.jit(draft_fn, donate_argnums=(2,)),
                    jax.jit(verify_fn, donate_argnums=(3,)))
            else:
                scaling = self.registry.scaling

                def draft_fn(dparams, stacked, aidx, last, dcache, rng,
                             stop):
                    def dec(p, t, c):
                        return api.decode(p, t, c, adapters=stacked,
                                          adapter_idx=aidx,
                                          lora_scaling=scaling)
                    b = last.shape[0]
                    return decode_steps(
                        dec, dparams, last, dcache, rng, stop,
                        jnp.zeros((b,), jnp.int32),
                        jnp.full((b,), 1 << 30, jnp.int32),
                        n=k + 1, vocab_size=vs, max_len=no_stop_len,
                        eos_id=None, greedy=True)

                def verify_fn(params, stacked, aidx, last, drafts, cache):
                    def dec(p, t, c):
                        return api.decode(p, t, c, adapters=stacked,
                                          adapter_idx=aidx,
                                          lora_scaling=scaling)
                    return verify_steps(dec, params, last, drafts, cache,
                                        vocab_size=vs)

                self._spec_fns[k] = (
                    jax.jit(draft_fn, donate_argnums=(4,)),
                    jax.jit(verify_fn, donate_argnums=(5,)))
        return self._spec_fns[k]

    def _spec_step(self, active, max_n: Optional[int]) -> bool:
        """One speculative round over the active slots: draft k proposals
        with the low-precision model, verify all of them in ONE
        teacher-forced target dispatch, append the longest agreeing
        prefix plus the target's correction token, and roll the KV tail
        written for rejected positions back (cursor reset / block
        truncation). Greedy output is bit-identical to `_step`'s
        target-only decode — every appended token is the target's own
        argmax (tests/test_speculative.py).

        Fault retry contract: both caches' ``pos`` cursors are host-set
        from request state at the top of every round, and the draft scan
        is deterministic (greedy, stop-free), so a round that faults at
        the "draft" or "verify" hook re-runs bit-identically — the
        positions past the cursor that a partial round already wrote are
        simply rewritten with the same values."""
        positions = {i: len(self.slots[i].prompt)
                     + len(self.slots[i].tokens) - 1 for i in active}

        def pick_k():
            return round_k(
                self.spec_k, max_len=self.max_len,
                positions=[positions[i] for i in active],
                budgets=[self.slots[i].max_new - len(self.slots[i].tokens)
                         for i in active],
                max_n=max_n)

        k = pick_k()
        if self.paged:
            # plan -> commit for the whole k+1 verify window, preempting
            # while it cannot fit (mirrors `_step`; a single slot always
            # fits because k is clamped to the slot's own remaining room)
            while len(active) > 1:
                need = 0
                for i in active:
                    a, c = self.pager.plan_decode(i, positions[i], k + 1)
                    need += a + c
                if self.pager.can_allocate(need):
                    break
                self._preempt_slot(pick_victim(self.slots))
                active = [i for i, s in enumerate(self.slots)
                          if s is not None]
                k = pick_k()
            cow = []
            pos_host = np.zeros((self.n_slots,), np.int32)
            for i in active:
                pos_host[i] = positions[i]
                cow += self.pager.prepare_decode(i, positions[i], k + 1)
            if cow:
                pad = _pow2_bucket(len(cow), 1, 1 << 30) - len(cow)
                pairs = cow + [(0, 0)] * pad
                self.cache = self._copier(
                    self.cache,
                    jnp.asarray([c[0] for c in pairs], jnp.int32),
                    jnp.asarray([c[1] for c in pairs], jnp.int32))
                self.stats.cow_copies += len(cow)
            self.cache["pos"] = jnp.asarray(pos_host)
            self.cache["block_tables"] = jnp.asarray(self.pager.tables)
            self.stats.blocks_in_use = self.pager.blocks_in_use
        else:
            # dense rollback is this line: the verify scan advanced the
            # device cursor to pos+k+1 last round, resetting it to the
            # accepted length makes the stale tail dead weight the next
            # window overwrites
            pos_host = np.zeros((self.n_slots,), np.int32)
            for i in active:
                pos_host[i] = positions[i]
            self.cache["pos"] = jnp.asarray(pos_host)
        dpos = np.zeros((self.n_slots,), np.int32)
        for i in active:
            dpos[i] = positions[i]
        self.draft_cache["pos"] = jnp.asarray(dpos)
        last = np.zeros((self.n_slots,), np.int32)
        stop = np.ones((self.n_slots,), bool)
        for i in active:
            last[i] = self.slots[i].tokens[-1]
            stop[i] = False
        draft_fn, verify_fn = self._get_spec_fns(k)
        if k:
            if self.fault_hook is not None:
                self.fault_hook("draft")
            if self.registry is not None:
                dout = draft_fn(self.draft_params, self.registry.stacked,
                                jnp.asarray(self.adapter_slots),
                                jnp.asarray(last), self.draft_cache,
                                self.rng, jnp.asarray(stop))
            else:
                dout = draft_fn(self.draft_params, jnp.asarray(last),
                                self.draft_cache, self.rng,
                                jnp.asarray(stop))
            self.draft_cache = dout.cache
            drafts_dev = dout.tokens[:k]
            drafts = np.asarray(drafts_dev)
        else:
            drafts_dev = jnp.zeros((0, self.n_slots), jnp.int32)
            drafts = np.zeros((0, self.n_slots), np.int32)
        if self.fault_hook is not None:
            self.fault_hook("verify")
        if self.registry is not None:
            targets_dev, self.cache = verify_fn(
                self.params, self.registry.stacked,
                jnp.asarray(self.adapter_slots), jnp.asarray(last),
                drafts_dev, self.cache)
        else:
            targets_dev, self.cache = verify_fn(
                self.params, jnp.asarray(last), drafts_dev, self.cache)
        targets = np.asarray(targets_dev)          # [k+1, B]
        now = self._now()
        emitted = 0
        for i in active:
            r = self.slots[i]
            m = accept_length(drafts[:, i], targets[:, i])
            got = 0
            reason = None
            for t in range(m + 1):
                # stops are re-derived per appended token: an EOS / budget
                # / cache-full landing mid-acceptance discards the rest
                r.tokens.append(int(targets[t, i]))
                got += 1
                reason = self._stop_reason(r)
                if reason is not None:
                    break
            r.t_last = now
            emitted += got
            self.stats.spec_slot_rounds += 1
            self.stats.drafted_tokens += k
            # of the kept tokens, all but a final correction/bonus token
            # were draft proposals
            self.stats.accepted_draft_tokens += min(got, m)
            if self.paged:
                # rollback: keep exactly the KV the kept tokens stand on
                # (prompt ++ tokens[:-1]); whole tail blocks written for
                # rejected positions return to the pool
                self.pager.truncate(i, positions[i] + got)
            want_cancel = self._emit(r, now)
            if reason is None and want_cancel:
                reason = "cancelled"
            if reason is not None:
                if self.paged:
                    if r.adapter is None:
                        self.pager.insert(self._kv_seq(r),
                                          self.pager.slot_blocks(i))
                    self.pager.release_slot(i)
                self._finish(r, reason)
                self.slots[i] = None
                self.adapter_slots[i] = -1
        self.stats.spec_rounds += 1
        self.stats.spec_emitted_tokens += emitted
        self.stats.decode_tokens += emitted
        self.stats.steps += k + 1                  # target decode steps
        self.stats.decode_chunks += 2 if k else 1  # dispatches this round
        self.stats.occupancy_sum += (k + 1) * len(active) / self.n_slots
        if self.paged:
            self.stats.blocks_in_use = self.pager.blocks_in_use
        return True

    def step(self, max_n: Optional[int] = None) -> bool:
        """Admit a prefill wave, then run ONE chunked decode dispatch of up
        to min(decode_chunk, max_n, largest per-slot remaining budget)
        on-device steps. With an adapter registry the per-slot [n_slots]
        adapter-index row rides along so mixed base/LoRA slots decode in
        the same scan. Returns False when no work is left."""
        with self._mesh_ctx():
            return self._step(max_n)

    def _chunk_len(self, active, max_n: Optional[int]) -> int:
        """Decode chunk length: largest per-slot remaining budget, clamped
        to decode_chunk and the caller's step budget."""
        remaining = 1
        for i in active:
            r = self.slots[i]
            # slot i can emit at most this many more tokens (max_new and
            # cache-capacity bounds; the scan wastes nothing past the wave)
            rem = min(r.max_new - len(r.tokens),
                      self.max_len - (len(r.prompt) + len(r.tokens) - 1))
            remaining = max(remaining, rem)
        return max(1, min(self.decode_chunk, remaining,
                          max_n if max_n is not None else remaining))

    def _step(self, max_n: Optional[int] = None) -> bool:
        if self.prefill_budget is not None:
            # per-STEP prefill-token ledger: first chunks and
            # continuations both draw from it, so no single engine step
            # ever does more prefill work than the budget
            self._prefill_left = self.prefill_budget
            self._prefill_progress = False
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        while not active and self.queue:
            # a whole wave can finish at prefill (EOS/max_new on the first
            # token); keep admitting so queued work is never stranded
            before = (len(self.queue), len(self.finished),
                      self.stats.restored)
            self._admit()
            active = [i for i, s in enumerate(self.slots) if s is not None]
            if not active and before == (len(self.queue),
                                         len(self.finished),
                                         self.stats.restored):
                # every slot is free yet nothing admits, finishes, or
                # expires: the pool can never fit the queued requests —
                # a sizing bug, not a transient overload
                raise RuntimeError(
                    f"admission stalled: {len(self.queue)} queued "
                    f"request(s) cannot fit an empty engine "
                    f"(num_blocks={getattr(self, 'num_blocks', None)})")
        if not active:
            return False
        # mid-prefill slots hold blocks but have no token to decode yet;
        # they sit out the decode dispatch (their block-table rows are
        # masked to trash below so the scan's unconditional KV writes
        # cannot touch their real blocks)
        decode_active = [i for i in active if not self.slots[i].prefilling]
        if not decode_active:
            if self._prefill_progress:
                return True             # prefill-only step: work happened
            # nothing decodable and no chunk ran (budget spent before
            # these slots, or the pool deferred every extension): preempt
            # one victim so the freed blocks guarantee the next step
            # makes progress instead of spinning
            victim = pick_victim(self.slots)
            if victim is not None:
                self._preempt_slot(victim)
                return True
            return False
        if self.speculate:
            return self._spec_step(decode_active, max_n)
        n = self._chunk_len(decode_active, max_n)
        if self.paged:
            # plan -> commit: reserve the whole write window's block
            # budget before touching the pool, preempting the lowest-
            # priority slot while the window cannot fit. A single slot
            # always fits (pool >= per-slot max + trash), so this
            # terminates with at least one runner.
            while True:
                need = 0
                for i in decode_active:
                    r = self.slots[i]
                    pos0 = len(r.prompt) + len(r.tokens) - 1
                    rem = min(r.max_new - len(r.tokens),
                              self.max_len - pos0)
                    a, c = self.pager.plan_decode(i, pos0,
                                                  max(1, min(n, rem)))
                    need += a + c
                if self.pager.can_allocate(need):
                    break
                if sum(s is not None for s in self.slots) <= 1:
                    break
                self._preempt_slot(pick_victim(self.slots))
                decode_active = [i for i, s in enumerate(self.slots)
                                 if s is not None and not s.prefilling]
                if not decode_active:
                    # the last decoder was the victim; the preemption
                    # itself is this step's progress
                    return True
                n = self._chunk_len(decode_active, max_n)
        last = np.zeros((self.n_slots,), np.int32)
        gen = np.zeros((self.n_slots,), np.int32)
        budget = np.zeros((self.n_slots,), np.int32)
        stop = np.ones((self.n_slots,), bool)
        for i in decode_active:
            r = self.slots[i]
            last[i] = r.tokens[-1]
            gen[i] = len(r.tokens)
            budget[i] = r.max_new
            stop[i] = False
        if self.paged:
            # make every active slot's write window [pos, pos+n) backed by
            # uniquely owned blocks: append fresh blocks past the table end
            # and copy-on-write any shared block, in ONE batched device
            # copy. Planned above, so allocation cannot fail halfway; a
            # re-run after a decode-phase fault is a no-op (idempotent).
            cow = []
            pos_host = np.zeros((self.n_slots,), np.int32)
            for i in decode_active:
                r = self.slots[i]
                pos0 = len(r.prompt) + len(r.tokens) - 1
                pos_host[i] = pos0
                rem = min(r.max_new - len(r.tokens), self.max_len - pos0)
                cow += self.pager.prepare_decode(i, pos0,
                                                 max(1, min(n, rem)))
            if cow:
                # pad to a power-of-two count (trash onto trash) so the
                # jitted copier compiles once per bucket, not per count
                pad = _pow2_bucket(len(cow), 1, 1 << 30) - len(cow)
                pairs = cow + [(0, 0)] * pad
                src = jnp.asarray([c[0] for c in pairs], jnp.int32)
                dst = jnp.asarray([c[1] for c in pairs], jnp.int32)
                self.cache = self._copier(self.cache, src, dst)
                self.stats.cow_copies += len(cow)
            self.cache["pos"] = jnp.asarray(pos_host)
            # the decode scan writes KV for EVERY row, every scan step
            # (stopped rows freeze their token but not the cache write at
            # pos). Free slots' table rows are already all-trash; a mid-
            # prefill slot's row holds REAL blocks at index 0, which a
            # write at pos=0 would corrupt — mask those rows to trash in
            # the dispatched copy (the pager's own tables are untouched)
            tables = self.pager.tables
            if any(s is not None and s.prefilling for s in self.slots):
                tables = tables.copy()
                for i, s in enumerate(self.slots):
                    if s is not None and s.prefilling:
                        tables[i, :] = TRASH_BLOCK
            self.cache["block_tables"] = jnp.asarray(tables)
            self.stats.blocks_in_use = self.pager.blocks_in_use
        fn = self._get_chunk_fn(n)
        if self.fault_hook is not None:
            # after the (idempotent) pager commit, before the dispatch:
            # a fault here leaves the step cleanly retryable
            self.fault_hook("decode")
        if self.registry is not None:
            out = fn(self.params, self.registry.stacked,
                     jnp.asarray(self.adapter_slots), jnp.asarray(last),
                     self.cache, self.rng, jnp.asarray(stop),
                     jnp.asarray(gen), jnp.asarray(budget))
        else:
            out = fn(self.params, jnp.asarray(last), self.cache, self.rng,
                     jnp.asarray(stop), jnp.asarray(gen),
                     jnp.asarray(budget))
        self.cache, self.rng = out.cache, out.rng
        toks = np.asarray(out.tokens)
        valid = np.asarray(out.valid)
        self.stats.steps += n
        self.stats.decode_chunks += 1
        self.stats.decode_tokens += int(valid.sum())
        self.stats.occupancy_sum += float(valid.sum()) / self.n_slots
        now = self._now()
        for i in decode_active:
            r = self.slots[i]
            got = 0
            for t in range(n):
                if not valid[t, i]:
                    break
                r.tokens.append(int(toks[t, i]))
                got += 1
            if got:
                r.t_last = now
            want_cancel = self._emit(r, now)
            reason = self._stop_reason(r)
            if reason is None and want_cancel:
                reason = "cancelled"
            if reason is not None:
                if self.paged:
                    # publish the generated tokens' full blocks too (KV at
                    # position p is keyed by prompt ++ tokens[:-1], the
                    # sequence actually fed), then drop the slot's refs —
                    # indexed blocks stay cached for future requests.
                    # LoRA rows stay unindexed (adapter-specific KV).
                    if r.adapter is None:
                        self.pager.insert(self._kv_seq(r),
                                          self.pager.slot_blocks(i))
                    self.pager.release_slot(i)
                self._finish(r, reason)
                self.slots[i] = None
                self.adapter_slots[i] = -1
        if self.paged:
            self.stats.blocks_in_use = self.pager.blocks_in_use
        return True

    def run(self, max_steps: int = 10000):
        """Serve until drained or `max_steps` device decode steps ran."""
        while (self.queue or any(s is not None for s in self.slots)) \
                and max_steps > 0:
            before = self.stats.steps
            if not self.step(max_n=max_steps):
                break
            max_steps -= self.stats.steps - before
        return self.finished

    def adopt_compiled(self, other: "ServeEngine"):
        """Inherit another engine's jitted callables (benchmark warmup:
        the timed engine starts compile-free). The adopted closures bake
        the source engine's config and stop semantics, so mismatched
        engines are rejected rather than silently decoding wrong tokens."""
        mine = (self.cfg, self.eos_id, self.max_len, self.greedy,
                self.n_slots, self.registry is None,
                None if self.registry is None else self.registry.scaling,
                self.paged,
                self.kv_block_size if self.paged else None,
                getattr(self, "num_blocks", None) if self.paged else None,
                self.mesh,
                self.speculate, self.spec_k if self.speculate else None,
                self.draft_bits if self.speculate else None,
                self.draft_mode if self.speculate else None,
                self.prefill_budget)
        theirs = (other.cfg, other.eos_id, other.max_len, other.greedy,
                  other.n_slots, other.registry is None,
                  None if other.registry is None else other.registry.scaling,
                  other.paged,
                  other.kv_block_size if other.paged else None,
                  getattr(other, "num_blocks", None) if other.paged else None,
                  other.mesh,
                  other.speculate,
                  other.spec_k if other.speculate else None,
                  other.draft_bits if other.speculate else None,
                  other.draft_mode if other.speculate else None,
                  other.prefill_budget)
        if mine != theirs:
            raise ValueError(
                "adopt_compiled: engines differ in (cfg, eos_id, max_len, "
                "greedy, n_slots, paged layout, mesh, speculation): "
                f"{mine} vs {theirs}")
        self._chunk_fns = other._chunk_fns
        self._spec_fns = other._spec_fns
        self._prefill_cache = other._prefill_cache
        self._writer = other._writer
        self._sampler = other._sampler
        return self

    def generate(self, prompts, max_new: int = 32, max_steps: int = 10000,
                 return_requests: bool = False, adapters=None):
        """Serve `prompts`; returns one token list per prompt (in order).

        adapters: optional per-prompt list of registered LoRA adapter
        names (None entries decode with the base model) — a mixed batch
        of base and N distinct adapters runs in the same waves/chunks.

        Requests still in flight after `max_steps` are cancelled: they come
        back with partial tokens and `truncated=True`, and their slots/queue
        entries are released so a later `generate()` starts clean instead of
        resuming (and mutating) already-returned results.
        `return_requests=True` returns the Request objects (tokens +
        truncated/prompt_truncated flags)."""
        if adapters is None:
            adapters = [None] * len(prompts)
        if len(adapters) != len(prompts):
            raise ValueError(f"adapters list length {len(adapters)} != "
                             f"{len(prompts)} prompts")
        start = len(self.finished)
        ids = []
        try:
            for p, a in zip(prompts, adapters):
                ids.append(self.submit(p, max_new, adapter=a))
            self.run(max_steps)
        except Exception:
            # leave the engine clean behind the propagating error: every
            # still-queued/running request from this call releases its
            # slot, pool blocks and adapter pins (the pin-leak fix)
            resolved = {r.rid for r in self.finished[start:]}
            for rid in ids:
                if rid not in resolved:
                    self._cancel(rid)
            raise
        want = set(ids)
        new = self.finished[start:]
        by_id = {r.rid: r for r in new}
        out = []
        for rid in ids:
            r = by_id.get(rid)
            if r is None:
                r = self._cancel(rid)
            out.append(r)
        # results are handed to the caller — drop them from the engine log so
        # a long-lived engine doesn't accumulate every request ever served
        del self.finished[start:]
        self.finished.extend(r for r in new if r.rid not in want)
        return out if return_requests else [r.tokens for r in out]

    def _cancel(self, rid: int) -> Request:
        """Evict an in-flight/queued request, returning it flagged truncated."""
        for i, s in enumerate(self.slots):
            if s is not None and s.rid == rid:
                self.slots[i] = None
                self.adapter_slots[i] = -1
                if self.paged:
                    self.pager.release_slot(i)
                if s.adapter is not None:
                    self.registry.release(s.adapter)
                s.truncated = True
                s.finish_reason = "cancelled"
                s._swap = None
                self.stats.truncated += 1
                return s
        for r in self.queue:
            if r.rid == rid:
                self.queue.remove(r)
                if r.adapter is not None:
                    self.registry.release(r.adapter)
                r.truncated = True
                r.finish_reason = "cancelled"
                r._swap = None
                self.stats.truncated += 1
                return r
        raise KeyError(f"request {rid} not found")
