"""Continuous-batching serving engine (the AxLLM deployment surface).

`ServeEngine(..., quantize=True)` converts trained params post-training
(zero setup, paper §I) to int8 codes; every linear then runs the fused
dequant-matmul path. The scheduler keeps `n_slots` request slots full:

Scheduler contract
------------------
- **Admission (prefill waves).** Every `step()` first admits queued
  requests into free slots. Attention-family models (`api.ragged_prefill`)
  take mixed-length prompts in ONE right-padded batch: causal masking
  keeps real tokens from seeing the pads, logits are gathered at each
  row's last real position, and the per-row cache cursor is set to the
  true length (pad KV beyond the cursor is dead and overwritten by
  decode). Recurrent families (ssm/hybrid) fold every position into
  state, so the wave is split into equal-length sub-batches — slots still
  fill in the same step.
- **Cache layout.** Slot insertion is driven by `api.cache_spec`, a
  pytree (same treedef as the cache) giving the batch axis of every leaf.
  This replaces shape-guessing (`shape[i] == n_slots`), which silently
  corrupted the cache whenever `n_slots` collided with a stacked-layer /
  head dim (e.g. xLSTM superblocks).
- **Hot loops.** Prefill is jitted and bucketed by `(wave_size,
  padded_len)`. Ragged families round both up to powers of two, so a
  steady mixed stream hits a handful of compiles
  (`stats.prefill_compiles`); recurrent families bucket wave size only —
  padded_len is the exact group length, i.e. one compile per distinct
  prompt length. Decode is one jitted call per step over all slots with
  the cache buffer donated.
- **Stop conditions.** Per-slot: EOS token (`eos_id`, engine arg or
  `cfg.eos_id`), `max_new` tokens, or cache-full (`prompt + generated`
  reaching `max_len` — flagged `truncated`). Finished slots free at the
  end of the step and refill on the next.
- **Long prompts.** `long_prompt="truncate"` keeps the last
  `max_len - 1` prompt tokens (flagging `prompt_truncated`);
  `"reject"` raises at `submit()`. Nothing silently overflows the cache.
- **Stats.** `engine.stats` tracks admitted/finished/truncated requests,
  decode steps/tokens, prefill waves/tokens/compiles and mean slot
  occupancy; `stats.as_dict()` feeds `benchmarks/serve_bench.py`.

`generate()` returns token lists for all submitted prompts; requests
still in flight when `max_steps` runs out come back with their partial
tokens and `truncated=True` (`return_requests=True` exposes the flags).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.axllm_linear import deploy_quantize
from repro.core.quantization import QuantConfig
from repro.models.model import ModelAPI, get_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32 (post long-prompt policy)
    max_new: int = 32
    tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False           # generation cut short (cache/steps)
    prompt_truncated: bool = False    # prompt clipped by long_prompt policy


@dataclasses.dataclass
class EngineStats:
    admitted: int = 0
    finished: int = 0
    truncated: int = 0
    steps: int = 0
    decode_tokens: int = 0
    prefill_waves: int = 0
    prefill_tokens: int = 0
    prefill_compiles: int = 0
    occupancy_sum: float = 0.0        # sum over steps of active/n_slots

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / self.steps if self.steps else 0.0

    @property
    def tokens_per_step(self) -> float:
        return self.decode_tokens / self.steps if self.steps else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["mean_occupancy"] = self.mean_occupancy
        d["tokens_per_step"] = self.tokens_per_step
        return d


def _pow2_bucket(n: int, lo: int, hi: int) -> int:
    """Smallest power of two >= n, floored at lo, capped at hi."""
    b = lo
    while b < n:
        b *= 2
    return min(b, hi)


class ServeEngine:
    def __init__(self, cfg, params, *, n_slots: int = 4, max_len: int = 512,
                 quantize: bool = False, quant_bits: int = 8,
                 impl: str = "auto", greedy: bool = True, seed: int = 0,
                 eos_id: Optional[int] = None,
                 long_prompt: str = "truncate"):
        if cfg.is_encoder_decoder:
            raise NotImplementedError(
                "ServeEngine drives token-only prefill; encoder-decoder "
                "serving needs a frames ingress (future PR)")
        if long_prompt not in ("truncate", "reject"):
            raise ValueError(f"long_prompt must be 'truncate' or 'reject', "
                             f"got {long_prompt!r}")
        if max_len < 2:
            raise ValueError("max_len must be >= 2 (prompt + 1 decode step)")
        self.cfg = cfg
        self.api: ModelAPI = get_model(cfg, impl=impl)
        if quantize:
            params = deploy_quantize(
                params, QuantConfig(bits=quant_bits, mode="affine",
                                    granularity="per_channel"))
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.greedy = greedy
        self.eos_id = eos_id if eos_id is not None else cfg.eos_id
        self.long_prompt = long_prompt
        self.rng = jax.random.PRNGKey(seed)
        self.cache = self.api.init_cache(n_slots, max_len)
        self._validate_cache_spec()
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._rid = 0
        self.stats = EngineStats()
        self._decode = jax.jit(self.api.decode, donate_argnums=(2,))
        self._prefill_cache = {}      # (wave_bucket, padded_len) -> jit fn
        self._writer = jax.jit(self._write_wave, donate_argnums=(0,))

    def _validate_cache_spec(self):
        spec = self.api.cache_spec
        if spec is None:
            raise ValueError("ModelAPI.cache_spec missing: the engine needs "
                             "the batch axis of every cache leaf")

        def check(leaf, ax):
            if leaf.shape[ax] != self.n_slots:
                raise ValueError(
                    f"cache_spec says batch axis {ax} but leaf shape "
                    f"{leaf.shape} has {leaf.shape[ax]} != n_slots="
                    f"{self.n_slots} there")
            return leaf

        jax.tree_util.tree_map(check, self.cache, spec)

    # -- request management ---------------------------------------------------
    def submit(self, prompt, max_new: int = 32) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        cap = self.max_len - 1            # leave >= 1 decode position
        prompt_truncated = False
        if prompt.size > cap:
            if self.long_prompt == "reject":
                raise ValueError(
                    f"prompt length {prompt.size} exceeds max_len-1={cap}; "
                    f"resubmit shorter or use long_prompt='truncate'")
            prompt = prompt[-cap:]        # keep the most recent context
            prompt_truncated = True
        req = Request(self._rid, prompt, max_new,
                      prompt_truncated=prompt_truncated)
        self._rid += 1
        self.queue.append(req)
        return req.rid

    def _free_slots(self):
        return [i for i, s in enumerate(self.slots) if s is None]

    # -- prefill waves ---------------------------------------------------------
    def _admit(self):
        free = self._free_slots()
        if not free or not self.queue:
            return
        take = self.queue[: len(free)]
        del self.queue[: len(take)]
        if self.api.ragged_prefill:
            groups = [take]
        else:
            by_len = {}
            for r in take:
                by_len.setdefault(len(r.prompt), []).append(r)
            groups = list(by_len.values())
        for group in groups:
            self._prefill_group(group, free)

    def _get_prefill(self, wave_bucket: int, padded_len: int):
        key = (wave_bucket, padded_len)
        if key not in self._prefill_cache:
            api, max_len = self.api, self.max_len
            if api.ragged_prefill:
                def fn(params, toks, lengths):
                    cache = api.init_cache(toks.shape[0], max_len)
                    return api.prefill(params, {"tokens": toks}, cache,
                                       lengths=lengths)
            else:
                def fn(params, toks, lengths):
                    cache = api.init_cache(toks.shape[0], max_len)
                    return api.prefill(params, {"tokens": toks}, cache)
            self._prefill_cache[key] = jax.jit(fn)
            self.stats.prefill_compiles += 1
        return self._prefill_cache[key]

    def _prefill_group(self, group: List[Request], free: List[int]):
        w = len(group)
        wb = _pow2_bucket(w, 1, self.n_slots)
        lens = [len(r.prompt) for r in group]
        if self.api.ragged_prefill:
            pl = _pow2_bucket(max(lens), min(8, self.max_len), self.max_len)
        else:
            pl = lens[0]                  # equal-length group, exact
        toks = np.zeros((wb, pl), np.int32)
        lengths = np.ones((wb,), np.int32)
        for i, r in enumerate(group):
            toks[i, : len(r.prompt)] = r.prompt
            lengths[i] = len(r.prompt)
        fn = self._get_prefill(wb, pl)
        logits, wave_cache = fn(self.params, jnp.asarray(toks),
                                jnp.asarray(lengths))
        first = self._sample(logits)
        src, dst = [], []
        for i, r in enumerate(group):
            r.tokens.append(int(first[i]))
            self.stats.admitted += 1
            self.stats.prefill_tokens += int(lengths[i])
            if self._stop_reason(r) is not None:
                self._finish(r)           # EOS/max_new on the first token
                continue
            slot = free.pop(0)
            self.slots[slot] = r
            src.append(i)
            dst.append(slot)
        if src:
            self.cache = self._writer(self.cache, wave_cache,
                                      jnp.asarray(src, jnp.int32),
                                      jnp.asarray(dst, jnp.int32))
        self.stats.prefill_waves += 1

    def _write_wave(self, cache, wave_cache, src, dst):
        """Copy wave rows `src` into engine slots `dst` on each leaf's
        declared batch axis (api.cache_spec)."""
        def put(full, one, ax):
            vals = jnp.take(one, src, axis=ax)
            idx = (slice(None),) * ax + (dst,)
            return full.at[idx].set(vals.astype(full.dtype))
        return jax.tree_util.tree_map(put, cache, wave_cache,
                                      self.api.cache_spec)

    # -- sampling --------------------------------------------------------------
    def _sample(self, logits):
        logits = jnp.asarray(logits)
        if logits.ndim == 3:              # [B, S, V]: sample the last position
            logits = logits[:, -1, :]
        logits = logits[..., : self.cfg.vocab_size]
        if self.greedy:
            return np.asarray(jnp.argmax(logits, -1))
        self.rng, k = jax.random.split(self.rng)
        return np.asarray(jax.random.categorical(k, logits))

    # -- stop conditions -------------------------------------------------------
    def _stop_reason(self, r: Request) -> Optional[str]:
        if self.eos_id is not None and r.tokens[-1] == self.eos_id:
            return "eos"
        if len(r.tokens) >= r.max_new:
            return "max_new"
        # next decode would write at pos = prompt + generated - 1
        if len(r.prompt) + len(r.tokens) - 1 >= self.max_len:
            r.truncated = True
            return "cache_full"
        return None

    def _finish(self, r: Request):
        r.done = True
        self.finished.append(r)
        self.stats.finished += 1
        if r.truncated:
            self.stats.truncated += 1

    # -- decode ----------------------------------------------------------------
    def step(self) -> bool:
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        while not active and self.queue:
            # a whole wave can finish at prefill (EOS/max_new on the first
            # token); keep admitting so queued work is never stranded
            self._admit()
            active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return False
        last = np.zeros((self.n_slots,), np.int32)
        for i in active:
            last[i] = self.slots[i].tokens[-1]
        logits, self.cache = self._decode(self.params, jnp.asarray(last),
                                          self.cache)
        nxt = self._sample(logits)
        self.stats.steps += 1
        self.stats.decode_tokens += len(active)
        self.stats.occupancy_sum += len(active) / self.n_slots
        for i in active:
            r = self.slots[i]
            r.tokens.append(int(nxt[i]))
            if self._stop_reason(r) is not None:
                self._finish(r)
                self.slots[i] = None
        return True

    def run(self, max_steps: int = 10000):
        while (self.queue or any(s is not None for s in self.slots)) \
                and max_steps > 0:
            self.step()
            max_steps -= 1
        return self.finished

    def generate(self, prompts, max_new: int = 32, max_steps: int = 10000,
                 return_requests: bool = False):
        """Serve `prompts`; returns one token list per prompt (in order).

        Requests still in flight after `max_steps` are cancelled: they come
        back with partial tokens and `truncated=True`, and their slots/queue
        entries are released so a later `generate()` starts clean instead of
        resuming (and mutating) already-returned results.
        `return_requests=True` returns the Request objects (tokens +
        truncated/prompt_truncated flags)."""
        start = len(self.finished)
        ids = [self.submit(p, max_new) for p in prompts]
        want = set(ids)
        self.run(max_steps)
        new = self.finished[start:]
        by_id = {r.rid: r for r in new}
        out = []
        for rid in ids:
            r = by_id.get(rid)
            if r is None:
                r = self._cancel(rid)
            out.append(r)
        # results are handed to the caller — drop them from the engine log so
        # a long-lived engine doesn't accumulate every request ever served
        del self.finished[start:]
        self.finished.extend(r for r in new if r.rid not in want)
        return out if return_requests else [r.tokens for r in out]

    def _cancel(self, rid: int) -> Request:
        """Evict an in-flight/queued request, returning it flagged truncated."""
        for i, s in enumerate(self.slots):
            if s is not None and s.rid == rid:
                self.slots[i] = None
                s.truncated = True
                self.stats.truncated += 1
                return s
        for r in self.queue:
            if r.rid == rid:
                self.queue.remove(r)
                r.truncated = True
                self.stats.truncated += 1
                return r
        raise KeyError(f"request {rid} not found")
