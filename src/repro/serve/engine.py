"""Batched serving engine with slot-based continuous batching (lite).

The AxLLM deployment surface: `ServeEngine(..., quantize=True)` converts the
trained params post-training (zero setup, paper §I) to int8 codes and every
linear runs through the fused dequant-matmul path. Decoding is batched across
`n_slots` request slots; finished slots are freed and refilled from the
queue. Prefill runs per-wave (all pending requests padded to a common length)
and is written into the batched cache slot-wise; decode advances all active
slots one token per `step()`.

Slot insertion handles any cache pytree: every array whose dim-k equals
n_slots at the engine's recorded batch axis is written at that axis (cache
layouts put batch right after the stacked-layer leading dims; we detect the
axis once from init_cache shapes).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.axllm_linear import deploy_quantize
from repro.core.quantization import QuantConfig
from repro.models.model import ModelAPI, get_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new: int = 32
    tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


def _batch_axis_of(shape, n_slots, max_len):
    """First axis equal to n_slots (skipping stacked-layer leading dims that
    could coincide is resolved by preferring the axis whose next dim is
    max_len when present)."""
    cands = [i for i, d in enumerate(shape) if d == n_slots]
    if not cands:
        return None
    for i in cands:
        if i + 1 < len(shape) and shape[i + 1] == max_len:
            return i
    return cands[0]


class ServeEngine:
    def __init__(self, cfg, params, *, n_slots: int = 4, max_len: int = 512,
                 quantize: bool = False, quant_bits: int = 8,
                 impl: str = "auto", greedy: bool = True, seed: int = 0):
        self.cfg = cfg
        self.api: ModelAPI = get_model(cfg, impl=impl)
        if quantize:
            params = deploy_quantize(
                params, QuantConfig(bits=quant_bits, mode="affine",
                                    granularity="per_channel"))
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.greedy = greedy
        self.rng = jax.random.PRNGKey(seed)
        self.cache = self.api.init_cache(n_slots, max_len)
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._rid = 0
        self._decode = jax.jit(self.api.decode)
        self._prefill_cache = {}

    # -- request management ---------------------------------------------------
    def submit(self, prompt, max_new: int = 32) -> int:
        req = Request(self._rid, np.asarray(prompt, np.int32), max_new)
        self._rid += 1
        self.queue.append(req)
        return req.rid

    def _free_slots(self):
        return [i for i, s in enumerate(self.slots) if s is None]

    # -- prefill wave ----------------------------------------------------------
    def _admit(self):
        free = self._free_slots()
        if not free or not self.queue:
            return
        # one wave = equal-length prompts (exact positions without padding
        # bookkeeping; mixed lengths wait for the next wave)
        length = len(self.queue[0].prompt)
        wave = [r for r in self.queue if len(r.prompt) == length][: len(free)]
        for r in wave:
            self.queue.remove(r)
        toks = np.stack([r.prompt for r in wave])
        wave_cache = self.api.init_cache(len(wave), self.max_len)
        logits, wave_cache = self.api.prefill(
            self.params, {"tokens": jnp.asarray(toks)}, wave_cache)
        first = self._sample(logits)
        for i, r in enumerate(wave):
            slot = free[i]
            self.slots[slot] = r
            r.tokens.append(int(first[i]))
            self._write_slot(wave_cache, i, slot)

    def _write_slot(self, wave_cache, src: int, dst: int):
        def put(full, one):
            ax = _batch_axis_of(full.shape, self.n_slots, self.max_len)
            if ax is None:
                return full
            # the wave cache has the wave size at the same axis
            src_slice = jax.lax.index_in_dim(one, src, ax, keepdims=False)
            idx = (slice(None),) * ax + (dst,)
            return full.at[idx].set(src_slice.astype(full.dtype))
        self.cache = jax.tree_util.tree_map(put, self.cache, wave_cache)

    def _sample(self, logits):
        logits = logits[:, : self.cfg.vocab_size]
        if self.greedy:
            return np.asarray(jnp.argmax(logits, -1))
        self.rng, k = jax.random.split(self.rng)
        return np.asarray(jax.random.categorical(k, logits))

    # -- decode ----------------------------------------------------------------
    def step(self):
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return False
        last = np.zeros((self.n_slots,), np.int32)
        for i in active:
            last[i] = self.slots[i].tokens[-1]
        logits, self.cache = self._decode(self.params, jnp.asarray(last),
                                          self.cache)
        nxt = self._sample(logits)
        for i in active:
            r = self.slots[i]
            r.tokens.append(int(nxt[i]))
            if len(r.tokens) >= r.max_new:
                r.done = True
                self.finished.append(r)
                self.slots[i] = None
        return True

    def run(self, max_steps: int = 10000):
        while (self.queue or any(self.slots)) and max_steps > 0:
            self.step()
            max_steps -= 1
        return self.finished

    def generate(self, prompts, max_new: int = 32):
        ids = [self.submit(p, max_new) for p in prompts]
        self.run()
        by_id = {r.rid: r for r in self.finished}
        return [by_id[i].tokens for i in ids]
