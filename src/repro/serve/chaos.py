"""Serving fault-injection harness (the `train/fault_tolerance.py` of
the serving stack).

Each scenario drives a `ServeEngine` through a specific failure mode —
pool exhaustion, prefix-eviction storms, injected dispatch faults,
bursty priority arrivals against a bounded queue, adapter evict races,
long-prompt storms under a chunked-prefill budget, cancellation storms
against streaming clients — and then *audits* the engine against two
invariants the robustness layer guarantees:

1. **Zero lost requests.** Every submitted request finishes exactly once
   with a ``finish_reason`` (generation / rejected / expired /
   cancelled); the engine ends drained (no slots held, no queue, no
   leaked pool blocks, no adapter pins) and the pager's
   refcount/free-list bookkeeping passes ``check_consistency`` after
   every step.
2. **Zero corrupted requests.** Every request that finished with a
   generation reason produced tokens *bit-identical* to a fault-free
   reference run of the same prompt — including requests that were
   preempted, swapped out, and restored mid-decode (or mid-prefill
   under a chunked-prefill budget). A request cut short mid-stream
   (expired past an execution deadline, or cancelled) must hold a
   *prefix* of the reference tokens — partial, never corrupted.

Faults are injected three ways, all deterministic:

- :class:`ServeFailureInjector` — the engine's ``fault_hook``; raises
  ``RuntimeError`` at chosen dispatch ordinals, right before the jitted
  prefill/decode call (mirrors ``FailureInjector`` in
  `repro.train.fault_tolerance`). The driver retries the step, which
  must be a clean no-op-replay (admission rolled back and requeued,
  decode pager commit idempotent).
- :class:`BlockThief` — allocates pool blocks that belong to no slot and
  no index entry, emulating pressure the LRU eviction cannot relieve;
  admission must *defer* and decode planning must *preempt* instead of
  corrupting state, and everything restores once the thief returns the
  blocks.
- The scenario script itself: eviction storms (``evict_prefixes``),
  burst arrivals with mixed priorities/deadlines against a small
  ``max_queue``, and `AdapterRegistry.evict` calls racing in-flight
  LoRA requests.

Run the CI smoke lane with ``python -m repro.serve.chaos --smoke``;
``--scenario NAME`` runs one scenario, default runs all. Exit status is
non-zero on any invariant violation.
"""

from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import sys
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import get_model
from repro.serve.engine import ServeEngine

CFG = ModelConfig(name="chaos", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, vocab_pad_multiple=64, dtype="float32")

#: default chaos workload: mixed lengths, shared-prefix pairs
WORKLOAD = [np.arange(8), np.arange(12) + 3, np.arange(31) + 7,
            np.arange(12) + 40, np.arange(8) + 60, np.arange(31) + 90,
            np.arange(20) + 11, np.arange(9) + 120]

MAX_NEW = 6
MAX_LEN = 64
BLOCK = 8


class ServeFailureInjector:
    """Engine ``fault_hook`` raising at chosen dispatch ordinals.

    ``fail_at`` counts calls across the selected ``phases`` ("prefill" /
    "decode", plus "draft" / "verify" on a speculating engine); each
    listed ordinal raises once. The raise happens before the jitted
    dispatch, where the engine guarantees rollback."""

    def __init__(self, fail_at=(), phases=("prefill", "decode")):
        self.remaining = set(fail_at)
        self.phases = set(phases)
        self.calls = 0
        self.raised = 0

    def __call__(self, phase: str):
        if phase not in self.phases:
            return
        self.calls += 1
        if self.calls in self.remaining:
            self.remaining.discard(self.calls)
            self.raised += 1
            raise RuntimeError(
                f"injected {phase} fault at dispatch {self.calls}")


class BlockThief:
    """Steals pool blocks for a window of steps.

    Stolen blocks have no slot and no index entry, so they are invisible
    to LRU eviction — from the engine's view the pool genuinely shrank
    (fragmentation, a co-tenant, a leak). Admission must defer and
    decode planning must preempt while the window lasts."""

    def __init__(self, steal: int, hold_steps: int, start_step: int = 1):
        self.steal = steal
        self.hold_steps = hold_steps
        self.start_step = start_step
        self.step = 0
        self.held: List[int] = []

    def on_step(self, eng: ServeEngine):
        self.step += 1
        if self.step == self.start_step:
            # take the whole free list (not the index: stealing must not
            # itself evict). Progress stays possible because preemption
            # and prefix eviction keep returning blocks to the free list.
            for _ in range(min(self.steal, len(eng.pager._free))):
                self.held.append(eng.pager.alloc())
        if self.step == self.start_step + self.hold_steps:
            self.release(eng)

    def release(self, eng: ServeEngine):
        for b in self.held:
            eng.pager._release_block(b)
        self.held = []


@dataclasses.dataclass
class ChaosReport:
    scenario: str
    submitted: int = 0
    finished: int = 0                 # generation outcomes
    rejected: int = 0
    expired: int = 0
    cancelled: int = 0
    preempted: int = 0
    preempted_prefill: int = 0
    restored: int = 0
    fast_restores: int = 0
    faults_injected: int = 0
    step_retries: int = 0
    lost: int = 0                     # submitted but unaccounted-for
    mismatched: int = 0               # tokens differ from fault-free run
    errors: List[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return self.lost == 0 and self.mismatched == 0 and not self.errors

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ok"] = self.ok
        return d


def _params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


def _reference(params, prompts, max_new=MAX_NEW, **kw) -> List[list]:
    """Fault-free tokens for ``prompts`` on a roomy engine."""
    eng = ServeEngine(CFG, params, n_slots=4, max_len=MAX_LEN, **kw)
    return eng.generate(prompts, max_new=max_new)


def _drive(eng: ServeEngine, report: ChaosReport,
           post_step: Optional[Callable] = None,
           thief: Optional[BlockThief] = None,
           max_retries: int = 200) -> None:
    """Run the engine to drain, retrying steps killed by injected faults
    (anything else propagates — a real bug, not chaos)."""
    while True:
        try:
            while eng.step():
                if post_step is not None:
                    post_step(eng)
                if eng.paged:
                    eng.pager.check_consistency(
                        external=thief.held if thief else ())
            return
        except RuntimeError as e:
            if "injected" not in str(e):
                raise
            report.step_retries += 1
            if report.step_retries > max_retries:
                raise


def _audit(eng: ServeEngine, rid_to_prompt: Dict[int, int],
           reference: List[list], report: ChaosReport) -> None:
    """Check the zero-lost / zero-corrupted invariants post-drain."""
    st = eng.stats
    report.finished = st.finished
    report.rejected = st.rejected
    report.expired = st.expired
    report.cancelled = st.cancelled
    report.preempted = st.preempted
    report.preempted_prefill = st.preempted_prefill
    report.restored = st.restored
    report.fast_restores = st.fast_restores
    seen = {}
    for r in eng.finished:
        if r.rid in seen:
            report.errors.append(f"rid {r.rid} finished twice")
        seen[r.rid] = r
    report.lost = len(set(rid_to_prompt) - set(seen))
    if report.lost:
        report.errors.append(f"{report.lost} request(s) never finished")
    for rid, r in seen.items():
        if r.finish_reason is None:
            report.errors.append(f"rid {rid} finished without a reason")
        if r.finish_reason == "rejected":
            if r.tokens:
                report.errors.append(f"rid {rid} was rejected but has "
                                     f"tokens")
            continue
        if r.finish_reason in ("expired", "cancelled"):
            # cut short mid-stream: whatever the client received must be
            # a prefix of the fault-free tokens — partial, never corrupt
            want = reference[rid_to_prompt[rid]]
            if r.tokens != want[:len(r.tokens)]:
                report.mismatched += 1
                report.errors.append(
                    f"rid {rid} ({r.finish_reason}) tokens {r.tokens} not "
                    f"a prefix of fault-free {want}")
            continue
        want = reference[rid_to_prompt[rid]]
        if r.tokens != want:
            report.mismatched += 1
            report.errors.append(
                f"rid {rid} tokens {r.tokens} != fault-free {want}"
                + (f" (preempted {r.preemptions}x)" if r.preemptions
                   else ""))
    if any(s is not None for s in eng.slots):
        report.errors.append("slots still held after drain")
    if eng.queue:
        report.errors.append("queue non-empty after drain")
    if eng.paged:
        eng.pager.check_consistency()
        for slot in range(eng.n_slots):
            if eng.pager.slot_blocks(slot):
                report.errors.append(f"slot {slot} leaked pool blocks")
    if eng.registry is not None and any(eng.registry._refs):
        report.errors.append(f"adapter pins leaked: "
                             f"{list(eng.registry._refs)}")


def _submit_all(eng, prompts, report, **kw) -> Dict[int, int]:
    rid_to_prompt = {}
    for i, p in enumerate(prompts):
        rid_to_prompt[eng.submit(p, MAX_NEW, **kw)] = i
        report.submitted += 1
    return rid_to_prompt


# ---------------------------------------------------------------------------
# Scenarios
# ---------------------------------------------------------------------------

def scenario_pool_exhaustion(params, smoke: bool) -> ChaosReport:
    """A thief drains the free list mid-serve: admission defers, decode
    planning preempts victims (swap-out), and everything restores
    token-identically once blocks return."""
    report = ChaosReport("pool_exhaustion")
    prompts = WORKLOAD[:6] if smoke else WORKLOAD
    reference = _reference(params, prompts)
    # decode_chunk=1 keeps requests in flight across steps so the
    # pressure window actually catches them mid-decode
    eng = ServeEngine(CFG, params, n_slots=4, max_len=MAX_LEN, paged=True,
                      kv_block_size=BLOCK, decode_chunk=1)
    # steal essentially the whole free list for several steps
    thief = BlockThief(steal=10_000, hold_steps=6)
    rid_to_prompt = _submit_all(eng, prompts, report)
    try:
        _drive(eng, report, post_step=thief.on_step, thief=thief)
    finally:
        thief.release(eng)
    _drive(eng, report)               # drain anything deferred at the end
    _audit(eng, rid_to_prompt, reference, report)
    if report.preempted == 0 and report.errors == []:
        # the thief must actually bite or the scenario tests nothing
        report.errors.append("pool pressure never triggered a preemption "
                             "or deferral (thief too weak?)")
    return report


def scenario_eviction_storm(params, smoke: bool) -> ChaosReport:
    """Every cached prefix is evicted after every step, so preempted
    requests can never fast-restore — the recompute path must still be
    token-identical."""
    report = ChaosReport("eviction_storm")
    prompts = WORKLOAD[:6] if smoke else WORKLOAD
    reference = _reference(params, prompts)
    eng = ServeEngine(CFG, params, n_slots=4, max_len=MAX_LEN, paged=True,
                      kv_block_size=BLOCK, decode_chunk=1)
    thief = BlockThief(steal=10_000, hold_steps=6)

    def storm(e):
        thief.on_step(e)
        e.pager.evict_prefixes()      # kill every index-only block

    rid_to_prompt = _submit_all(eng, prompts, report)
    try:
        _drive(eng, report, post_step=storm, thief=thief)
    finally:
        thief.release(eng)
    _drive(eng, report)
    _audit(eng, rid_to_prompt, reference, report)
    if report.fast_restores:
        report.errors.append("fast restore should be impossible under a "
                             "full eviction storm")
    if report.preempted == 0 and report.errors == []:
        report.errors.append("the storm never forced a preemption")
    return report


def scenario_dispatch_faults(params, smoke: bool) -> ChaosReport:
    """RuntimeError right before jitted prefill/decode dispatches: every
    faulted step must retry cleanly (admission rolled back + requeued,
    decode commit idempotent) with no lost work."""
    report = ChaosReport("dispatch_faults")
    prompts = WORKLOAD[:6] if smoke else WORKLOAD
    reference = _reference(params, prompts)
    inj = ServeFailureInjector(fail_at=(1, 3, 4, 7))
    eng = ServeEngine(CFG, params, n_slots=2, max_len=MAX_LEN, paged=True,
                      kv_block_size=BLOCK, fault_hook=inj)
    rid_to_prompt = _submit_all(eng, prompts, report)
    _drive(eng, report)
    report.faults_injected = inj.raised
    _audit(eng, rid_to_prompt, reference, report)
    if inj.raised == 0:
        report.errors.append("no fault was ever injected")
    return report


def scenario_burst_arrivals(params, smoke: bool) -> ChaosReport:
    """Bursts against a bounded queue with mixed priorities and
    deadlines (virtual clock): low-priority work is evicted/expired in a
    controlled way, high-priority arrivals preempt running slots, and
    whatever finishes is token-identical."""
    report = ChaosReport("burst_arrivals")
    prompts = WORKLOAD[:6] if smoke else WORKLOAD
    reference = _reference(params, prompts)
    clock = itertools.count(0)        # 1 virtual second per observation
    eng = ServeEngine(CFG, params, n_slots=2, max_len=MAX_LEN, paged=True,
                      kv_block_size=BLOCK, max_queue=3, admission="evict",
                      decode_chunk=1, clock=lambda: float(next(clock)))
    rid_to_prompt = {}
    half = len(prompts) // 2
    # burst 1: low priority, generous deadlines
    for i, p in enumerate(prompts[:half]):
        rid = eng.submit(p, MAX_NEW, priority=0, deadline_s=10_000.0)
        rid_to_prompt[rid] = i
        report.submitted += 1
    eng.step()
    # burst 2: high priority — preempts burst-1 slots, evicts queued ones
    for i, p in enumerate(prompts[half:]):
        rid = eng.submit(p, MAX_NEW, priority=5)
        rid_to_prompt[rid] = half + i
        report.submitted += 1
    # one urgent straggler with an already-hopeless deadline: it outranks
    # everyone (so the evict policy seats it in the full queue) but must
    # expire at the next admission scan, not run
    rid = eng.submit(prompts[0], MAX_NEW, priority=9, deadline_s=0.0)
    rid_to_prompt[rid] = 0
    report.submitted += 1
    _drive(eng, report)
    _audit(eng, rid_to_prompt, reference, report)
    if report.expired == 0:
        report.errors.append("the deadline-0 request did not expire")
    if report.preempted == 0 and report.errors == []:
        report.errors.append("the high-priority burst never preempted a "
                             "running low-priority slot")
    return report


def scenario_adapter_race(params, smoke: bool) -> ChaosReport:
    """`AdapterRegistry.evict` racing in-flight LoRA requests: evicting a
    pinned adapter must raise (not corrupt), pins must release on finish
    — including requests that died to an injected prefill fault and were
    retried — and the evict must succeed after the drain."""
    from repro.launch.serve import make_synthetic_adapters
    report = ChaosReport("adapter_race")
    reg, names = make_synthetic_adapters(CFG, n=2)
    inj = ServeFailureInjector(fail_at=(2,), phases=("prefill",))
    # decode_chunk=1 so the first step leaves the slot requests mid-
    # decode with their pins held — otherwise one chunk finishes them
    # and there is no race left to exercise
    eng = ServeEngine(CFG, params, n_slots=2, max_len=MAX_LEN,
                      quantize=True, adapters=reg, paged=True,
                      kv_block_size=BLOCK, fault_hook=inj, decode_chunk=1)
    prompts = [np.arange(8), np.arange(12) + 3, np.arange(8) + 60,
               np.arange(9) + 120]
    adapters = [names[0], names[1], names[0], None]
    ref_reg, ref_names = make_synthetic_adapters(CFG, n=2)
    ref_eng = ServeEngine(CFG, params, n_slots=4, max_len=MAX_LEN,
                          quantize=True, adapters=ref_reg)
    reference = ref_eng.generate(prompts, max_new=MAX_NEW,
                                 adapters=[None if a is None else
                                           {names[0]: ref_names[0],
                                            names[1]: ref_names[1]}[a]
                                           for a in adapters])
    rid_to_prompt = {}
    for i, (p, a) in enumerate(zip(prompts, adapters)):
        rid_to_prompt[eng.submit(p, MAX_NEW, adapter=a)] = i
        report.submitted += 1
    raced = 0
    try:
        eng.step()                    # adapters now pinned in-flight
    except RuntimeError as e:
        if "injected" not in str(e):
            raise
        report.step_retries += 1
    for name in names:                # the race: evict while pinned
        try:
            reg.evict(name)
            report.errors.append(f"evict({name!r}) succeeded while pinned")
        except RuntimeError:
            raced += 1
    _drive(eng, report)
    report.faults_injected = inj.raised
    _audit(eng, rid_to_prompt, reference, report)
    if raced == 0:
        report.errors.append("no pinned-evict race was exercised")
    for name in names:                # pins released: evict is legal now
        try:
            reg.evict(name)
        except RuntimeError as e:
            report.errors.append(f"evict({name!r}) still pinned after "
                                 f"drain: {e}")
    return report


def scenario_speculation_storm(params, smoke: bool) -> ChaosReport:
    """Speculative decoding under fire: faults injected right before the
    draft and verify dispatches, plus a block thief forcing preemption of
    mid-flight *speculating* slots. A retried round must replay
    bit-identically (the draft scan is deterministic and both caches'
    cursors are host-reset every round), rollback must return every
    rejected-tail block, and the final tokens must equal a fault-free
    TARGET-ONLY run — the strongest form of the zero-corruption
    invariant, since it also proves speculation changes nothing."""
    report = ChaosReport("speculation_storm")
    prompts = WORKLOAD[:6] if smoke else WORKLOAD
    reference = _reference(params, prompts)     # target-only, fault-free
    inj = ServeFailureInjector(fail_at=(1, 3, 4, 7),
                               phases=("draft", "verify"))
    eng = ServeEngine(CFG, params, n_slots=4, max_len=MAX_LEN, paged=True,
                      kv_block_size=BLOCK, fault_hook=inj,
                      speculate=True, spec_k=4)
    thief = BlockThief(steal=10_000, hold_steps=4, start_step=2)
    rid_to_prompt = _submit_all(eng, prompts, report)
    try:
        _drive(eng, report, post_step=thief.on_step, thief=thief)
    finally:
        thief.release(eng)
    _drive(eng, report)
    report.faults_injected = inj.raised
    _audit(eng, rid_to_prompt, reference, report)
    if inj.raised == 0:
        report.errors.append("no draft/verify fault was ever injected")
    if report.preempted == 0 and report.errors == []:
        report.errors.append("the thief never preempted a speculating "
                             "slot")
    if report.fast_restores:
        report.errors.append("fast restore must be gated off under "
                             "speculation (stale draft KV)")
    if eng.stats.accepted_draft_tokens == 0 and report.errors == []:
        report.errors.append("speculation never accepted a draft token "
                             "(draft hopelessly misaligned?)")
    return report


def scenario_long_prompt_storm(params, smoke: bool) -> ChaosReport:
    """Long prompts under a chunked-prefill budget while a block thief
    drains the pool: no step may prefill more than the budget, the
    mid-prefill victim must be preempted with its consumed prefix
    published (never swapped), and everything — including the long
    prompts restored from a partial cursor — must finish
    token-identical to an unbudgeted fault-free run."""
    report = ChaosReport("long_prompt_storm")
    budget = 16
    max_len = 128
    # shorts first (small rids decode early), longs last (youngest →
    # preferred preemption victims while still mid-prefill)
    prompts = [np.arange(8), np.arange(12) + 40, np.arange(9) + 120,
               np.arange(100) % 256]
    if not smoke:
        prompts += [np.arange(20) + 11, np.arange(100) + 50]
    ref = ServeEngine(CFG, params, n_slots=4, max_len=max_len)
    reference = ref.generate(prompts, max_new=MAX_NEW)
    eng = ServeEngine(CFG, params, n_slots=2, max_len=max_len, paged=True,
                      kv_block_size=BLOCK, decode_chunk=1,
                      prefill_budget=budget)
    thief = BlockThief(steal=10_000, hold_steps=5, start_step=1)
    seen = {"prefill_tokens": 0}

    def storm(e):
        thief.on_step(e)
        delta = e.stats.prefill_tokens - seen["prefill_tokens"]
        seen["prefill_tokens"] = e.stats.prefill_tokens
        if delta > budget:
            report.errors.append(
                f"a step prefilled {delta} tokens > budget {budget}")

    rid_to_prompt = _submit_all(eng, prompts, report)
    try:
        _drive(eng, report, post_step=storm, thief=thief)
    finally:
        thief.release(eng)
    _drive(eng, report, post_step=storm)
    _audit(eng, rid_to_prompt, reference, report)
    if report.preempted_prefill == 0 and report.errors == []:
        report.errors.append("the storm never preempted a mid-prefill "
                             "slot (thief too weak / prompts too short?)")
    if eng.stats.prefill_chunks <= report.submitted and report.errors == []:
        report.errors.append("prefill was never actually chunked")
    return report


def scenario_cancel_storm(params, smoke: bool) -> ChaosReport:
    """Cancellation at every lifecycle point — while queued, mid-prefill
    chunk, mid-decode, and a streaming client whose callback raises
    StopStream — with the rest of the workload still running: every
    teardown must balance the books (slot, blocks, pins), a cancelled
    stream may hold only a prefix of the fault-free tokens, and the
    surviving streams must stay bit-identical."""
    from repro.serve.engine import StopStream
    report = ChaosReport("cancel_storm")
    prompts = WORKLOAD[:6] if smoke else WORKLOAD
    reference = _reference(params, prompts)
    # budget=8 forces the 31-token prompt through multiple chunks (a
    # mid-prefill cancel window); decode_chunk=1 keeps streams in
    # flight across steps (a mid-decode cancel window)
    eng = ServeEngine(CFG, params, n_slots=2, max_len=MAX_LEN, paged=True,
                      kv_block_size=BLOCK, decode_chunk=1,
                      prefill_budget=8)
    hangup = {"tokens": 0}

    def client(req, tok):
        hangup["tokens"] += 1
        if hangup["tokens"] >= 2:
            raise StopStream()         # client went away mid-stream

    rid_to_prompt = {}
    for i, p in enumerate(prompts):
        kw = {"on_token": client} if i == 1 else {}
        rid_to_prompt[eng.submit(p, MAX_NEW, **kw)] = i
        report.submitted += 1
    by_prompt = {v: k for k, v in rid_to_prompt.items()}
    rid_prefill, rid_decode = by_prompt[2], by_prompt[3]
    if not eng.cancel(by_prompt[4]):   # cancel while still queued
        report.errors.append("queued cancel returned False")
    fired = {"prefill": False, "decode": False}

    def storm(e):
        if not fired["prefill"]:
            for s in e.slots:
                if s is not None and s.rid == rid_prefill and s.prefilling:
                    e.cancel(rid_prefill)
                    fired["prefill"] = True
        if not fired["decode"]:
            for s in e.slots:
                if (s is not None and s.rid == rid_decode
                        and not s.prefilling and s.tokens):
                    e.cancel(rid_decode)
                    fired["decode"] = True

    _drive(eng, report, post_step=storm)
    _audit(eng, rid_to_prompt, reference, report)
    for point, did in fired.items():
        if not did:
            report.errors.append(f"mid-{point} cancel never found its "
                                 f"target in a slot")
    if hangup["tokens"] < 2:
        report.errors.append("the StopStream client never saw 2 tokens")
    if report.cancelled != 4:
        report.errors.append(f"expected 4 cancelled, got "
                             f"{report.cancelled}")
    # with every stream torn down or finished, evicting the cached
    # prefixes must drain the pool to zero — nothing leaked
    eng.pager.evict_prefixes()
    if eng.pager.blocks_in_use:
        report.errors.append(f"{eng.pager.blocks_in_use} pool blocks "
                             f"leaked after cancel teardown")
    return report


SCENARIOS = {
    "pool_exhaustion": scenario_pool_exhaustion,
    "eviction_storm": scenario_eviction_storm,
    "dispatch_faults": scenario_dispatch_faults,
    "burst_arrivals": scenario_burst_arrivals,
    "adapter_race": scenario_adapter_race,
    "speculation_storm": scenario_speculation_storm,
    "long_prompt_storm": scenario_long_prompt_storm,
    "cancel_storm": scenario_cancel_storm,
}


def run(scenarios=None, smoke: bool = False) -> List[ChaosReport]:
    params = _params()
    reports = []
    for name in scenarios or SCENARIOS:
        reports.append(SCENARIOS[name](params, smoke))
    return reports


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", choices=sorted(SCENARIOS), default=None,
                    help="run one scenario (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="smaller workloads (CI lane)")
    args = ap.parse_args(argv)
    names = [args.scenario] if args.scenario else None
    reports = run(names, smoke=args.smoke)
    print(json.dumps([r.as_dict() for r in reports], indent=2))
    bad = [r for r in reports if not r.ok]
    for r in bad:
        print(f"FAIL {r.scenario}: {'; '.join(r.errors)}", file=sys.stderr)
    print(f"chaos: {len(reports) - len(bad)}/{len(reports)} scenarios ok")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
