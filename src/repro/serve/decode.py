"""On-device multi-token decode: the chunked scan that kills the per-token
host round-trip.

The per-token serving loop pays one dispatch, one logits device->host sync,
one NumPy sample and one per-slot Python append for *every* generated token
— the dominant tax on small-batch decode (kernel-launch/host-sync overhead,
Donisch et al.). :func:`decode_steps` instead runs ``n`` decode steps inside
one ``jax.lax.scan``: sampling (greedy argmax or categorical with a per-step
split PRNG key) happens on device, per-slot stop conditions are tracked in a
boolean mask, and the KV/recurrent cache stays resident in the carry (the
engine donates it, so the buffer is reused in place). The host syncs once
per chunk — a ``[n, B]`` token block plus its validity mask — to harvest
finished slots and admit the next prefill wave.

Stop-mask semantics (mirrors ``ServeEngine._stop_reason`` exactly):
  - ``next == eos_id``          (EOS, when an eos id is configured)
  - ``gen >= max_new``          (per-slot generation budget)
  - ``cache["pos"] >= max_len`` (cache full: the next decode would write
                                 out of bounds -> flagged truncated by the
                                 engine at harvest)
A stopped slot keeps riding through the scan (its row computes garbage that
is masked out and overwritten by the next prefill wave into that slot);
``valid`` is a per-slot prefix, so harvesting is "append tokens until the
first False". Token-for-token equivalence with ``n`` sequential
``api.decode`` calls is property-tested per family in
tests/test_decode_steps.py.

Preemption happens only at chunk boundaries: the engine reserves every
block the *whole* chunk window may touch before dispatching
(plan-then-commit on the paged pool), so a running scan never hits an
allocation failure mid-chunk. A slot preempted between chunks has its KV
swapped out and restored bit-identically — the scan itself never observes
a half-evicted cache.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class DecodeChunk(NamedTuple):
    """Result of a chunked decode dispatch."""
    tokens: jax.Array      # [n, B] int32 sampled tokens (garbage where ~valid)
    valid: jax.Array       # [n, B] bool: slot was active when step ran
    last: jax.Array        # [B] int32 last valid token per slot
    cache: object          # advanced cache pytree (carry-resident)
    rng: jax.Array         # PRNG key after n on-device splits
    stop_mask: jax.Array   # [B] bool: slot finished inside this chunk
    gen: jax.Array         # [B] int32 tokens generated so far (incl. prefill)


def verify_steps(decode_fn, params, last, drafts, cache, *,
                 vocab_size: int):
    """Teacher-forced scan for speculative verification: ONE dispatch that
    feeds ``[last, d_1, ..., d_k]`` through the target model and returns
    its greedy token after each input.

    Where :func:`decode_steps` feeds each step the token *it* sampled,
    the verify scan feeds the *draft's* proposals — the same scan body,
    cache carry and on-device argmax, with the sampled-token feedback
    edge replaced by the teacher-forced input row. ``targets[i]`` is the
    target's greedy choice after consuming input ``i``, so the host's
    accept-longest-prefix rule (``repro.serve.speculative``) compares
    ``targets[:k]`` against the drafts and always has ``targets[m]`` as
    the correction/bonus token.

    The scan has NO stop machinery on purpose: EOS / budget / cache-full
    are re-derived on the host while *appending* the accepted tokens
    (mirroring ``ServeEngine._stop_reason``), because a stop may land
    mid-acceptance and everything after it must be discarded. KV written
    for rejected positions is rolled back by the engine (cursor reset /
    ``PagedKVCache.truncate``), never read.

    decode_fn: ``(params, token [B], cache) -> (logits [B, V], cache)``.
    last:      [B] int32 last accepted token per slot.
    drafts:    [k, B] int32 draft proposals (k == 0 verifies nothing and
               degenerates to one plain greedy decode step).
    Returns ``(targets [k+1, B] int32, cache)`` with the cache advanced
    by k+1 positions (the engine resets per-row cursors afterwards).
    """
    inputs = jnp.concatenate(
        [jnp.asarray(last, jnp.int32)[None, :],
         jnp.asarray(drafts, jnp.int32)], axis=0)

    def step(cache, tok):
        logits, cache = decode_fn(params, tok, cache)
        logits = logits[..., :vocab_size]
        return cache, jnp.argmax(logits, axis=-1).astype(jnp.int32)

    cache, targets = jax.lax.scan(step, cache, inputs)
    return targets, cache


def decode_steps(decode_fn, params, last, cache, rng, stop_mask, gen,
                 max_new, *, n: int, vocab_size: int, max_len: int,
                 eos_id: Optional[int] = None,
                 greedy: bool = True) -> DecodeChunk:
    """Run up to ``n`` decode steps of ``decode_fn`` entirely on device.

    decode_fn:  ``(params, token [B], cache) -> (logits [B, V], cache)``
                (a ``ModelAPI.decode``; the cache must carry a per-row
                ``"pos"`` cursor, which all families do). Per-slot state
                beyond the carry — e.g. the multi-LoRA ``[B]``
                adapter-index row — is closed over by the engine's
                wrapper, so the scan itself stays adapter-agnostic.
    last:       [B] int32 last sampled token per slot.
    stop_mask:  [B] bool; True rows are dead (empty or finished slots).
    gen:        [B] int32 tokens generated so far (prefill token included).
    max_new:    [B] int32 per-slot generation budget.
    ``n``, ``vocab_size``, ``max_len``, ``eos_id`` and ``greedy`` are
    trace-time constants (jit-static at the engine's call site).
    """

    def step(carry, _):
        last, cache, rng, stop, gen = carry
        logits, cache = decode_fn(params, last, cache)
        logits = logits[..., :vocab_size]
        if greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            nxt = jax.random.categorical(k, logits).astype(jnp.int32)
        active = ~stop
        nxt = jnp.where(active, nxt, last)
        gen = gen + active.astype(jnp.int32)
        hit = (gen >= max_new) | (cache["pos"] >= max_len)
        if eos_id is not None:
            hit = hit | (nxt == eos_id)
        stop = stop | (active & hit)
        return (nxt, cache, rng, stop, gen), (nxt, active)

    carry = (jnp.asarray(last, jnp.int32), cache, rng,
             jnp.asarray(stop_mask, bool), jnp.asarray(gen, jnp.int32))
    (last, cache, rng, stop, gen), (toks, valid) = jax.lax.scan(
        step, carry, None, length=n)
    return DecodeChunk(toks, valid, last, cache, rng, stop, gen)
