"""Host-side manager for the block-paged KV cache: free-list allocation,
copy-on-write refcounts, and the radix prefix index.

The device side is a shared pool ``[n_layers, n_blocks, block, Hk, hd]``
plus per-slot block tables (``repro.models.attention.init_paged_cache``);
everything about *ownership* lives here, in plain Python, because it
changes at request granularity (admission / chunk boundaries / finish),
never inside a jitted step:

- **Free-list allocator.** Blocks are fixed-size (``block_size`` tokens)
  and handed out from a free list. Block 0 is reserved as the *trash
  block*: unallocated table entries point at it and out-of-range writes
  (stopped slots riding through a decode scan) are routed to it, so device
  code never needs a branch for "no block here".
- **Refcounts + copy-on-write.** A block's refcount counts the slots whose
  tables reference it plus one if the prefix index holds it. Writes only
  ever target uniquely-owned blocks: :meth:`prepare_decode` detects a
  shared block in the write window and returns ``(src, dst)`` copy pairs
  for the engine to execute as one batched device copy (``cow_copies``
  in EngineStats). Today's engine flows never actually share a *writable*
  block — hits are full blocks strictly behind the write cursor and
  partial blocks are never published — so the CoW branch is a defensive
  invariant (exercised directly in tests/test_paged.py) that keeps the
  allocator correct for sharing modes the scheduler may add later, e.g.
  forked/parallel sampling from one prompt.
- **Radix prefix index.** A trie over full *blocks* of token ids (one edge
  per ``block_size``-token chunk — a node's path from the root is the
  exact token prefix its block's KV was computed under, which is what
  makes the KV reusable at all). ``match()`` walks the longest cached
  prefix for a new prompt and acquires the hit blocks for a slot;
  ``insert()`` publishes a finished prefill/generation so future requests
  reuse it. This extends the paper's computation-reuse principle from
  weight products (the Result Cache) one level up, to whole KV rows:
  requests sharing a system prompt or few-shot template hit the index and
  skip recomputing that prefill entirely.
- **Eviction.** When the free list runs dry, leaf nodes of the radix tree
  that no slot references (refcount == 1, held only by the index) are
  evicted in LRU order. Blocks referenced by live slots are never evicted,
  so sizing the pool at ``n_slots * max_blocks_per_slot`` unique blocks
  (+ trash + one copy-on-write spare) guarantees allocation never fails.

Partial blocks are never indexed or matched: a hit is always a whole
number of blocks, and is additionally capped at ``len(prompt) - 1`` so
prefill always has at least one suffix token to produce logits from.
"""

from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

TRASH_BLOCK = 0


class _RadixNode:
    """One cached block: edge key = its ``block_size`` token ids."""
    __slots__ = ("children", "parent", "key", "block", "last_used")

    def __init__(self, parent: Optional["_RadixNode"], key, block: int):
        self.children: Dict[tuple, _RadixNode] = {}
        self.parent = parent
        self.key = key
        self.block = block
        self.last_used = 0


class PagedKVCache:
    """Block allocator + prefix index for one engine's KV pool.

    Device arrays are owned by the engine; this class tracks which pool
    blocks exist, who references them, and which token prefixes they hold.
    """

    def __init__(self, *, n_slots: int, n_blocks: int, block_size: int,
                 max_blocks_per_slot: int, prefix_cache: bool = True):
        if block_size < 1 or block_size & (block_size - 1):
            raise ValueError(f"block_size must be a power of two, got "
                             f"{block_size}")
        min_blocks = n_slots * max_blocks_per_slot + 2  # + trash + CoW spare
        if n_blocks < min_blocks:
            raise ValueError(
                f"n_blocks={n_blocks} cannot back {n_slots} slots of "
                f"{max_blocks_per_slot} blocks each (need >= {min_blocks} "
                f"including the trash block and a copy-on-write spare)")
        self.n_slots = n_slots
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.max_blocks = max_blocks_per_slot
        self.prefix_cache = prefix_cache
        # block 0 is the trash block — never allocated, never freed
        self._free: List[int] = list(range(n_blocks - 1, 0, -1))
        self._ref = np.zeros((n_blocks,), np.int32)
        self._ref[TRASH_BLOCK] = 1            # pin trash out of the free list
        # per-slot tables: allocated prefix of each row holds real block
        # ids, the rest points at trash
        self.tables = np.zeros((n_slots, max_blocks_per_slot), np.int32)
        self._slot_len = np.zeros((n_slots,), np.int32)   # allocated blocks
        self._root = _RadixNode(None, None, TRASH_BLOCK)
        self._clock = itertools.count(1)
        self.evictions = 0

    # -- allocator -----------------------------------------------------------
    @property
    def blocks_in_use(self) -> int:
        """Allocated blocks (trash excluded)."""
        return self.n_blocks - 1 - len(self._free)

    def alloc(self) -> int:
        """Pop a free block (refcount 1), evicting cached prefixes if dry."""
        if not self._free:
            self._evict_one()
        bid = self._free.pop()
        self._ref[bid] = 1
        return bid

    def _release_block(self, bid: int):
        if bid == TRASH_BLOCK:
            return
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            self._free.append(bid)
        assert self._ref[bid] >= 0, f"refcount underflow on block {bid}"

    def _evict_one(self):
        """Free the LRU evictable radix leaf (index-only refcount)."""
        best: Optional[_RadixNode] = None
        stack = [self._root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node is self._root or node.children:
                continue
            if self._ref[node.block] != 1:       # a slot still reads it
                continue
            if best is None or node.last_used < best.last_used:
                best = node
        if best is None:
            raise RuntimeError(
                "KV block pool exhausted: every block is referenced by a "
                "live slot and nothing is evictable — size the engine with "
                "more num_blocks (or fewer slots / shorter max_len)")
        del best.parent.children[best.key]
        self._release_block(best.block)
        self.evictions += 1

    # -- radix prefix index --------------------------------------------------
    def _chunks(self, tokens: Sequence[int]):
        bs = self.block_size
        for i in range(len(tokens) // bs):
            yield tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])

    def match(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Longest cached full-block prefix of ``tokens``.

        Returns ``(block_ids, hit_tokens)`` with ``hit_tokens`` a multiple
        of ``block_size`` capped at ``len(tokens) - 1`` (at least one
        token must remain for prefill to produce logits). The matched
        blocks are NOT acquired — call :meth:`acquire_blocks` when a slot
        takes them, while they are still index-pinned and unevictable.
        """
        if not self.prefix_cache:
            return [], 0
        cap_blocks = max(0, (len(tokens) - 1) // self.block_size)
        node, hit = self._root, []
        for chunk in self._chunks(tokens):
            if len(hit) >= cap_blocks:
                break
            child = node.children.get(chunk)
            if child is None:
                break
            child.last_used = next(self._clock)
            hit.append(child.block)
            node = child
        return hit, len(hit) * self.block_size

    def insert(self, tokens: Sequence[int], block_ids: Sequence[int]) -> int:
        """Publish ``tokens``' full blocks (backed by ``block_ids``, one
        per block-chunk) into the prefix index. Chunks already present
        keep their existing block (the duplicate stays slot-owned and
        simply is not published); new nodes acquire one index reference.
        Returns the number of newly indexed blocks.
        """
        if not self.prefix_cache:
            return 0
        node, added = self._root, 0
        for i, chunk in enumerate(self._chunks(tokens)):
            if i >= len(block_ids):
                break
            child = node.children.get(chunk)
            if child is None:
                bid = int(block_ids[i])
                child = _RadixNode(node, chunk, bid)
                node.children[chunk] = child
                self._ref[bid] += 1            # the index's reference
                added += 1
            child.last_used = next(self._clock)
            node = child
        return added

    # -- slot lifecycle ------------------------------------------------------
    def acquire_blocks(self, slot: int, block_ids: Sequence[int]):
        """Start a slot's table with already-cached blocks (prefix hits)."""
        n = len(block_ids)
        assert self._slot_len[slot] == 0, "slot table not released"
        for j, bid in enumerate(block_ids):
            self.tables[slot, j] = bid
            self._ref[bid] += 1
        self._slot_len[slot] = n

    def append_block(self, slot: int) -> int:
        """Allocate and append a fresh (uniquely owned) block to a slot."""
        j = int(self._slot_len[slot])
        if j >= self.max_blocks:
            raise RuntimeError(f"slot {slot} exceeded max_blocks="
                               f"{self.max_blocks}")
        bid = self.alloc()
        self.tables[slot, j] = bid
        self._slot_len[slot] = j + 1
        return bid

    def release_slot(self, slot: int):
        """Drop a slot's references; index-published blocks stay cached."""
        for j in range(int(self._slot_len[slot])):
            self._release_block(int(self.tables[slot, j]))
        self.tables[slot, :] = TRASH_BLOCK
        self._slot_len[slot] = 0

    def slot_blocks(self, slot: int) -> List[int]:
        return [int(b) for b in self.tables[slot, : self._slot_len[slot]]]

    def prepare_decode(self, slot: int, pos0: int, n: int
                       ) -> List[Tuple[int, int]]:
        """Make positions ``[pos0, pos0 + n)`` of ``slot`` writable.

        Appends fresh blocks where the table ends and copy-on-writes any
        shared block in the window. Returns ``(src, dst)`` block-id pairs
        the engine must copy on device BEFORE the decode chunk runs.
        """
        cow: List[Tuple[int, int]] = []
        first = pos0 // self.block_size
        last = min((pos0 + n - 1) // self.block_size, self.max_blocks - 1)
        for j in range(first, last + 1):
            if j >= self._slot_len[slot]:
                # decode windows are contiguous: the first unallocated
                # index is always exactly the table's current end
                assert j == self._slot_len[slot], (slot, j)
                self.append_block(slot)
                continue
            bid = int(self.tables[slot, j])
            if self._ref[bid] > 1:              # shared: copy before write
                new = self.alloc()
                cow.append((bid, new))
                self.tables[slot, j] = new
                self._release_block(bid)
        return cow
