"""Host-side manager for the block-paged KV cache: free-list allocation,
copy-on-write refcounts, and the radix prefix index.

The device side is a shared pool ``[n_layers, n_blocks, block, Hk, hd]``
plus per-slot block tables (``repro.models.attention.init_paged_cache``);
everything about *ownership* lives here, in plain Python, because it
changes at request granularity (admission / chunk boundaries / finish),
never inside a jitted step:

- **Free-list allocator.** Blocks are fixed-size (``block_size`` tokens)
  and handed out from a free list. Block 0 is reserved as the *trash
  block*: unallocated table entries point at it and out-of-range writes
  (stopped slots riding through a decode scan) are routed to it, so device
  code never needs a branch for "no block here".
- **Refcounts + copy-on-write.** A block's refcount counts the slots whose
  tables reference it plus one if the prefix index holds it. Writes only
  ever target uniquely-owned blocks: :meth:`prepare_decode` detects a
  shared block in the write window and returns ``(src, dst)`` copy pairs
  for the engine to execute as one batched device copy (``cow_copies``
  in EngineStats). Today's engine flows never actually share a *writable*
  block — hits are full blocks strictly behind the write cursor and
  partial blocks are never published — so the CoW branch is a defensive
  invariant (exercised directly in tests/test_paged.py) that keeps the
  allocator correct for sharing modes the scheduler may add later, e.g.
  forked/parallel sampling from one prompt.
- **Radix prefix index.** A trie over full *blocks* of token ids (one edge
  per ``block_size``-token chunk — a node's path from the root is the
  exact token prefix its block's KV was computed under, which is what
  makes the KV reusable at all). ``match()`` walks the longest cached
  prefix for a new prompt and acquires the hit blocks for a slot;
  ``insert()`` publishes a finished prefill/generation so future requests
  reuse it. This extends the paper's computation-reuse principle from
  weight products (the Result Cache) one level up, to whole KV rows:
  requests sharing a system prompt or few-shot template hit the index and
  skip recomputing that prefill entirely.
- **Eviction.** When the free list runs dry, leaf nodes of the radix tree
  that no slot references (refcount == 1, held only by the index) are
  evicted in LRU order. Blocks referenced by live slots are never evicted,
  so sizing the pool at ``n_slots * max_blocks_per_slot`` unique blocks
  (+ trash + one copy-on-write spare) guarantees allocation never fails.
- **Plan-then-commit admission.** :meth:`admit` is the transactional
  entry the engine uses: it acquires a request's prefix hits and
  allocates its suffix blocks *atomically* — if the pool cannot supply
  the full plan, everything taken so far is rolled back and the slot
  table is left exactly as before, so an overloaded admission becomes a
  clean "defer or preempt" decision instead of a mid-wave
  ``RuntimeError`` with blocks leaked into a half-built table.
  :meth:`plan_decode` / :meth:`can_allocate` give the engine the same
  guarantee for decode write-windows: count what a chunk needs without
  mutating, check it against ``free + evictable``, and only then commit
  (preempting victims first when the answer is no).

Partial blocks are never indexed or matched: a hit is always a whole
number of blocks, and is additionally capped at ``len(prompt) - 1`` so
prefill always has at least one suffix token to produce logits from.
"""

from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

TRASH_BLOCK = 0


class _RadixNode:
    """One cached block: edge key = its ``block_size`` token ids."""
    __slots__ = ("children", "parent", "key", "block", "last_used")

    def __init__(self, parent: Optional["_RadixNode"], key, block: int):
        self.children: Dict[tuple, _RadixNode] = {}
        self.parent = parent
        self.key = key
        self.block = block
        self.last_used = 0


class PagedKVCache:
    """Block allocator + prefix index for one engine's KV pool.

    Device arrays are owned by the engine; this class tracks which pool
    blocks exist, who references them, and which token prefixes they hold.
    """

    def __init__(self, *, n_slots: int, n_blocks: int, block_size: int,
                 max_blocks_per_slot: int, prefix_cache: bool = True):
        if block_size < 1 or block_size & (block_size - 1):
            raise ValueError(f"block_size must be a power of two, got "
                             f"{block_size}")
        min_blocks = n_slots * max_blocks_per_slot + 2  # + trash + CoW spare
        if n_blocks < min_blocks:
            raise ValueError(
                f"n_blocks={n_blocks} cannot back {n_slots} slots of "
                f"{max_blocks_per_slot} blocks each (need >= {min_blocks} "
                f"including the trash block and a copy-on-write spare)")
        self.n_slots = n_slots
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.max_blocks = max_blocks_per_slot
        self.prefix_cache = prefix_cache
        # block 0 is the trash block — never allocated, never freed
        self._free: List[int] = list(range(n_blocks - 1, 0, -1))
        self._ref = np.zeros((n_blocks,), np.int32)
        self._ref[TRASH_BLOCK] = 1            # pin trash out of the free list
        # per-slot tables: allocated prefix of each row holds real block
        # ids, the rest points at trash
        self.tables = np.zeros((n_slots, max_blocks_per_slot), np.int32)
        self._slot_len = np.zeros((n_slots,), np.int32)   # allocated blocks
        self._root = _RadixNode(None, None, TRASH_BLOCK)
        self._clock = itertools.count(1)
        self.evictions = 0

    # -- allocator -----------------------------------------------------------
    @property
    def blocks_in_use(self) -> int:
        """Allocated blocks (trash excluded)."""
        return self.n_blocks - 1 - len(self._free)

    def alloc(self) -> int:
        """Pop a free block (refcount 1), evicting cached prefixes if dry."""
        if not self._free:
            self._evict_one()
        bid = self._free.pop()
        self._ref[bid] = 1
        return bid

    def _release_block(self, bid: int):
        if bid == TRASH_BLOCK:
            return
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            self._free.append(bid)
        assert self._ref[bid] >= 0, f"refcount underflow on block {bid}"

    def _evict_one(self):
        """Free the LRU evictable radix leaf (index-only refcount)."""
        best: Optional[_RadixNode] = None
        stack = [self._root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node is self._root or node.children:
                continue
            if self._ref[node.block] != 1:       # a slot still reads it
                continue
            if best is None or node.last_used < best.last_used:
                best = node
        if best is None:
            raise RuntimeError(
                "KV block pool exhausted: every block is referenced by a "
                "live slot and nothing is evictable — size the engine with "
                "more num_blocks (or fewer slots / shorter max_len)")
        del best.parent.children[best.key]
        self._release_block(best.block)
        self.evictions += 1

    def evictable_blocks(self) -> int:
        """Blocks the index could surrender under pressure.

        A node is reclaimable iff it is index-only (refcount 1) and its
        whole subtree is too — an interior node above a slot-referenced
        descendant can never become a leaf, so it (and its ancestors)
        are pinned. ``free + evictable`` is therefore the true
        allocation capacity :meth:`can_allocate` checks against.
        """
        def freeable(node: _RadixNode) -> Tuple[bool, int]:
            ok, count = True, 0
            for child in node.children.values():
                c_ok, c_count = freeable(child)
                count += c_count
                ok = ok and c_ok
            if node is self._root:
                return ok, count
            if ok and self._ref[node.block] == 1:
                return True, count + 1
            return False, count

        return freeable(self._root)[1]

    def can_allocate(self, n: int) -> bool:
        """Whether ``n`` fresh blocks can be produced (free + evictable)."""
        return len(self._free) + self.evictable_blocks() >= n

    def evict_prefixes(self, n: Optional[int] = None) -> int:
        """Force-evict up to ``n`` cached prefix blocks (all when None).

        Returns the number evicted. Used by the chaos harness's
        eviction-storm fault and by operators that want to drop the
        index wholesale (e.g. after a model hot-swap)."""
        done = 0
        while n is None or done < n:
            if self.evictable_blocks() == 0:
                break
            self._evict_one()
            done += 1
        return done

    # -- radix prefix index --------------------------------------------------
    def _chunks(self, tokens: Sequence[int]):
        bs = self.block_size
        for i in range(len(tokens) // bs):
            yield tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])

    def match(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Longest cached full-block prefix of ``tokens``.

        Returns ``(block_ids, hit_tokens)`` with ``hit_tokens`` a multiple
        of ``block_size`` capped at ``len(tokens) - 1`` (at least one
        token must remain for prefill to produce logits). The matched
        blocks are NOT acquired — call :meth:`acquire_blocks` when a slot
        takes them, while they are still index-pinned and unevictable.
        """
        if not self.prefix_cache:
            return [], 0
        cap_blocks = max(0, (len(tokens) - 1) // self.block_size)
        node, hit = self._root, []
        for chunk in self._chunks(tokens):
            if len(hit) >= cap_blocks:
                break
            child = node.children.get(chunk)
            if child is None:
                break
            child.last_used = next(self._clock)
            hit.append(child.block)
            node = child
        return hit, len(hit) * self.block_size

    def lookup(self, tokens: Sequence[int]) -> List[int]:
        """Uncapped full-chunk walk: block ids covering every complete
        ``block_size`` chunk of ``tokens`` still present in the index.

        Unlike :meth:`match` there is no ``len - 1`` cap — this is the
        swap-in path's query ("are ALL of a preempted request's full
        blocks still cached?"), not a prefill plan. Stops at the first
        missing chunk; touches LRU clocks like a match does."""
        if not self.prefix_cache:
            return []
        node, hit = self._root, []
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None:
                break
            child.last_used = next(self._clock)
            hit.append(child.block)
            node = child
        return hit

    def insert(self, tokens: Sequence[int], block_ids: Sequence[int]) -> int:
        """Publish ``tokens``' full blocks (backed by ``block_ids``, one
        per block-chunk) into the prefix index. Chunks already present
        keep their existing block (the duplicate stays slot-owned and
        simply is not published); new nodes acquire one index reference.
        Returns the number of newly indexed blocks.
        """
        if not self.prefix_cache:
            return 0
        node, added = self._root, 0
        for i, chunk in enumerate(self._chunks(tokens)):
            if i >= len(block_ids):
                break
            child = node.children.get(chunk)
            if child is None:
                bid = int(block_ids[i])
                child = _RadixNode(node, chunk, bid)
                node.children[chunk] = child
                self._ref[bid] += 1            # the index's reference
                added += 1
            child.last_used = next(self._clock)
            node = child
        return added

    # -- slot lifecycle ------------------------------------------------------
    def acquire_blocks(self, slot: int, block_ids: Sequence[int]):
        """Start a slot's table with already-cached blocks (prefix hits)."""
        n = len(block_ids)
        assert self._slot_len[slot] == 0, "slot table not released"
        for j, bid in enumerate(block_ids):
            self.tables[slot, j] = bid
            self._ref[bid] += 1
        self._slot_len[slot] = n

    def append_block(self, slot: int) -> int:
        """Allocate and append a fresh (uniquely owned) block to a slot."""
        j = int(self._slot_len[slot])
        if j >= self.max_blocks:
            raise RuntimeError(f"slot {slot} exceeded max_blocks="
                               f"{self.max_blocks}")
        bid = self.alloc()
        self.tables[slot, j] = bid
        self._slot_len[slot] = j + 1
        return bid

    def admit(self, slot: int, hit_blocks: Sequence[int], n_new: int) -> bool:
        """Atomically start ``slot`` with ``hit_blocks`` + ``n_new`` fresh
        blocks — all of it or none of it.

        Returns False (with the slot table and every refcount exactly as
        before) when the pool cannot supply ``n_new`` blocks even after
        evicting cached prefixes; the engine then defers or preempts
        instead of crashing mid-wave. Prefix evictions performed before
        the failure are not undone — they only shrink the cache, never
        corrupt it. This is the plan-then-commit fix for
        ``alloc()``/``append_block()`` raising with blocks already
        acquired (the refcounts they had taken used to leak).
        """
        if n_new > self.max_blocks - len(hit_blocks):
            return False
        self.acquire_blocks(slot, hit_blocks)
        try:
            for _ in range(n_new):
                self.append_block(slot)
        except RuntimeError:
            self.release_slot(slot)         # rolls back hits + fresh blocks
            return False
        return True

    def extend(self, slot: int, n_new: int) -> bool:
        """Atomically append ``n_new`` fresh blocks to an already-seated
        slot — all of them or none of them.

        This is chunked prefill's per-chunk allocation: a partially
        prefilled slot asks for the next chunk's blocks before any device
        work runs. Returns False (slot table and refcounts exactly as
        before) when the pool cannot supply the full plan, so the engine
        defers the chunk or preempts a victim instead of crashing with a
        half-extended table. Prefix evictions performed before a failure
        are not undone — they only shrink the cache.
        """
        if n_new <= 0:
            return True
        start = int(self._slot_len[slot])
        if start + n_new > self.max_blocks:
            return False
        taken: List[int] = []
        try:
            for _ in range(n_new):
                taken.append(self.append_block(slot))
        except RuntimeError:
            for j in range(start + len(taken) - 1, start - 1, -1):
                self._release_block(int(self.tables[slot, j]))
                self.tables[slot, j] = TRASH_BLOCK
            self._slot_len[slot] = start
            return False
        return True

    def plan_decode(self, slot: int, pos0: int, n: int) -> Tuple[int, int]:
        """Read-only twin of :meth:`prepare_decode`: how many fresh blocks
        the write window ``[pos0, pos0 + n)`` needs as ``(appends, cows)``.

        The engine sums this over all active slots and checks
        :meth:`can_allocate` BEFORE committing anything, so a decode
        chunk either has its whole block budget reserved or preempts a
        victim first — allocation can never fail halfway through a step.
        """
        appends = cows = 0
        first = pos0 // self.block_size
        last = min((pos0 + n - 1) // self.block_size, self.max_blocks - 1)
        for j in range(first, last + 1):
            if j >= self._slot_len[slot]:
                appends += 1
            elif self._ref[int(self.tables[slot, j])] > 1:
                cows += 1
        return appends, cows

    def release_slot(self, slot: int):
        """Drop a slot's references; index-published blocks stay cached."""
        for j in range(int(self._slot_len[slot])):
            self._release_block(int(self.tables[slot, j]))
        self.tables[slot, :] = TRASH_BLOCK
        self._slot_len[slot] = 0

    def truncate(self, slot: int, new_len: int) -> int:
        """Shrink ``slot``'s table to cover ``new_len`` tokens, releasing
        whole trailing blocks back to the pool. Returns the number of
        blocks released.

        This is the speculative-decoding rollback primitive: a verify
        window writes KV at ``pos .. pos + k`` optimistically, and after
        the host accepts ``m <= k`` draft tokens the slot only holds
        ``new_len = pos + m + 1`` positions — any block wholly past that
        point is unreferenced garbage. Only *trailing whole blocks* are
        released (released means refcount-decremented: a block the radix
        index also holds survives with its published prefix intact —
        rollback never rewrites history, the boundary block's garbage
        tail is simply overwritten by the next decode window and never
        published, since :meth:`insert` only indexes full chunks of the
        actual token sequence).
        """
        keep = -(-new_len // self.block_size)          # ceil-div
        n = int(self._slot_len[slot])
        if keep >= n:
            return 0
        for j in range(keep, n):
            self._release_block(int(self.tables[slot, j]))
            self.tables[slot, j] = TRASH_BLOCK
        self._slot_len[slot] = keep
        return n - keep

    def slot_blocks(self, slot: int) -> List[int]:
        return [int(b) for b in self.tables[slot, : self._slot_len[slot]]]

    def prepare_decode(self, slot: int, pos0: int, n: int
                       ) -> List[Tuple[int, int]]:
        """Make positions ``[pos0, pos0 + n)`` of ``slot`` writable.

        Appends fresh blocks where the table ends and copy-on-writes any
        shared block in the window. Returns ``(src, dst)`` block-id pairs
        the engine must copy on device BEFORE the decode chunk runs.
        """
        cow: List[Tuple[int, int]] = []
        first = pos0 // self.block_size
        last = min((pos0 + n - 1) // self.block_size, self.max_blocks - 1)
        for j in range(first, last + 1):
            if j >= self._slot_len[slot]:
                # decode windows are contiguous: the first unallocated
                # index is always exactly the table's current end
                assert j == self._slot_len[slot], (slot, j)
                self.append_block(slot)
                continue
            bid = int(self.tables[slot, j])
            if self._ref[bid] > 1:              # shared: copy before write
                new = self.alloc()
                cow.append((bid, new))
                self.tables[slot, j] = new
                self._release_block(bid)
        return cow

    # -- invariants (chaos harness / tests) ----------------------------------
    def check_consistency(self, external: Sequence[int] = ()) -> None:
        """Assert the allocator's books balance; raises AssertionError.

        Recomputes every block's expected refcount from the slot tables
        plus the radix index and compares against ``_ref``, checks the
        free list holds exactly the zero-ref blocks (trash excluded) with
        no duplicates, and that no freed block is referenced by a live
        slot table or index node. ``external`` names blocks alloc'd by
        an outside owner (the chaos harness's BlockThief) that carry one
        ref with no slot/index entry. The chaos harness calls this after
        every injected fault — any leak or double-free the rollback
        paths miss shows up here, not as silent corruption later.
        """
        want = np.zeros((self.n_blocks,), np.int64)
        want[TRASH_BLOCK] = 1
        for b in external:
            want[b] += 1
        for slot in range(self.n_slots):
            for j in range(int(self._slot_len[slot])):
                want[int(self.tables[slot, j])] += 1
            # beyond the allocated prefix, tables must point at trash
            for j in range(int(self._slot_len[slot]), self.max_blocks):
                assert self.tables[slot, j] == TRASH_BLOCK, (
                    f"slot {slot} entry {j} is {self.tables[slot, j]} past "
                    f"its allocated length {int(self._slot_len[slot])}")
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            want[node.block] += 1
        mismatch = [(b, int(self._ref[b]), int(want[b]))
                    for b in range(self.n_blocks) if self._ref[b] != want[b]]
        assert not mismatch, (
            f"refcount drift (block, have, want): {mismatch[:8]}")
        free = list(self._free)
        assert len(free) == len(set(free)), "free list holds duplicates"
        assert TRASH_BLOCK not in free, "trash block leaked into free list"
        for b in free:
            assert want[b] == 0, f"free block {b} still referenced"
        n_zero = int((want[1:] == 0).sum())
        assert n_zero == len(free), (
            f"{n_zero} zero-ref blocks but {len(free)} free-listed")
