"""Self-speculative decoding on the quantization ladder (host-side rules).

The repo holds one model at several precisions sharing a tokenizer and
cache layout (bf16 / axllm-int8 / int4 / shiftadd), which is a natural
self-speculation stack: a cheap low-precision *draft* proposes ``k``
tokens per round, the serving-precision *target* checks all of them in
ONE teacher-forced chunked scan (``repro.serve.decode.verify_steps``),
and the engine keeps the longest agreeing prefix plus the target's own
next token. Greedy output is **bit-identical** to target-only decode by
construction — the draft only ever changes *how fast* tokens appear,
never *which* tokens (tests/test_speculative.py drives the differential
matrix).

One speculative round, per slot (``pos`` = KV positions held, i.e.
``len(prompt) + len(tokens) - 1``)::

      draft scan (k+1 steps)          verify scan (k+1 steps, ONE dispatch)
      last -> d1 -> d2 -> ... d_{k+1}   [last, d1, .., dk] -> t1 .. t_{k+1}
        writes draft KV @ pos..pos+k      writes target KV @ pos..pos+k
                                  |
                                  v
      accept m = longest agreeing prefix (d_i == t_i for i < m)
      emit  t1..t_{m+1}  (= d1..dm  ++  the target's correction token)
                                  |
                                  v
      rollback: new KV length = pos + m + 1  <= pos + k + 1
        dense: reset the per-row cursor (stale tail is overwritten)
        paged: ``PagedKVCache.truncate(slot, new_len)`` frees whole
               now-unused tail blocks back to the pool

    The draft runs k+1 steps (not k) and its last proposal is discarded:
    this leaves draft KV covering exactly the target's written range, so
    an all-accept round starts the next draft from fully valid KV.

This module owns the *pure host-side rules* of that loop — acceptance
and round sizing — so they are property-testable without an engine. The
device half lives in ``repro.serve.decode.verify_steps`` and the engine
integration (dual-model step loop, draft prefill riding admission
waves, preemption interplay) in ``repro.serve.engine``.
"""

from __future__ import annotations

from typing import Sequence

__all__ = ["accept_length", "emitted_tokens", "round_k"]


def accept_length(draft: Sequence[int], target: Sequence[int]) -> int:
    """Longest-agreeing-prefix rule: how many draft tokens the target
    confirms.

    ``draft`` holds the k proposals, ``target`` the k+1 greedy choices of
    the verify scan (``target[i]`` is the target's token after consuming
    ``draft[:i]``). The accept length is the index of the first
    disagreement — every draft token before it IS what target-only
    greedy decode would have produced, and ``target[m]`` is the
    correction (or bonus, when everything agreed) token.

    >>> accept_length([5, 7, 9], [5, 7, 2, 4])     # first mismatch at 2
    2
    >>> accept_length([5, 7, 9], [5, 7, 9, 4])     # all accepted
    3
    >>> accept_length([3], [8, 1])                 # immediate mismatch
    0
    >>> accept_length([], [6])                     # k == 0: plain decode
    0
    """
    if len(target) != len(draft) + 1:
        raise ValueError(
            f"verify scan must produce len(draft)+1 = {len(draft) + 1} "
            f"target tokens, got {len(target)}")
    m = 0
    while m < len(draft) and int(draft[m]) == int(target[m]):
        m += 1
    return m


def emitted_tokens(draft: Sequence[int], target: Sequence[int]) -> list:
    """Tokens one speculative round emits: the accepted draft prefix plus
    the target's correction token — always at least one token, so every
    round makes progress even at zero acceptance.

    The emitted block equals ``target[:m+1]`` (the target's own greedy
    tokens), which is WHY speculative greedy output is bit-identical to
    target-only decode: nothing the draft proposed survives unverified.

    >>> emitted_tokens([5, 7, 9], [5, 7, 2, 4])
    [5, 7, 2]
    >>> emitted_tokens([5, 7, 9], [5, 7, 9, 4])    # all-accept + bonus
    [5, 7, 9, 4]
    >>> emitted_tokens([], [6])                    # k == 0
    [6]
    """
    m = accept_length(draft, target)
    return [int(t) for t in target[: m + 1]]


def round_k(spec_k: int, *, max_len: int, positions: Sequence[int],
            budgets: Sequence[int], max_n: int | None = None) -> int:
    """Draft length for one speculative round over the active slots.

    Clamps ``spec_k`` so the round stays correct and useful for every
    slot, then buckets DOWN to ``{0} | {powers of two} | {spec_k}`` so
    the jitted draft/verify scans compile a handful of lengths instead
    of one per distinct clamp:

    - ``max_len``: the verify scan writes KV at ``pos .. pos+k`` for
      every slot, so ``k <= max_len - 1 - max(positions)`` keeps every
      write in bounds (no clamped/garbage writes to reason about).
    - ``budgets``: per-slot ``max_new - len(tokens)`` remainders; a
      round emits at most k+1 tokens per slot, so drafting past the
      largest remainder is pure waste.
    - ``max_n``: the caller's device-step budget (a round costs k+1
      target steps).

    ``k == 0`` degenerates to a plain (teacher-forced) decode step —
    the round still emits the target's token, so progress is guaranteed.

    >>> round_k(8, max_len=64, positions=[10, 20], budgets=[30, 30])
    8
    >>> round_k(8, max_len=64, positions=[60], budgets=[30])   # pos bound
    2
    >>> round_k(8, max_len=64, positions=[63], budgets=[30])   # no room
    0
    >>> round_k(8, max_len=64, positions=[10], budgets=[4])    # budget
    2
    >>> round_k(6, max_len=64, positions=[10], budgets=[30])   # own size
    6
    >>> round_k(6, max_len=64, positions=[59], budgets=[30])   # pow2 down
    4
    """
    if spec_k < 1:
        raise ValueError(f"spec_k must be >= 1, got {spec_k}")
    k = min(spec_k,
            min(max_len - 1 - int(p) for p in positions),
            max(int(b) for b in budgets) - 1)
    if max_n is not None:
        k = min(k, max_n - 1)
    if k <= 0:
        return 0
    if k >= spec_k:
        return spec_k
    return 1 << (k.bit_length() - 1)        # largest power of two <= k
