"""Multi-LoRA adapter registry for the serving engine (paper §III).

The paper's second headline claim is the dual multiply/reuse pipeline:
the base model's weights stay frozen in their quantized AxLLM form while
LoRA fine-tunes ride alongside as low-rank bf16/fp32 deltas — "without
altering parameters, retraining, or offline preprocessing".  This module
is the software shape of that split for continuous batching: up to
``max_loras`` trained adapters are stacked into batched per-target
``[n_layers, max_loras, ...]`` A/B tensors, requests carry an adapter
name, and the engine threads a per-slot ``[B]`` adapter-index array
(``-1`` = base-only) through prefill waves and the chunked decode scan.
One dispatch then serves a mixed batch of base and N different adapters
(:func:`repro.core.axllm_linear.lora_delta_batched` does the gathered
apply); the base pipeline — quantized matmul, fused wqkv included — is
untouched.

Layout
------
A registered adapter is a pytree ``{target: {"lora_a": [n_layers, n_in,
rank], "lora_b": [n_layers, rank, n_out]}}`` — exactly what per-layer
LoRA training produces (see examples/lora_finetune.py).  Targets are the
attention projections ``wq``/``wk``/``wv``/``wo``; a target missing from
an adapter stays zero in its stacked row (B=0 ⇒ exact identity).
Adapters must stay *dense*: quantizing the delta would collapse the two
pipelines, so :class:`QTensor` leaves are rejected at :meth:`add`.

Lifecycle
---------
``add``/``evict`` hot-swap adapters between waves — the stacked tensor
shapes never change, so the engine's jitted prefill/decode callables are
reused across swaps (the stack is passed as a jit *argument*, not baked
in at trace time).  The engine ``acquire``\\ s an adapter at ``submit``
and ``release``\\ s it when the request finishes, so ``evict`` on an
adapter with in-flight requests raises instead of yanking live weights.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core.axllm_linear import LoRAConfig
from repro.core.quantization import QTensor

#: targets the serve path can apply (attention projections, paper §III)
SUPPORTED_TARGETS = ("wq", "wk", "wv", "wo")


def target_dims(cfg, target: str) -> Tuple[int, int]:
    """(n_in, n_out) of an attention projection weight for ``cfg``.

    >>> import dataclasses
    >>> from repro.configs.base import ModelConfig
    >>> c = ModelConfig(name="d", family="dense", n_layers=2, d_model=64,
    ...                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    ...                 head_dim=16)
    >>> target_dims(c, "wq"), target_dims(c, "wk"), target_dims(c, "wo")
    ((64, 64), (64, 32), (64, 64))
    """
    d, h, hk, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim)
    dims = {"wq": (d, h * hd), "wk": (d, hk * hd), "wv": (d, hk * hd),
            "wo": (h * hd, d)}
    if target not in dims:
        raise ValueError(f"unsupported LoRA target {target!r}; serveable "
                         f"targets are {SUPPORTED_TARGETS}")
    return dims[target]


class AdapterRegistry:
    """Stacked multi-LoRA store consumed by :class:`~repro.serve.engine.
    ServeEngine`.

    cfg:       the ModelConfig the adapters were trained against (shapes
               are validated per target at ``add``).
    lora_cfg:  rank/alpha/targets; every registered adapter must match
               ``lora_cfg.rank`` (the stacked tensors have one rank).
    max_loras: stacked capacity — hot ``add``/``evict`` swap within it.
    """

    def __init__(self, cfg, lora_cfg: Optional[LoRAConfig] = None,
                 max_loras: int = 4, dtype=jnp.float32):
        if max_loras < 1:
            raise ValueError(f"max_loras must be >= 1, got {max_loras}")
        self.cfg = cfg
        self.lora_cfg = lora_cfg or LoRAConfig()
        self.max_loras = max_loras
        self.dtype = dtype
        targets = tuple(self.lora_cfg.targets)
        for t in targets:
            target_dims(cfg, t)                      # raises on unknown
        self.targets = targets
        nl, r = cfg.n_layers, self.lora_cfg.rank
        self._stacked = {}
        for t in targets:
            n_in, n_out = target_dims(cfg, t)
            self._stacked[t] = {
                "lora_a": jnp.zeros((nl, max_loras, n_in, r), dtype),
                "lora_b": jnp.zeros((nl, max_loras, r, n_out), dtype),
            }
        self._names: List[Optional[str]] = [None] * max_loras
        self._refs: List[int] = [0] * max_loras

    # -- introspection --------------------------------------------------------
    @property
    def scaling(self) -> float:
        """alpha / rank — the delta multiplier (jit-static at the engine)."""
        return self.lora_cfg.scaling

    @property
    def stacked(self) -> Dict[str, Dict[str, jnp.ndarray]]:
        """``{target: {"lora_a": [n_layers, max_loras, n_in, r], "lora_b":
        [n_layers, max_loras, r, n_out]}}`` — passed as an argument to the
        engine's jitted callables (shapes are swap-invariant)."""
        return self._stacked

    @property
    def names(self) -> List[str]:
        return [n for n in self._names if n is not None]

    def __contains__(self, name: str) -> bool:
        return name in self._names

    def __len__(self) -> int:
        return len(self.names)

    def index_of(self, name: str) -> int:
        """Stacked row of ``name`` (the value requests carry per slot)."""
        try:
            return self._names.index(name)
        except ValueError:
            raise KeyError(
                f"unknown adapter {name!r}; registered: {self.names}")

    # -- validation -----------------------------------------------------------
    def _check_leaf(self, name, target, key, leaf, want_shape):
        if isinstance(leaf, QTensor):
            raise TypeError(
                f"adapter {name!r} {target}/{key} is a QTensor: LoRA deltas "
                "stay dense (bf16/fp32) — the dual-pipeline split quantizes "
                "only the frozen base")
        if not hasattr(leaf, "shape"):
            raise TypeError(f"adapter {name!r} {target}/{key} is not an "
                            f"array: {type(leaf)}")
        if tuple(leaf.shape) != want_shape:
            got_r = leaf.shape[-1] if key == "lora_a" else leaf.shape[-2]
            want_r = self.lora_cfg.rank
            if len(leaf.shape) == len(want_shape) and got_r != want_r:
                raise ValueError(
                    f"adapter {name!r} {target}/{key} rank {got_r} != "
                    f"registry rank {want_r} (one stacked rank per registry)")
            raise ValueError(
                f"adapter {name!r} {target}/{key} shape {tuple(leaf.shape)} "
                f"!= expected {want_shape} for model {self.cfg.name!r}")

    # -- mutation -------------------------------------------------------------
    def add(self, name: str, adapter: Dict[str, Dict[str, jnp.ndarray]]) -> int:
        """Validate + stack a trained adapter; returns its row index.

        adapter: ``{target: {"lora_a": [n_layers, n_in, rank], "lora_b":
        [n_layers, rank, n_out]}}``; targets must be a subset of the
        registry's (missing targets stay zero ⇒ identity).  Raises
        TypeError on QTensor leaves, ValueError on shape/rank/target
        mismatch or a duplicate name, RuntimeError when the registry is
        full (evict first).
        """
        if name in self._names:
            raise ValueError(f"adapter {name!r} already registered; evict "
                             "first to replace")
        if not adapter:
            raise ValueError(f"adapter {name!r} has no targets")
        unknown = set(adapter) - set(self.targets)
        if unknown:
            raise ValueError(
                f"adapter {name!r} targets {sorted(unknown)} not in registry "
                f"targets {self.targets}")
        nl, r = self.cfg.n_layers, self.lora_cfg.rank
        for t, ab in adapter.items():
            n_in, n_out = target_dims(self.cfg, t)
            if set(ab) != {"lora_a", "lora_b"}:
                raise ValueError(f"adapter {name!r} target {t!r} needs "
                                 "exactly {'lora_a', 'lora_b'} leaves")
            self._check_leaf(name, t, "lora_a", ab["lora_a"], (nl, n_in, r))
            self._check_leaf(name, t, "lora_b", ab["lora_b"], (nl, r, n_out))
        try:
            row = self._names.index(None)
        except ValueError:
            raise RuntimeError(
                f"registry full ({self.max_loras} adapters); evict one "
                "before adding")
        # targets absent from this adapter keep their row's zeros (free
        # rows are zeroed at __init__ and re-zeroed by evict)
        for t in adapter:
            for key in ("lora_a", "lora_b"):
                cur = self._stacked[t][key]
                self._stacked[t][key] = cur.at[:, row].set(
                    jnp.asarray(adapter[t][key], self.dtype))
        self._names[row] = name
        self._refs[row] = 0
        return row

    def evict(self, name: str) -> None:
        """Free ``name``'s row (zeroing it). Raises RuntimeError while any
        in-flight request still holds the adapter (engine acquire/release)."""
        row = self.index_of(name)
        if self._refs[row]:
            raise RuntimeError(
                f"adapter {name!r} is assigned to {self._refs[row]} active "
                "request(s); drain them before evicting")
        for t in self.targets:
            for key in ("lora_a", "lora_b"):
                cur = self._stacked[t][key]
                self._stacked[t][key] = cur.at[:, row].set(
                    jnp.zeros(cur.shape[:1] + cur.shape[2:], self.dtype))
        self._names[row] = None
        self._refs[row] = 0

    def place(self, specs: Dict[str, Dict[str, object]]) -> None:
        """Commit the stacked tensors to device placements (one-time, at
        engine init under a mesh: `dist.sharding.adapter_specs` gives
        replicated A / out-sharded B).

        Later hot `add`/`evict` updates go through ``.at[:, row].set``,
        which preserves the committed sharding — swaps stay cheap and the
        stacked tensors never silently migrate back to one device."""
        import jax
        for t, mats in specs.items():
            for key, spec in mats.items():
                self._stacked[t][key] = jax.device_put(
                    self._stacked[t][key], spec)

    # -- engine lifecycle ------------------------------------------------------
    def acquire(self, name: str) -> int:
        """Pin ``name`` for an in-flight request; returns its row index."""
        row = self.index_of(name)
        self._refs[row] += 1
        return row

    def release(self, name: str) -> None:
        row = self.index_of(name)
        if self._refs[row] <= 0:
            raise RuntimeError(f"release of adapter {name!r} without a "
                               "matching acquire")
        self._refs[row] -= 1

    def refcount(self, name: str) -> int:
        return self._refs[self.index_of(name)]
