"""Admission control for the serving engine: the bounded wait queue,
overload policies, and open-loop arrival processes.

Everything here is host-side request bookkeeping — the layer the
ROADMAP's heavy-traffic scenario was missing. The engine used to hold a
plain unbounded FIFO list: all requests arrived at once, nothing bounded
how long one could wait, and overload surfaced as a ``RuntimeError``
from the block pool mid-step. This module gives `ServeEngine` the three
standard levers (Sarathi/vLLM lineage — "Inference Optimizations for
LLMs" names scheduling as a serving bottleneck):

- **Bounded queue + admission policy.** ``WaitQueue(max_queue=...)``
  caps how many requests may wait. When full, ``submit()`` applies one
  of three policies (:data:`ADMISSION_POLICIES`):

  * ``"block"`` — backpressure: the engine drives ``step()`` until a
    queue position frees (the open-loop analogue of a full TCP accept
    queue: the *caller* slows down).
  * ``"reject"`` — load shedding: the request is finished immediately
    with ``finish_reason="rejected"`` (zero tokens). Nothing raises;
    the caller reads the outcome off the returned request/stats.
  * ``"evict"`` — priority shedding: the lowest-priority (then
    youngest) *queued* request with strictly lower priority than the
    newcomer is rejected to make room; a newcomer that outranks nobody
    is itself rejected.

- **Priorities + deadlines.** The queue admits in ``(priority desc,
  rid asc)`` order — a stable sort, so equal priorities stay FIFO and a
  preempted request (which keeps its original rid) re-enters ahead of
  its priority class. ``deadline_s`` bounds *queue wait*: a request
  still queued ``deadline_s`` seconds after submission expires
  (``finish_reason="expired"``) instead of occupying the queue forever.
  Deadlines are checked against the engine's injectable ``clock`` so
  tests and the chaos harness can drive virtual time.

- **Victim selection.** :func:`pick_victim` chooses which *running*
  slot to preempt (lowest priority, then youngest rid) when the block
  pool runs dry or a strictly-higher-priority request is waiting — the
  swap-out/restore mechanics live in the engine.

- **Arrival processes.** :func:`arrival_times` turns a spec string into
  a deterministic open-loop arrival schedule for benchmarks and the
  launcher:

  >>> list(arrival_times("fixed:4", 3))
  [0.25, 0.5, 0.75]
  >>> parse_arrival("poisson:8")
  ('poisson', 8.0)
  >>> len(arrival_times("poisson:100", 5, seed=1))
  5
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

#: submit() behaviors when the wait queue is at max_queue
ADMISSION_POLICIES = ("block", "reject", "evict")


@dataclasses.dataclass
class QueueDecision:
    """Outcome of offering a request to a full-capable queue."""
    admitted: bool                 # the offered request entered the queue
    evicted: Optional[object] = None   # queued request shed to make room
    must_block: bool = False       # queue full under "block": caller drains


class WaitQueue:
    """Bounded, priority-ordered wait queue for `ServeEngine`.

    ``max_queue=None`` (default) is unbounded — the pre-robustness
    engine behavior, and what closed-loop tests use. The queue stores
    engine ``Request`` objects and reads only their ``rid``,
    ``priority``, ``deadline_s`` and ``t_submit`` attributes.
    """

    def __init__(self, max_queue: Optional[int] = None,
                 policy: str = "block"):
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"admission policy must be one of "
                             f"{ADMISSION_POLICIES}, got {policy!r}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_queue = max_queue
        self.policy = policy
        self._items: List[object] = []

    # -- list-like surface (serve_bench reads len(engine.queue)) -----------
    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)

    def __iter__(self):
        return iter(self._items)

    @property
    def full(self) -> bool:
        return self.max_queue is not None and len(self._items) >= \
            self.max_queue

    # -- admission ---------------------------------------------------------
    def offer(self, req) -> QueueDecision:
        """Apply the admission policy to ``req``.

        Returns a :class:`QueueDecision`; on ``admitted=True`` the
        request is in the queue. ``must_block=True`` (policy "block",
        queue full) means the caller must drain the engine and re-offer
        — the queue itself never busy-waits. An ``evicted`` request has
        been *removed* from the queue; the caller owns finishing it.
        """
        if not self.full:
            self._items.append(req)
            return QueueDecision(admitted=True)
        if self.policy == "block":
            return QueueDecision(admitted=False, must_block=True)
        if self.policy == "reject":
            return QueueDecision(admitted=False)
        # evict: shed the lowest-priority, youngest strictly-lower rival
        victim_i = None
        for i, r in enumerate(self._items):
            if r.priority >= req.priority:
                continue
            if victim_i is None:
                victim_i = i
                continue
            v = self._items[victim_i]
            if (r.priority, -r.rid) < (v.priority, -v.rid):
                victim_i = i
        if victim_i is None:
            return QueueDecision(admitted=False)   # newcomer outranks nobody
        victim = self._items.pop(victim_i)
        self._items.append(req)
        return QueueDecision(admitted=True, evicted=victim)

    def push_front(self, req) -> None:
        """Unconditionally requeue (deferred admission / preemption).

        Bypasses ``max_queue``: the request was already admitted once,
        so bouncing it against the bound would *lose* it."""
        self._items.append(req)

    # -- draining ----------------------------------------------------------
    def _order(self) -> None:
        # stable: equal priorities keep FIFO (rid) order, and a preempted
        # request's original rid puts it ahead of its priority class
        self._items.sort(key=lambda r: (-r.priority, r.rid))

    def expire(self, now: float) -> List[object]:
        """Remove and return every queued request past its deadline."""
        dead = [r for r in self._items
                if r.deadline_s is not None
                and now - r.t_submit > r.deadline_s]
        if dead:
            gone = set(id(r) for r in dead)
            self._items = [r for r in self._items if id(r) not in gone]
        return dead

    def take(self, k: int) -> List[object]:
        """Pop up to ``k`` requests in admission order."""
        if k <= 0 or not self._items:
            return []
        self._order()
        taken, self._items = self._items[:k], self._items[k:]
        return taken

    def peek_priority(self) -> Optional[int]:
        """Highest queued priority (None when empty)."""
        if not self._items:
            return None
        return max(r.priority for r in self._items)

    def remove(self, req) -> bool:
        try:
            self._items.remove(req)
            return True
        except ValueError:
            return False


def pick_victim(slots: Sequence[object],
                below_priority: Optional[int] = None) -> Optional[int]:
    """Index of the running slot to preempt, or None.

    Victims are chosen lowest-priority first, then youngest (largest
    rid) — the request that has consumed the least service and delays
    the fewest others when rolled back. ``below_priority`` restricts to
    slots *strictly* below that priority (priority preemption must
    never preempt an equal — that would thrash two peers forever).
    ``slots`` entries are engine Requests or None (free slots skipped).
    """
    best = None
    for i, r in enumerate(slots):
        if r is None:
            continue
        if below_priority is not None and r.priority >= below_priority:
            continue
        if best is None:
            best = i
            continue
        b = slots[best]
        if (r.priority, -r.rid) < (b.priority, -b.rid):
            best = i
    return best


def prefill_chunk(remaining: int, budget: int, block_size: int) -> int:
    """Tokens of prompt to prefill this step under a chunked-prefill budget.

    A *final* chunk (everything left fits the budget) takes exactly
    ``remaining`` tokens so the request produces its first logits this
    step. A *non-final* chunk is floored to a whole number of KV blocks:
    the prefill cursor then always sits on a block boundary, which keeps
    the paged scatter whole-block and lets every completed chunk publish
    into the radix index immediately.

    >>> prefill_chunk(10, 64, 8)     # fits: take it all
    10
    >>> prefill_chunk(100, 64, 8)    # non-final: block-aligned floor
    64
    >>> prefill_chunk(100, 60, 8)
    56
    >>> prefill_chunk(100, 7, 8)     # budget below one block: no progress
    0
    >>> prefill_chunk(0, 64, 8)
    0
    """
    if remaining <= 0 or budget <= 0:
        return 0
    if remaining <= budget:
        return remaining
    return (budget // block_size) * block_size


# -- open-loop arrival processes -------------------------------------------

def parse_arrival(spec: str) -> Tuple[str, float]:
    """Parse an arrival spec ``"poisson:<rate>"`` / ``"fixed:<rate>"``.

    Rates are requests/second. Raises ValueError on anything else.

    >>> parse_arrival("fixed:2.5")
    ('fixed', 2.5)
    """
    kind, sep, val = spec.partition(":")
    if not sep or kind not in ("poisson", "fixed"):
        raise ValueError(
            f"arrival spec must be 'poisson:<rate>' or 'fixed:<rate>', "
            f"got {spec!r}")
    rate = float(val)
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0, got {rate}")
    return kind, rate


def arrival_times(spec: str, n: int, seed: int = 0) -> List[float]:
    """``n`` deterministic arrival offsets (seconds) for ``spec``.

    ``fixed:r`` spaces arrivals exactly ``1/r`` apart; ``poisson:r``
    draws i.i.d. exponential inter-arrival gaps with mean ``1/r`` from
    a seeded generator, so a benchmark's offered load is reproducible.
    """
    kind, rate = parse_arrival(spec)
    if kind == "fixed":
        return [(i + 1) / rate for i in range(n)]
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return list(np.cumsum(gaps))
