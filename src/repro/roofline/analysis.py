"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (v5e constants):

    compute    = FLOPs / (chips * 197e12)
    memory     = HBM bytes / (chips * 819e9)
    collective = collective bytes / (chips * 50e9)       (per-link ICI)

Sources & corrections (EXPERIMENTS.md §Roofline methodology):

* ``compiled.cost_analysis()`` is recorded VERBATIM, but XLA's HLO cost
  analysis counts a while-loop (lax.scan) body ONCE, not trip-count times —
  verified empirically in this container (scan vs unrolled: 8x flops gap).
  Scan-over-layers therefore undercounts by ~n_layers.
* Correction: each cell is additionally lowered UNROLLED at 1 and 2
  layer-groups; per-group cost = cost(2) - cost(1); total =
  cost(1) - delta + n_groups * delta. Exact for homogeneous stacks (all
  assigned archs are homogeneous per group). Collective bytes get the same
  delta treatment (they sit inside the same loops).
* MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) + attention terms —
  the "useful compute" yardstick; MODEL_FLOPS / HLO_FLOPs(corrected) is the
  waste ratio (remat recompute, dequant overhead, dispatch).
* Collective bytes: parsed from post-SPMD ``compiled.as_text()`` — shapes in
  partitioned HLO are per-device, so summed operand bytes approximate
  per-chip link traffic; all-reduce counts 2x (reduce-scatter + all-gather
  phases of a ring).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "s4": 0.5, "u4": 0.5,
}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    """bytes of 'bf16[16,512]{1,0}' or tuple '(f32[8,2], f32[8,2])'."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum per-op output bytes by collective kind (post-SPMD per-device
    shapes). all-reduce doubles (RS+AG ring phases)."""
    out: Dict[str, Dict[str, float]] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        if kind == "all-reduce":
            b *= 2.0
        d = out.setdefault(kind, {"count": 0, "bytes": 0.0})
        d["count"] += 1
        d["bytes"] += b
    return out


def total_collective_bytes(coll: Dict[str, Dict[str, float]]) -> float:
    return sum(v["bytes"] for v in coll.values())


def cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca) if ca else {}


def memory_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def extrapolate(cost1: float, cost2: float, n_groups: int) -> float:
    """cost(1 group), cost(2 groups) -> cost(n_groups) for homogeneous
    stacks: base + n * delta."""
    delta = cost2 - cost1
    base = cost1 - delta
    return base + n_groups * delta


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS
# ---------------------------------------------------------------------------

def model_flops(cfg, shape_kind: str, seq: int, global_batch: int) -> float:
    """6·N·D (+ attention 12·L·d_head·H·S per token, causal halved) —
    training counts fwd+bwd (3x fwd); decode counts one token."""
    n_active = cfg.n_active_params()
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, hk, l = cfg.n_heads, cfg.n_kv_heads, cfg.n_layers

    def attn_flops_per_token(kv_len):
        # qk and pv per layer; causal average kv_len/2 during prefill
        return l * (2 * h * hd * kv_len + 2 * h * hd * kv_len)

    if shape_kind == "train":
        tokens = seq * global_batch
        fwd = 2 * n_active * tokens + tokens * attn_flops_per_token(seq / 2)
        return 3 * fwd                      # fwd + 2x bwd
    if shape_kind == "prefill":
        tokens = seq * global_batch
        return 2 * n_active * tokens + tokens * attn_flops_per_token(seq / 2)
    # decode: one token against a seq-length cache
    tokens = global_batch
    kv = seq if cfg.family not in ("ssm",) else cfg.ssm_state
    return 2 * n_active * tokens + tokens * attn_flops_per_token(kv)


def useful_hbm_bytes(cfg, shape_kind: str, seq: int, global_batch: int,
                     weight_bytes_per_param: float = 1.0,
                     kv_bytes: float = 2.0) -> float:
    """Physics floor on global HBM traffic per step: bytes the hardware MUST
    move (each weight read once; the KV/state cache read once per decoded
    token; activations touched a small constant number of times). The
    reported roofline fraction is floor / HLO-estimate: how close the
    compiled program is to this bound.

    weight_bytes_per_param: 2.0 bf16 baseline, 1.0 int8 codes, 0.5 int4.
    kv_bytes: 2.0 bf16 cache, 1.0 int8-quantized cache.
    """
    n_active = cfg.n_active_params()
    l, hk, hd = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
    d = cfg.d_model
    act_round = 12 * l * d * 2.0                      # bytes/token/pass

    if shape_kind == "decode":
        w = n_active * weight_bytes_per_param
        if cfg.family == "ssm":
            state = cfg.n_layers * (2 * d) * (2 * d // cfg.n_heads + 1) * 4
            cache = global_batch * state
        elif cfg.family == "hybrid":
            sites = cfg.n_layers // max(cfg.hybrid_attn_every, 1)
            cache = global_batch * (
                sites * 2 * seq * hk * hd * kv_bytes
                + cfg.n_layers * 2 * d * cfg.ssm_state * 4)
        else:
            cache = global_batch * 2 * l * seq * hk * hd * kv_bytes
        return w + cache + global_batch * act_round
    if shape_kind == "prefill":
        tokens = seq * global_batch
        w = n_active * weight_bytes_per_param
        kv_write = global_batch * 2 * l * seq * hk * hd * kv_bytes \
            if cfg.family not in ("ssm",) else 0.0
        return w + tokens * act_round + kv_write
    # train: per optimizer step
    tokens = seq * global_batch
    accum = max(cfg.grad_accum, 1)
    w_bytes = cfg.n_params() * 2.0                    # bf16 compute params
    opt_bytes = cfg.n_params() * (4.0 if not cfg.int8_optimizer else 10.0)
    grads = cfg.n_params() * 4.0
    # weights re-read fwd+bwd per microbatch; activations 3 passes w/ remat
    return (2 * accum * w_bytes + grads + opt_bytes
            + 3 * tokens * act_round)


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int) -> dict:
    comp = flops / (chips * PEAK_FLOPS)
    mem = hbm_bytes / (chips * HBM_BW)
    coll = coll_bytes / (chips * LINK_BW)
    dom = max(("compute", comp), ("memory", mem), ("collective", coll),
              key=lambda kv: kv[1])
    return {"compute_s": comp, "memory_s": mem, "collective_s": coll,
            "dominant": dom[0],
            "bound_step_s": max(comp, mem, coll)}
