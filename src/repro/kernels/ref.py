"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references: each Pallas kernel is validated against
its oracle in interpret mode across shape/dtype sweeps
(tests/test_kernels_*.py), and they double as the XLA fallback path used on
non-TPU backends (including the CPU dry-run — where the int8/int4 weight
arrays still flow through HLO, so cost_analysis sees the reduced byte
traffic the AxLLM technique is about).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import QTensor, decode_codes, dequantize, lookup


# ---------------------------------------------------------------------------
# AxLLM quantized matmul
# ---------------------------------------------------------------------------

def axllm_matmul_ref(x: jax.Array, qt: QTensor,
                     out_dtype=jnp.float32) -> jax.Array:
    """y = x @ deq(W) with f32 accumulation.

    Arithmetic contract (paper §III.b): every product is x[i] * value where
    value = codebook[code] * scale — identical to the RC-cached products
    modulo float summation order.
    """
    w = dequantize(qt, jnp.float32)
    y = jnp.dot(x.astype(jnp.float32), w,
                preferred_element_type=jnp.float32)
    return y.astype(out_dtype)


def reuse_matmul_ref(x: jax.Array, qt: QTensor,
                     out_dtype=jnp.float32) -> jax.Array:
    """y = x @ deq(W) computed with the reuse (LUT) association.

    Mirrors the reuse kernel's arithmetic exactly: each product is
    ``x[i,k] * levels[cell]`` (the table entry — sign applied on read for
    folded affine alphabets), partial sums run *within* each scale group,
    and the per-channel/per-group scale multiplies the group sum — not the
    individual products like :func:`axllm_matmul_ref` does. In the dyadic
    integer regime both associations are exact and bitwise-equal
    (tests/test_reuse_kernel.py); in general float they differ by normal
    rounding. jit-safe (pure jnp); use :func:`reuse_mult_count` for the
    multiply-count side of the contract.
    """
    from repro.core.reuse import rc_alphabet
    codes = decode_codes(qt)
    levels, fold = rc_alphabet(qt.bits, qt.mode)
    levels = jnp.asarray(levels)
    c = codes.astype(jnp.int32)
    if fold:
        vals = jnp.take(levels, jnp.abs(c), axis=0)
        vals = jnp.where(c < 0, -vals, vals)
    else:
        vals = jnp.take(levels, c + (levels.shape[0] >> 1), axis=0)
    kdim, n = qt.shape[-2], qt.shape[-1]
    m = x.shape[0]
    xf = x.astype(jnp.float32)
    scale = _reuse_scale(qt)                       # [G, N]
    g_rows = scale.shape[0]
    g = kdim // g_rows
    xg = xf.reshape(m, g_rows, g)
    vg = vals.astype(jnp.float32).reshape(g_rows, g, n)
    part = jnp.einsum("mgk,gkn->gmn", xg, vg,
                      preferred_element_type=jnp.float32)
    y = jnp.sum(part * scale[:, None, :], axis=0)
    return y.astype(out_dtype)


def _reuse_scale(qt: QTensor) -> jax.Array:
    """[G, N] f32 group scales with the affine /qmax folded in (G = 1 for
    per_channel/per_tensor) — the post-group-sum factor of the reuse path."""
    n = qt.shape[-1]
    if qt.granularity == "per_group":
        s = qt.scale.reshape(-1, n)
    elif qt.scale.size == n:
        s = qt.scale.reshape(1, n)
    else:
        s = jnp.broadcast_to(jnp.reshape(qt.scale, (1, 1)), (1, n))
    if qt.mode == "affine":
        s = s / ((1 << (qt.bits - 1)) - 1)
    return s.astype(jnp.float32)


def reuse_mult_count(qt: QTensor, segment: int) -> int:
    """Multiplies per activation row the reuse path executes: distinct
    alphabet cells per (k-row, ``segment``-wide column block), summed —
    ``core.reuse.segment_unique_counts`` under the kernel's own alphabet
    fold. Host-side (numpy): requires concrete codes, i.e. call outside
    jit. Multiply by M for the total of an [M, K] @ [K, N] call."""
    from repro.core.reuse import rc_alphabet, segment_unique_counts
    import numpy as np
    _, fold = rc_alphabet(qt.bits, qt.mode)
    codes = np.asarray(decode_codes(qt))
    return int(segment_unique_counts(codes, segment, fold_sign=fold).sum())


def lora_matmul_ref(x: jax.Array, qt: QTensor, a: jax.Array, b: jax.Array,
                    scaling: float, out_dtype=jnp.float32) -> jax.Array:
    """y = x @ deq(W) + scaling * (x @ A) @ B  (paper §III, LoRA support)."""
    base = axllm_matmul_ref(x, qt, jnp.float32)
    xa = jnp.dot(x.astype(jnp.float32), a.astype(jnp.float32))
    delta = jnp.dot(xa, b.astype(jnp.float32))
    return (base + scaling * delta).astype(out_dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """[B, S, Hk, d] -> [B, S, Hk*n_rep, d] (GQA head broadcast)."""
    if n_rep == 1:
        return k
    b, s, hk, d = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, hk, n_rep, d))
    return k.reshape(b, s, hk * n_rep, d)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, scale: Optional[float] = None,
                  bias: Optional[jax.Array] = None) -> jax.Array:
    """Full softmax attention. q: [B, Sq, H, d]; k, v: [B, Sk, Hk, d]."""
    b, sq, h, d = q.shape
    hk = k.shape[2]
    n_rep = h // hk
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if bias is not None:
        logits = logits + bias
    if causal:
        sk = k.shape[1]
        # queries occupy the LAST sq positions of the sk-long key sequence
        qpos = jnp.arange(sq) + (sk - sq)
        mask = qpos[:, None] >= jnp.arange(sk)[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         length: jax.Array,
                         k_scale: Optional[jax.Array] = None,
                         v_scale: Optional[jax.Array] = None) -> jax.Array:
    """One-token attention against a (possibly int8) KV cache.

    q: [B, H, d]; caches: [B, S, Hk, d] (int8 codes if *_scale given, with
    scales [B, S, Hk, 1]); length: [B] valid prefix lengths.
    """
    b, h, d = q.shape
    s, hk = k_cache.shape[1], k_cache.shape[2]
    if k_scale is not None:
        k_cache = k_cache.astype(jnp.float32) * k_scale
    if v_scale is not None:
        v_cache = v_cache.astype(jnp.float32) * v_scale
    out = attention_ref(q[:, None], k_cache, v_cache, causal=False,
                        bias=_length_bias(length, s, h))
    # length == 0 rows: every key is masked, so the softmax renormalizes a
    # uniform distribution over garbage — force the exact-zero output the
    # online-softmax kernels produce (l == 0 -> acc/max(l, eps) == 0)
    return jnp.where(length[:, None, None] > 0, out[:, 0], 0.0) \
        .astype(q.dtype)


def paged_decode_attention_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_tables: jax.Array,
                               length: jax.Array,
                               k_scale: Optional[jax.Array] = None,
                               v_scale: Optional[jax.Array] = None
                               ) -> jax.Array:
    """Oracle for the block-paged decode kernel: gather each row's logical
    KV sequence out of the shared pool through its block table, then run
    the dense decode oracle.

    q: [B, H, d]; pools: [NB, bs, Hk, d] (int8 codes if *_scale given,
    scales [NB, bs, Hk, 1]); block_tables: [B, MB] int32; length: [B].
    Table entries past a row's length may point anywhere (trash block 0 by
    convention) — masked by `length` exactly like dense pad positions.
    """
    b = q.shape[0]
    nb, bs = k_pool.shape[0], k_pool.shape[1]
    mb = block_tables.shape[1]

    def gather(pool):
        g = jnp.take(pool, block_tables, axis=0)       # [B, MB, bs, Hk, *]
        return g.reshape(b, mb * bs, *pool.shape[2:])

    return decode_attention_ref(
        q, gather(k_pool), gather(v_pool), length,
        k_scale=None if k_scale is None else gather(k_scale),
        v_scale=None if v_scale is None else gather(v_scale))


def _length_bias(length: jax.Array, s: int, h: int) -> jax.Array:
    mask = jnp.arange(s)[None, :] < length[:, None]          # [B, S]
    return jnp.where(mask, 0.0, -1e30)[:, None, None, :]     # [B, 1, 1, S]


def prefix_attention_ref(q: jax.Array, k_prefix: jax.Array,
                         v_prefix: jax.Array, prefix_len: jax.Array,
                         k_suffix: jax.Array, v_suffix: jax.Array
                         ) -> jax.Array:
    """Suffix-only prefill attention against a cached prefix: query i of
    row b sits at global position ``prefix_len[b] + i`` and attends every
    valid prefix key (j < prefix_len[b]) plus the causal suffix keys
    (j <= i). This is what lets prefix-cache hits skip recomputing their
    shared prompt head — the prefill wave only runs the un-cached tail.

    q: [B, S, H, d]; k/v_prefix: [B, P, Hk, d] (right-padded, per-row
    valid length ``prefix_len`` [B]); k/v_suffix: [B, S, Hk, d].
    Returns [B, S, H, d]. One joint f32 softmax over [prefix ++ suffix].
    """
    b, s, h, d = q.shape
    p = k_prefix.shape[1]
    n_rep = h // k_prefix.shape[2]
    scale = 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32)
    lp = jnp.einsum("bqhd,bkhd->bhqk", qf,
                    _repeat_kv(k_prefix, n_rep).astype(jnp.float32)) * scale
    ls = jnp.einsum("bqhd,bkhd->bhqk", qf,
                    _repeat_kv(k_suffix, n_rep).astype(jnp.float32)) * scale
    pmask = jnp.arange(p)[None, :] < prefix_len[:, None]       # [B, P]
    lp = jnp.where(pmask[:, None, None, :], lp, -1e30)
    smask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]   # causal [S,S]
    ls = jnp.where(smask[None, None], ls, -1e30)
    logits = jnp.concatenate([lp, ls], axis=-1)                # [B,H,S,P+S]
    probs = jax.nn.softmax(logits, axis=-1)
    vcat = jnp.concatenate([_repeat_kv(v_prefix, n_rep),
                            _repeat_kv(v_suffix, n_rep)], axis=1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vcat.astype(jnp.float32))
    return out.astype(q.dtype)


# Analysis mode (set via kernels.ops.set_analysis_mode): unrolls the KV-chunk
# loop so XLA cost analysis sees every chunk's FLOPs (a lax.scan body is
# counted once) — used only by the roofline aux lowering.
ANALYSIS_UNROLL = False


def chunked_attention_ref(q, k, v, causal: bool = True,
                          chunk: int = 1024) -> jax.Array:
    """Flash-style online-softmax attention in pure jnp (lax.scan over KV
    chunks) — the memory-safe fallback used for 32k prefill on the dry-run
    path, numerically equal to attention_ref."""
    b, sq, h, d = q.shape
    hk = k.shape[2]
    n_rep = h // hk
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    sk = k.shape[1]
    scale = 1.0 / (d ** 0.5)
    n_chunks = (sk + chunk - 1) // chunk
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    qf = q.astype(jnp.float32)
    qpos = jnp.arange(sq) + (sk - sq)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, idx = xs
        kpos = idx * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(jnp.float32))
        logits = logits * scale
        valid = kpos[None, :] < sk
        if causal:
            valid = valid & (qpos[:, None] >= kpos[None, :])
        logits = jnp.where(valid[None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), jnp.float32)
    if ANALYSIS_UNROLL:
        carry = (m0, l0, a0)
        for i in range(n_chunks):
            carry, _ = body(carry, (kc[i], vc[i], jnp.asarray(i)))
        m, l, acc = carry
    else:
        # checkpoint the chunk body: backward re-computes the [.., sq, chunk]
        # probability tile instead of storing one per chunk (which would undo
        # the whole memory saving)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(body, prevent_cse=False), (m0, l0, a0),
            (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# Quantization kernel oracle
# ---------------------------------------------------------------------------

def quantize_ref(w: jax.Array, bits: int = 8):
    """Per-channel absmax quantization: returns (codes int8, scale f32)."""
    qmax = (1 << (bits - 1)) - 1
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / qmax
    codes = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int8)
    return codes, scale
