"""Pallas TPU kernel: causal flash attention with GQA.

Standard online-softmax tiling adapted to the TPU memory hierarchy: Q/K/V
tiles stream HBM->VMEM per BlockSpec; the running max/denominator/accumulator
live in VMEM scratch across the innermost KV grid dimension, so the S_q x S_k
score matrix never exists in HBM — the requirement for the 32k-prefill cells.

Grid: (B*H, Sq/bq, Sk/bk), KV innermost ("arbitrary"). GQA is handled in the
K/V index maps (query head h reads kv head h // (H/Hk)). Causally dead blocks
are masked to zero inside the kernel (a production TPU kernel would prune
them via a block-sparse index map; the masked form is kept for clarity and is
what the interpret-mode tests validate — the pruned variant is a recorded
§Perf candidate).

Masking note: fully-masked tiles make every score -1e30; the probability tile
is multiplied by the 0/1 validity mask, so the m == -1e30 corner cannot leak
exp(0) = 1 into the accumulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, sq: int, sk: int,
                  bq: int, bk: int, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # [bq, d]
    k = k_ref[0].astype(jnp.float32)                    # [bk, d]
    v = v_ref[0].astype(jnp.float32)                    # [bk, d]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    iq = pl.program_id(1)
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (sk - sq)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = kpos < sk
    if causal:
        valid = valid & (qpos >= kpos)
    vmask = valid.astype(jnp.float32)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[:, :1]                               # [bq, 1]
    l_prev = l_ref[:, :1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new) * vmask                      # masked tiles -> 0
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == n_k - 1)
    def _flush():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 256, block_k: int = 256,
                           interpret: bool = False):
    """q: [B, Sq, H, d]; k, v: [B, Sk, Hk, d] -> [B, Sq, H, d]."""
    b, sq, h, d = q.shape
    sk, hk = k.shape[1], k.shape[2]
    rep = h // hk
    scale = 1.0 / (d ** 0.5)

    bq = min(block_q, sq)
    bk = min(block_k, sk)
    if sq % bq or sk % bk:
        raise ValueError(f"seq ({sq},{sk}) not divisible by blocks ({bq},{bk})")
    n_q, n_k = sq // bq, sk // bk

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hk, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hk, sk, d)

    def kv_index(bh, iq, ik):
        return ((bh // h) * hk + (bh % h) // rep, ik, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          sq=sq, sk=sk, bq=bq, bk=bk, n_k=n_k),
        grid=(b * h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (replicated)
            pltpu.VMEM((bq, 128), jnp.float32),   # running denom
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
