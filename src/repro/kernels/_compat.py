"""Pallas-TPU API compatibility aliases.

Imported only by the Pallas kernel modules — ref-only paths (models,
serve, the CPU dry-run with impl="ref") must never pull in
jax.experimental.pallas.tpu just by importing repro.kernels.
"""

from jax.experimental.pallas import tpu as _pltpu

# renamed TPUCompilerParams -> CompilerParams across jax releases
CompilerParams = getattr(_pltpu, "CompilerParams", None) or \
    getattr(_pltpu, "TPUCompilerParams")
