"""Pallas TPU kernel: single-token decode attention against a block-paged
KV pool (flash-decode through a block table).

The serving engine stores KV in fixed-size blocks inside a shared pool
``[n_blocks, block, Hk, d]`` with per-slot block tables — the KV-side
analogue of the paper's Result Cache: identical prompt prefixes map to the
*same* physical blocks, so their KV is computed once and reused by every
request that shares them (see repro/serve/paged_cache.py). This kernel is
the dense flash-decode kernel of ``decode_attention.py`` generalized to
gather its KV tiles through that indirection.

Grid: (B*H, n_blocks_per_seq). The block table and the per-row valid
lengths ride in as scalar-prefetch operands, so each KV tile's DMA source
address is computed from ``block_tables[b, ib]`` *before* the kernel body
runs (pltpu.PrefetchScalarGridSpec) — the gather costs no extra pass over
HBM. Online-softmax state lives in VMEM scratch across the block dimension,
exactly as in the dense kernel; int8-KV per-(position, head) scales stream
through the same block-table index map.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_decode_kernel(len_ref, bt_ref, q_ref, k_ref, v_ref, ks_ref,
                         vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                         scale: float, bs: int, n_b: int, h: int,
                         quantized: bool):
    bh = pl.program_id(0)
    ib = pl.program_id(1)

    @pl.when(ib == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)                     # [1, d]
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # [bs, d]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    if quantized:
        k = k * ks_ref[0, :, 0, :].astype(jnp.float32)     # [bs, 1] scales
        v = v * vs_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # global key position of this tile: block ib holds positions
    # [ib*bs, (ib+1)*bs) of the row's logical sequence, wherever the
    # block table placed them in the pool
    kpos = ib * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = kpos < len_ref[bh // h]   # scalar-prefetch refs are unblocked
    vmask = valid.astype(jnp.float32)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[:1, :1]
    l_prev = l_ref[:1, :1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new) * vmask
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ib == n_b - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[:1, :1], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q, k_pool, v_pool, block_tables, length, *,
                                  k_scale=None, v_scale=None,
                                  interpret: bool = False):
    """q: [B, H, d]; pools: [NB, bs, Hk, d]; block_tables: [B, MB] int32
    (pool block id of each row's ib-th logical block); length: [B].
    Returns [B, H, d]. Entries of the table beyond a row's valid length may
    point anywhere in the pool (conventionally block 0, the trash block) —
    the length mask keeps them out of the softmax.
    """
    b, h, d = q.shape
    bs, hk = k_pool.shape[1], k_pool.shape[2]
    mb = block_tables.shape[1]
    rep = h // hk
    quantized = k_scale is not None

    qf = q.reshape(b * h, d)
    if not quantized:
        # dummy scale refs keep the kernel signature uniform (one trash
        # block's worth per index — the map below pins them to block 0)
        k_scale = jnp.ones((1, bs, hk, 1), jnp.float32)
        v_scale = jnp.ones((1, bs, hk, 1), jnp.float32)

    def kv_index(bh, ib, len_ref, bt_ref):
        return (bt_ref[bh // h, ib], 0, (bh % h) // rep, 0)

    def scale_index(bh, ib, len_ref, bt_ref):
        if quantized:
            return kv_index(bh, ib, len_ref, bt_ref)
        return (0, 0, (bh % h) // rep, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # lengths + block table in SMEM
        grid=(b * h, mb),
        in_specs=[
            pl.BlockSpec((1, d), lambda bh, ib, len_ref, bt_ref: (bh, 0)),
            pl.BlockSpec((1, bs, 1, d), kv_index),
            pl.BlockSpec((1, bs, 1, d), kv_index),
            pl.BlockSpec((1, bs, 1, 1), scale_index),
            pl.BlockSpec((1, bs, 1, 1), scale_index),
        ],
        out_specs=pl.BlockSpec((1, d),
                               lambda bh, ib, len_ref, bt_ref: (bh, 0)),
        scratch_shapes=[
            pltpu.VMEM((8, 128), jnp.float32),
            pltpu.VMEM((8, 128), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, scale=1.0 / (d ** 0.5),
                          bs=bs, n_b=mb, h=h, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * h, d), q.dtype),
        interpret=interpret,
    )(length.astype(jnp.int32), block_tables.astype(jnp.int32),
      qf, k_pool, v_pool, k_scale, v_scale)
    return out.reshape(b, h, d)
