"""Public kernel entry points: Pallas-on-TPU, jnp-oracle elsewhere.

Every op takes `impl` in {"auto", "pallas", "ref", "pallas_interpret"}:
  auto             -> pallas on TPU backends, ref otherwise (CPU dry-run path)
  pallas_interpret -> pallas kernel body executed in Python (tests on CPU)

The quantized matmul additionally accepts the reuse (LUT) impls
{"reuse", "reuse_interpret", "reuse_ref"}, which route through the
codebook-LUT kernel of :mod:`repro.kernels.reuse_matmul` (gather instead of
multiply for repeated codes — the paper's Result Cache on device):
  reuse            -> reuse kernel on TPU, reuse jnp oracle otherwise
  reuse_interpret  -> reuse kernel body executed in Python (tests on CPU)
  reuse_ref        -> reuse jnp oracle (same product association, jit-safe)
Non-matmul ops treat "reuse" as "auto" and the other two as "ref" — the
reuse mode changes how quantized weights are multiplied, not how attention
or KV quantization dispatch.

The wrapper layer owns all shape plumbing the kernels require: scale-semantics
normalization (affine kernels consume scale/qmax), padding M to block
multiples, and flattening leading batch dims.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import QTensor
from repro.kernels import ref as _ref
from repro.kernels import axllm_matmul as _amm
from repro.kernels import reuse_matmul as _rmm


def set_analysis_mode(on: bool) -> None:
    """Roofline aux lowering: unroll inner attention chunk loops so HLO cost
    analysis counts them fully (see ref.ANALYSIS_UNROLL)."""
    _ref.ANALYSIS_UNROLL = on


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


REUSE_IMPLS = ("reuse", "reuse_interpret", "reuse_ref")


def _base_impl(impl: str) -> str:
    """What non-matmul ops see: reuse modes only redirect the quantized
    matmul, so "reuse" degrades to "auto" and the interpret/ref variants to
    the oracle path (interpreting every attention kernel alongside a
    reuse-matmul test would add wall time without covering anything new)."""
    if impl == "reuse":
        return "auto"
    if impl in ("reuse_interpret", "reuse_ref"):
        return "ref"
    return impl


def _use_pallas(impl: str) -> bool:
    if impl == "auto":
        return _on_tpu()
    return impl.startswith("pallas")


def _interpret(impl: str) -> bool:
    return impl == "pallas_interpret"


# ---------------------------------------------------------------------------
# AxLLM quantized matmul
# ---------------------------------------------------------------------------

def _kernel_scale(qt: QTensor) -> jax.Array:
    """Scale in the form the kernel consumes: [1, N] or [K/g, N] f32,
    folding the /qmax of affine dequantization."""
    n = qt.shape[-1]
    if qt.granularity == "per_group":
        s = qt.scale.reshape(-1, n)
    else:
        s = qt.scale.reshape(1, n) if qt.scale.size == n else jnp.broadcast_to(
            qt.scale.reshape(1, 1), (1, n))
    if qt.mode == "affine":
        qmax = (1 << (qt.bits - 1)) - 1
        s = s / qmax
    return s.astype(jnp.float32)


def _divisor_block(dim: int, target: int) -> int:
    """Largest power-of-two block <= target that divides dim (fallback:
    the dim itself, i.e. a single block)."""
    for b in (512, 256, 128, 64, 32, 16, 8):
        if b <= target and b <= dim and dim % b == 0:
            return b
    return dim


def pick_blocks(m: int, k: int, n: int, group_size: int = 128,
                per_group: bool = False, reuse_levels: Optional[int] = None):
    """Block-size table for the fused dequant-matmul: (bm, bk, bn, pad_m).

    The pad decision is part of the table: decode shapes (m < 128) pick the
    largest SKINNY_BM entry that divides m exactly, so m ∈ {8,16,...,64}
    (n_slots · decode tokens) hits a no-pad fast path instead of being
    silently re-padded on every call. Skinny launches widen bn to 512 (vs
    the 256 default) to keep the MXU fed from the N grid dimension — the
    per-tile VMEM footprint stays far under budget because the x tile
    shrinks with bm.

    ``reuse_levels`` switches to the reuse (LUT) kernel's table: its
    per-tile product table and one-hot selector scale with the alphabet
    size L, so bk is capped at ``REUSE_BK_LEVELS / L`` (per_group tiles
    floor at one group — their selector tile may exceed the soft budget,
    which the docstring of reuse_matmul.py accepts explicitly).

    >>> pick_blocks(16, 128, 256)       # skinny decode shape: no pad
    (16, 128, 256, 0)
    >>> pick_blocks(9, 128, 256)        # odd m falls back to bm=8 + pad
    (8, 128, 256, 7)
    >>> pick_blocks(16, 512, 256, reuse_levels=128)   # LUT: bk capped at 64
    (16, 64, 256, 0)
    """
    if m >= 128:
        bm = 128
    else:
        bm = next((b for b in _amm.SKINNY_BM if m % b == 0), 8)
    bk = _divisor_block(k, 512)
    bn = _divisor_block(n, 512 if bm <= 32 else 256)
    if reuse_levels:
        from repro.kernels.reuse_matmul import REUSE_BK_LEVELS
        bk = _divisor_block(k, max(REUSE_BK_LEVELS // reuse_levels, 8))
        bn = _divisor_block(n, 256)
    if per_group:
        g_bk = (bk // group_size) * group_size
        if g_bk <= 0 or k % g_bk:
            g_bk = group_size
        bk = g_bk
    return bm, bk, bn, (-m) % bm


def axllm_matmul(x: jax.Array, qt: QTensor, *, impl: str = "auto",
                 out_dtype=None) -> jax.Array:
    """y = x @ deq(qt). x: [..., K]; qt: [K, N]. Returns [..., N].

    ``impl`` in ``REUSE_IMPLS`` routes through the reuse (LUT) kernel —
    same result, gather-instead-of-multiply arithmetic (see
    :func:`reuse_matmul` for the stats-bearing entry point).
    """
    out_dtype = out_dtype or x.dtype
    if impl in REUSE_IMPLS:
        y, _ = reuse_matmul(x, qt, impl=impl, out_dtype=out_dtype)
        return y
    if not _use_pallas(impl):
        lead = x.shape[:-1]
        y = _ref.axllm_matmul_ref(x.reshape(-1, x.shape[-1]), qt, out_dtype)
        return y.reshape(*lead, -1)

    kdim, n = qt.shape[-2], qt.shape[-1]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, kdim)
    m = x2.shape[0]
    per_group = qt.granularity == "per_group"
    bm, bk, bn, pad_m = pick_blocks(m, kdim, n, qt.group_size, per_group)
    if pad_m:
        x2 = jnp.pad(x2, ((0, pad_m), (0, 0)))
    scale = _kernel_scale(qt)
    from repro.core.quantization import resolve_codebook
    y = _amm.axllm_matmul_pallas(
        x2, qt.codes, scale, resolve_codebook(qt),
        bits=qt.bits, packed=qt.packed, group_size=qt.group_size,
        blocks=(bm, bk, bn), interpret=_interpret(impl))
    if pad_m:
        y = y[:m]
    return y.reshape(*lead, n).astype(out_dtype)


def reuse_matmul(x: jax.Array, qt: QTensor, *, impl: str = "auto",
                 out_dtype=None, with_stats: bool = False):
    """Reuse (LUT) matmul: ``(y, mults)`` = x @ deq(qt) by gathering cached
    alphabet products instead of multiplying every code (paper §III.b).

    x: [..., K]; qt: [K, N]. ``y`` is [..., N]. ``mults`` is the
    *per-activation-row* multiply count — the distinct alphabet cells per
    (k-row, bn-wide column segment), summed — i.e. what a Result Cache
    executes for ONE input row; the baseline pays K*N. It is
    activation-independent, so the achieved multiply-reduction is
    ``1 - mults / (K * N)`` regardless of the batch. ``mults`` is a traced
    int32 scalar on the kernel paths and a host int on the ref path;
    ``with_stats=False`` (the serving default) returns ``mults=None`` —
    the ref-path count needs concrete codes and must stay out of jit.

    impl: "auto"/"reuse" -> kernel on TPU, jnp oracle otherwise;
    "reuse_interpret"/"pallas_interpret" -> kernel body in Python;
    "reuse_ref"/"ref" -> jnp oracle; "pallas" -> kernel.
    """
    from repro.core.reuse import rc_alphabet
    out_dtype = out_dtype or x.dtype
    kdim, n = qt.shape[-2], qt.shape[-1]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, kdim)
    m = x2.shape[0]
    levels, fold = rc_alphabet(qt.bits, qt.mode)
    per_group = qt.granularity == "per_group"
    bm, bk, bn, pad_m = pick_blocks(m, kdim, n, qt.group_size, per_group,
                                    reuse_levels=len(levels))

    use_kernel = impl in ("pallas", "pallas_interpret", "reuse_interpret") \
        or (impl in ("auto", "reuse") and _on_tpu())
    if not use_kernel:
        y = _ref.reuse_matmul_ref(x2, qt, jnp.float32)
        mults = _ref.reuse_mult_count(qt, bn) if with_stats else None
        return y.reshape(*lead, n).astype(out_dtype), mults

    interpret = impl in ("pallas_interpret", "reuse_interpret")
    if pad_m:
        x2 = jnp.pad(x2, ((0, pad_m), (0, 0)))
    y, counts = _rmm.reuse_matmul_pallas(
        x2, qt.codes, _kernel_scale(qt), jnp.asarray(levels),
        packed=qt.packed, fold_sign=fold, group_size=qt.group_size,
        blocks=(bm, bk, bn), interpret=interpret)
    if pad_m:
        y = y[:m]
    mults = counts[0, 0] if with_stats else None
    return y.reshape(*lead, n).astype(out_dtype), mults


def lora_matmul(x: jax.Array, qt: QTensor, a: jax.Array, b: jax.Array,
                scaling: float, *, impl: str = "auto",
                out_dtype=None) -> jax.Array:
    """y = x @ deq(qt) + scaling * (x @ A) @ B (paper Fig. 5 combined path)."""
    out_dtype = out_dtype or x.dtype
    base = axllm_matmul(x, qt, impl=impl, out_dtype=jnp.float32)
    xa = jnp.dot(x.astype(jnp.float32), a.astype(jnp.float32))
    delta = jnp.dot(xa, b.astype(jnp.float32))
    return (base + scaling * delta).astype(out_dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True,
                    impl: str = "auto") -> jax.Array:
    """q: [B, Sq, H, d]; k, v: [B, Sk, Hk, d] -> [B, Sq, H, d]."""
    impl = _base_impl(impl)
    if _use_pallas(impl):
        from repro.kernels import flash_attention as _fa
        return _fa.flash_attention_pallas(
            q, k, v, causal=causal, interpret=_interpret(impl))
    # memory-safe oracle (chunked online softmax) once the full [B,H,Sq,Sk]
    # score tensor stops being trivially small
    if q.shape[1] * k.shape[1] > 1024 * 1024:
        return _ref.chunked_attention_ref(q, k, v, causal=causal)
    return _ref.attention_ref(q, k, v, causal=causal)


def decode_attention(q, k_cache, v_cache, length, *, k_scale=None,
                     v_scale=None, block_tables=None,
                     impl: str = "auto") -> jax.Array:
    """q: [B, H, d]; caches [B, S, Hk, d] (int8 if scales given); length [B].

    With ``block_tables`` ([B, MB] int32) the caches are a shared *paged
    pool* [NB, bs, Hk, d] instead: each row's logical sequence is the
    concatenation of its table's blocks, and the paged flash-decode kernel
    gathers KV tiles through the table (scalar-prefetch index map) so
    prefix-shared blocks stream from HBM once per referencing row without
    ever being materialized contiguously.
    """
    impl = _base_impl(impl)
    if block_tables is not None:
        if _use_pallas(impl):
            from repro.kernels import paged_decode_attention as _pda
            return _pda.paged_decode_attention_pallas(
                q, k_cache, v_cache, block_tables, length,
                k_scale=k_scale, v_scale=v_scale,
                interpret=_interpret(impl))
        return _ref.paged_decode_attention_ref(
            q, k_cache, v_cache, block_tables, length,
            k_scale=k_scale, v_scale=v_scale)
    if _use_pallas(impl):
        from repro.kernels import decode_attention as _da
        return _da.decode_attention_pallas(
            q, k_cache, v_cache, length, k_scale=k_scale, v_scale=v_scale,
            interpret=_interpret(impl))
    return _ref.decode_attention_ref(q, k_cache, v_cache, length,
                                     k_scale=k_scale, v_scale=v_scale)


def prefix_attention(q, k_prefix, v_prefix, prefix_len, k_suffix, v_suffix,
                     *, impl: str = "auto") -> jax.Array:
    """Suffix-prefill attention against a cached (right-padded) prefix.

    q/k_suffix/v_suffix: [B, S, H|Hk, d]; k/v_prefix: [B, P, Hk, d] with
    per-row valid lengths ``prefix_len`` [B]. There is no Pallas
    suffix-prefill kernel yet — prefill waves are small and XLA fuses the
    jnp oracle fine; the decode hot path is where the paged Pallas kernel
    earns its keep. Dispatch is honest about that: ``auto``/``ref`` run
    the oracle, ``pallas_interpret`` runs it too (the oracle IS the kernel
    body being interpreted — there is no second implementation to check
    against), and an explicit ``impl="pallas"`` raises instead of
    silently substituting the jnp path for a compiled kernel.
    """
    impl = _base_impl(impl)
    if impl == "pallas":
        raise NotImplementedError(
            "prefix_attention has no compiled Pallas kernel yet: "
            "impl='pallas' would silently run the jnp oracle, which is "
            "not what you asked for. Use impl='auto' (oracle on every "
            "backend) or 'pallas_interpret'.")
    return _ref.prefix_attention_ref(q, k_prefix, v_prefix, prefix_len,
                                     k_suffix, v_suffix)


def quantize_channels(w, *, bits: int = 8, impl: str = "auto"):
    """Per-channel absmax quantization (codes, scale) — used for KV-cache
    quantization at serve time."""
    impl = _base_impl(impl)
    if _use_pallas(impl):
        from repro.kernels import quantize as _q
        return _q.quantize_pallas(w, bits=bits, interpret=_interpret(impl))
    return _ref.quantize_ref(w, bits=bits)
