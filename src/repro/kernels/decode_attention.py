"""Pallas TPU kernel: single-token decode attention (flash-decode) with
optional int8-quantized KV cache.

Decode is the memory-roofline cell: per step the whole KV cache streams
HBM->VMEM once while doing O(S·d) FLOPs. Quantizing the cache to int8 halves
those bytes — the KV-side counterpart of the AxLLM weight-code traffic
reduction (DESIGN.md §2) and a §Perf lever for decode_32k. Dequantization is
fused: codes and per-(position, head) scales stream in, f32 math in VMEM.

Grid: (B*H, S/bs) with the online-softmax state in VMEM scratch across the
S dimension. Valid-length masking reads `length[b]` from an SMEM-blocked ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float, bs: int,
                   n_s: int, quantized: bool):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)                     # [1, d]
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # [bs, d]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    if quantized:
        k = k * ks_ref[0, :, 0, :].astype(jnp.float32)     # [bs, 1] scales
        v = v * vs_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = ik * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = kpos < len_ref[0]
    vmask = valid.astype(jnp.float32)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[:1, :1]
    l_prev = l_ref[:1, :1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new) * vmask
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == n_s - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[:1, :1], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_pallas(q, k_cache, v_cache, length, *, k_scale=None,
                            v_scale=None, block_s: int = 512,
                            interpret: bool = False):
    """q: [B, H, d]; caches: [B, S, Hk, d]; length: [B] -> [B, H, d]."""
    b, h, d = q.shape
    s, hk = k_cache.shape[1], k_cache.shape[2]
    rep = h // hk
    quantized = k_scale is not None
    bs = min(block_s, s)
    if s % bs:
        # non-power-of-two cache lengths (e.g. S=768 with block 512): fall
        # back to the largest power-of-two block that divides S instead of
        # refusing the launch — worst case one block spanning all of S
        from repro.kernels.ops import _divisor_block
        bs = _divisor_block(s, bs)
    n_s = s // bs

    qf = q.reshape(b * h, d)
    if not quantized:
        # feed dummy scale refs so the kernel signature is uniform
        k_scale = jnp.ones((b, s, hk, 1), jnp.float32)
        v_scale = jnp.ones((b, s, hk, 1), jnp.float32)

    def kv_index(bh, ik):
        return (bh // h, ik, (bh % h) // rep, 0)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=1.0 / (d ** 0.5), bs=bs,
                          n_s=n_s, quantized=quantized),
        grid=(b * h, n_s),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, ik: (bh // h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, d), lambda bh, ik: (bh, 0)),
            pl.BlockSpec((1, bs, 1, d), kv_index),
            pl.BlockSpec((1, bs, 1, d), kv_index),
            pl.BlockSpec((1, bs, 1, 1), kv_index),
            pl.BlockSpec((1, bs, 1, 1), kv_index),
        ],
        out_specs=pl.BlockSpec((1, d), lambda bh, ik: (bh, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((8, 128), jnp.float32),
            pltpu.VMEM((8, 128), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(length.astype(jnp.int32), qf, k_cache, v_cache, k_scale, v_scale)
    return out.reshape(b, h, d)
