"""Pallas TPU kernel: per-channel absmax quantization.

Used at deploy time (weight conversion) and for KV-cache quantization bursts.
Grid over column strips; each strip reduces |w| over the full K dimension in
VMEM, then rounds. K x bn x 4B must fit VMEM (checked; ops.py falls back to
the jnp oracle for oversized K, where XLA streams the reduction).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

MAX_K_VMEM = 8192


def _quant_kernel(w_ref, codes_ref, scale_ref, *, qmax: int):
    w = w_ref[...].astype(jnp.float32)                   # [K, bn]
    absmax = jnp.max(jnp.abs(w), axis=0, keepdims=True)  # [1, bn]
    scale = jnp.maximum(absmax, 1e-8) / qmax
    codes = jnp.clip(jnp.round(w / scale), -qmax, qmax)
    codes_ref[...] = codes.astype(jnp.int8)
    scale_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("bits", "block_n", "interpret"))
def quantize_pallas(w, *, bits: int = 8, block_n: int = 256,
                    interpret: bool = False):
    """w: [K, N] -> (codes int8 [K, N], scale f32 [1, N])."""
    k, n = w.shape
    if k > MAX_K_VMEM:
        raise ValueError(f"K={k} exceeds single-pass VMEM budget; use ref")
    bn = min(block_n, n)
    if n % bn:
        raise ValueError(f"N={n} not divisible by block {bn}")
    qmax = (1 << (bits - 1)) - 1
    codes, scale = pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((k, bn), lambda j: (0, j))],
        out_specs=[pl.BlockSpec((k, bn), lambda j: (0, j)),
                   pl.BlockSpec((1, bn), lambda j: (0, j))],
        out_shape=[jax.ShapeDtypeStruct((k, n), jnp.int8),
                   jax.ShapeDtypeStruct((1, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(w)
    return codes, scale
