"""Sequence-sharded decode attention (shard_map): fused cache-update +
flash-decode with cross-shard softmax combine.

Why: long-context decode shards the KV cache's *sequence* dim over "model"
(kv_heads are too few to shard — glm4 has 2). Under plain pjit, the
per-token cache update is a scatter into a sharded dim at a traced index, and
GSPMD's fallback is to ALL-GATHER the cache (measured: 537 MB/layer/token on
glm4-9b:decode_32k — the dominant collective, §Perf hillclimb). This module
makes the distributed structure explicit:

  * every "model" shard owns seq rows [lo, hi); the new token's K/V is
    written LOCALLY by the owning shard (a where-masked scatter — zero
    communication);
  * each shard computes a partial flash-decode (m, l, acc) over its rows;
  * the combine is the flash-decode reduction: m* = pmax(m),
    l* = psum(l·e^{m-m*}), acc* = psum(acc·e^{m-m*}) — communication is
    O(B·H·d) per layer instead of O(B·S·Hk·d).

Works for bf16 and int8-quantized caches (scales ride along).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

NEG_INF = -1e30


def _local_update(cache, new_val, pos, lo, s_local):
    """Write new_val [B, Hk, d] at seq position pos[b]-lo when owned."""
    b = cache.shape[0]
    local_pos = pos - lo
    in_range = (local_pos >= 0) & (local_pos < s_local)
    idx = jnp.clip(local_pos, 0, s_local - 1)
    bidx = jnp.arange(b)
    old = cache[bidx, idx]                                   # [B, Hk, d]
    val = jnp.where(in_range[:, None, None], new_val.astype(cache.dtype),
                    old)
    return cache.at[bidx, idx].set(val)


def _partial_attend(q, k, v, k_scale, v_scale, lo, length, scale):
    """Local flash-decode over this shard's rows.

    q: [B, H, d]; k/v: [B, S_loc, Hk, d]; returns (m, l, acc) partials."""
    b, h, d = q.shape
    s_loc, hk = k.shape[1], k.shape[2]
    rep = h // hk
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale
        vf = vf * v_scale
    # [B, S, Hk, d] -> [B, S, H, d]
    kf = jnp.repeat(kf, rep, axis=2)
    vf = jnp.repeat(vf, rep, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), kf) * scale
    kpos = lo + jnp.arange(s_loc)
    valid = kpos[None, None, :] < length[:, None, None]
    s = jnp.where(valid, s, NEG_INF)
    m = s.max(axis=-1)                                       # [B, H]
    p = jnp.exp(s - m[..., None]) * valid.astype(jnp.float32)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhs,bshd->bhd", p, vf)
    return m, l, acc


def decode_attention_seqsharded(q, k_cache, v_cache, new_k, new_v, pos,
                                length, mesh: Mesh,
                                seq_axes: Tuple[str, ...],
                                batch_axes: Tuple[str, ...],
                                k_scale=None, v_scale=None,
                                new_k_scale=None, new_v_scale=None):
    """Fused update+attend. Shapes (global):
      q, new_k, new_v: [B, H|Hk, d]; caches: [B, S, Hk, d]; pos/length: [B].
    Returns (out [B, H, d], k_cache', v_cache', k_scale', v_scale')."""
    b, s = k_cache.shape[0], k_cache.shape[1]
    d = q.shape[-1]
    n_seq = 1
    for ax in seq_axes:
        n_seq *= mesh.shape[ax]
    s_local = s // n_seq
    quantized = k_scale is not None
    seq_spec = seq_axes[0] if len(seq_axes) == 1 else tuple(seq_axes)
    bspec = batch_axes[0] if len(batch_axes) == 1 else \
        (tuple(batch_axes) if batch_axes else None)

    cache_p = P(bspec, seq_spec, None, None)
    scale_p = P(bspec, seq_spec, None, None)
    vec_p = P(bspec, None, None)
    s1_p = P(bspec)

    in_specs = [vec_p, cache_p, cache_p, vec_p, vec_p, s1_p, s1_p]
    out_specs = [vec_p, cache_p, cache_p]
    args = [q, k_cache, v_cache, new_k, new_v, pos, length]
    if quantized:
        in_specs += [scale_p, scale_p, vec_p, vec_p]
        out_specs += [scale_p, scale_p]
        args += [k_scale, v_scale, new_k_scale, new_v_scale]

    axis_for_index = seq_axes

    def body(q_l, k_l, v_l, nk, nv, pos_l, len_l, *rest):
        # shard index along the (possibly compound) seq axes
        idx = 0
        for ax in axis_for_index:
            idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        lo = idx * s_local
        if quantized:
            ks_l, vs_l, nks, nvs = rest
            k_l2 = _local_update(k_l, nk, pos_l, lo, s_local)
            v_l2 = _local_update(v_l, nv, pos_l, lo, s_local)
            ks2 = _local_update(ks_l, nks, pos_l, lo, s_local)
            vs2 = _local_update(vs_l, nvs, pos_l, lo, s_local)
            m, l, acc = _partial_attend(q_l, k_l2, v_l2, ks2, vs2, lo,
                                        len_l, 1.0 / (d ** 0.5))
        else:
            k_l2 = _local_update(k_l, nk, pos_l, lo, s_local)
            v_l2 = _local_update(v_l, nv, pos_l, lo, s_local)
            m, l, acc = _partial_attend(q_l, k_l2, v_l2, None, None, lo,
                                        len_l, 1.0 / (d ** 0.5))
        # cross-shard flash combine over the seq axes
        for ax in axis_for_index:
            m_g = jax.lax.pmax(m, ax)
            corr = jnp.exp(m - m_g)
            l = jax.lax.psum(l * corr, ax)
            acc = jax.lax.psum(acc * corr[..., None], ax)
            m = m_g
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q_l.dtype)
        if quantized:
            return out, k_l2, v_l2, ks2, vs2
        return out, k_l2, v_l2

    fn = shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                   out_specs=tuple(out_specs), check_rep=False)
    return fn(*args)
