"""Pallas TPU kernel: fused AxLLM dequant-matmul.

TPU mapping of the paper's Result Cache (DESIGN.md §2): weights live in HBM as
q-bit codes; the 2^q-entry codebook (the RC) is resident in VMEM for the whole
kernel invocation and every weight tile is dequantized *in VMEM* right before
the MXU contraction — the product of an input element with each unique value
is materialized once per tile in registers/VMEM, never re-fetched from HBM.
The HBM traffic is `bytes(int8 codes) = N·M` instead of `2·N·M` (bf16) or
`4·N·M` (f32); for int4-codebook mode it is `N·M/2` plus a 16-float table.

Layout & tiling
  x     [M, K]   activations (bf16/f32), blocked (bm, bk)
  codes [K, N]   int8 (or uint8-packed int4), blocked (bk, bn)
  scale per-channel [1, N] f32, blocked (1, bn)       (affine / codebook)
        per-group  [K/g, N] f32, blocked (bk/g, bn)   (per_group affine)
  out   [M, N]   f32 accumulation across the K grid dimension.

Grid = (M/bm, N/bn, K/bk), K innermost ("arbitrary" semantics) so the f32
accumulator tile persists in VMEM scratch across K steps. MXU-aligned block
defaults (bm, bk, bn) = (128, 512, 256); VMEM footprint ≈ x 128·512·4 +
codes 512·256 + acc 128·256·4 ≈ 0.5 MB — far under the ~16 MB v5e budget,
leaving room for double buffering.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

DEFAULT_BLOCKS = (128, 512, 256)  # (bm, bk, bn)

# Decode-shape M blocks, preferred order. Serving batches are small
# (m = n_slots·decode tokens, typically 1..64); picking the largest entry
# that divides m exactly gives a no-pad fast path for m ∈ {8..64} instead
# of rounding every call up to the 128-row tile. Skinny-m launches pair
# with a widened bn (ops.pick_blocks) to keep the MXU busy.
SKINNY_BM = (64, 32, 16, 8)


def _dequant_tile(codes, scale_tile, codebook, bits: int, group_size: int):
    """codes [bk, bn] int -> w f32 [bk, bn], inside the kernel (VMEM)."""
    if codebook is None:
        w = codes.astype(jnp.float32)
        if scale_tile.ndim == 2 and scale_tile.shape[0] > 1:
            # per-group: scale [bk/g, bn] -> broadcast over rows within group
            g = group_size
            bk, bn = codes.shape
            w = w.reshape(bk // g, g, bn) * scale_tile[:, None, :]
            return w.reshape(bk, bn)
        return w * scale_tile  # per-channel [1, bn]
    # codebook mode: 2^bits-entry RC lookup as a one-hot MXU contraction
    # (16-entry for int4 — 6% FLOP overhead at bn=256; the gather-free form
    # TPUs prefer). codes are recentred to [0, 2^bits).
    n_levels = 1 << bits
    offset = 1 << (bits - 1)
    onehot = jax.nn.one_hot(codes + offset, n_levels, dtype=jnp.float32)
    w = jax.lax.dot_general(
        onehot, codebook.astype(jnp.float32),
        (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return w * scale_tile


def _unpack_nibbles(packed):
    """uint8 [bk, bn/2] -> int8-valued int32 [bk, bn] in [-8, 7]."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    bk, half = packed.shape
    return jnp.stack([lo, hi], axis=-1).reshape(bk, half * 2)


def _axllm_kernel(x_ref, codes_ref, scale_ref, cb_ref, out_ref, acc_ref, *,
                  bits: int, packed: bool, group_size: int, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = codes_ref[...]
    if packed:
        codes = _unpack_nibbles(codes)
    cb = cb_ref[...] if cb_ref is not None else None
    w = _dequant_tile(codes, scale_ref[...], cb, bits, group_size)
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=(
    "bits", "packed", "group_size", "blocks", "interpret"))
def axllm_matmul_pallas(x: jax.Array, codes: jax.Array, scale: jax.Array,
                        codebook: Optional[jax.Array] = None, *,
                        bits: int = 8, packed: bool = False,
                        group_size: int = 128,
                        blocks=DEFAULT_BLOCKS,
                        interpret: bool = False) -> jax.Array:
    """y[M, N] = x[M, K] @ deq(codes[K, N]); see module docstring.

    `scale` must be [1, N] (per_channel/per_tensor broadcast) or [K/g, N]
    (per_group). `codes` is [K, N] int8, or [K, N//2] uint8 when packed.
    """
    m, kdim = x.shape
    n = scale.shape[-1]
    bm, bk, bn = blocks
    bm = min(bm, m)
    bk = min(bk, kdim)
    bn = min(bn, n)
    if m % bm or kdim % bk or n % bn:
        raise ValueError(f"shape ({m},{kdim},{n}) not divisible by blocks "
                         f"({bm},{bk},{bn})")
    n_k = kdim // bk
    per_group = scale.shape[0] > 1
    if per_group and bk % group_size:
        raise ValueError("per_group requires group_size | bk")

    x_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))
    if packed:
        codes_spec = pl.BlockSpec((bk, bn // 2), lambda i, j, k: (k, j))
    else:
        codes_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
    if per_group:
        scale_spec = pl.BlockSpec((bk // group_size, bn),
                                  lambda i, j, k: (k, j))
    else:
        scale_spec = pl.BlockSpec((1, bn), lambda i, j, k: (0, j))
    out_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))

    in_specs = [x_spec, codes_spec, scale_spec]
    args = [x, codes, scale]
    if codebook is not None:
        in_specs.append(pl.BlockSpec((1 << bits,), lambda i, j, k: (0,)))
        args.append(codebook)

    kernel = functools.partial(
        _axllm_kernel if codebook is not None else _axllm_kernel_nocb,
        bits=bits, packed=packed, group_size=group_size, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)


def _axllm_kernel_nocb(x_ref, codes_ref, scale_ref, out_ref, acc_ref, *,
                       bits: int, packed: bool, group_size: int, n_k: int):
    _axllm_kernel(x_ref, codes_ref, scale_ref, None, out_ref, acc_ref,
                  bits=bits, packed=packed, group_size=group_size, n_k=n_k)
