"""Pallas TPU kernel: AxLLM reuse (LUT) matmul — the paper's core, on device.

Where :mod:`repro.kernels.axllm_matmul` dequantizes every weight code and
multiplies (one MAC per element), this kernel implements the Result-Cache
semantics of paper §III.b: once per activation tile it materializes the
product of every activation element with the *code alphabet* — a
``levels``-entry table per (row, k) pair, SqueezeLLM/FineQuant-style — and
then *gathers* table entries for every repeated code instead of multiplying
again. For q-bit weights a row segment can contain at most ``2**q`` distinct
values, so the table build costs ``bm x bk x L`` multiplies and everything
past the first occurrence of a code is an add-only reuse.

Alphabet (shared contract with core/reuse.rc_alphabet — regression-pinned):
  affine    levels = [0 .. qmax] magnitudes, sign-folded: code ``c`` reads
            cell ``|c|`` and the sign rides on the gather (the paper's
            128-cell RC for 8-bit, 8 cells for int4). The per-channel
            ``scale/qmax`` factor is applied after the per-group reduction.
  codebook  levels = the explicit 2**bits table (NF4 / identity), unfolded:
            cell ``c + 2**(bits-1)``. NF4 is not sign-symmetric, so no fold.

TPU mapping: the gather is expressed as a signed one-hot contraction
(``[bm, bk*L] @ [bk*L, bn]``) — the gather-free form the MXU prefers; the
0/1 selector rows are the "adds" of the reuse path. The vector-unit table
build is the only place activation values are multiplied. The kernel also
*measures* its reuse: a second output accumulates, once per (j, k) tile, the
number of distinct alphabet cells per k-row within the bn-wide column
segment — i.e. the multiplies a Result Cache would actually execute. The
wrapper scales this by the logical M to report the achieved multiply count,
directly comparable against ``core.reuse.segment_unique_counts`` /
``simulator.simulate_matrix`` predictions (kernel_bench's
predicted-vs-achieved row).

Grid = (M/bm, N/bn, K/bk), all "arbitrary": the multiply-count output is a
single revisited (1, 1) block accumulated across grid steps, which requires
the sequential traversal order. VMEM per tile is dominated by the one-hot
selector (bk x bn x L f32); ops.pick_blocks caps bk so bk*L stays within
budget (per_group tiles floor at one group).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels.axllm_matmul import _unpack_nibbles

# bk * n_levels budget for the LUT/selector tiles (f32 words per activation
# row / output column). 8192 keeps the selector tile ≈ bn * 32 KB.
REUSE_BK_LEVELS = 8192


def _reuse_kernel(x_ref, codes_ref, scale_ref, levels_ref, out_ref,
                  mults_ref, acc_ref, *, packed: bool, fold_sign: bool,
                  groups: int, n_k: int):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((i == 0) & (j == 0) & (k == 0))
    def _init_count():
        mults_ref[...] = jnp.zeros_like(mults_ref)

    codes = codes_ref[...]
    if packed:
        codes = _unpack_nibbles(codes)
    codes = codes.astype(jnp.int32)
    levels = levels_ref[...].astype(jnp.float32)        # [L]
    n_levels = levels.shape[0]
    if fold_sign:
        cells = jnp.abs(codes)                          # [bk, bn] in [0, L)
        sign = jnp.where(codes < 0, -1.0, 1.0).astype(jnp.float32)
    else:
        cells = codes + (n_levels >> 1)
        sign = None
    onehot = jax.nn.one_hot(cells, n_levels, dtype=jnp.float32)  # [bk,bn,L]
    sel = onehot if sign is None else onehot * sign[..., None]

    x = x_ref[...].astype(jnp.float32)                  # [bm, bk]
    bm, bk = x.shape
    bn = cells.shape[1]
    g = bk // groups
    # the LUT build: every alphabet product computed once per (row, k)
    tab = x[:, :, None] * levels[None, None, :]         # [bm, bk, L]
    tabg = tab.reshape(bm, groups, g * n_levels).transpose(1, 0, 2)
    selg = sel.reshape(groups, g, bn, n_levels) \
        .transpose(0, 1, 3, 2).reshape(groups, g * n_levels, bn)
    # the reuse path: signed 0/1 gather-sum per scale group on the MXU
    part = jax.lax.dot_general(
        tabg, selg, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)             # [groups, bm, bn]
    acc_ref[...] += jnp.sum(part * scale_ref[...][:, None, :], axis=0)

    # measured reuse: distinct cells per k-row within this bn segment are
    # the multiplies the RC executes; everything else was a table hit. The
    # count is activation-row-independent, so tally it once (i == 0).
    @pl.when(i == 0)
    def _count():
        present = jnp.max(onehot, axis=1)               # [bk, L]
        mults_ref[0, 0] += jnp.sum(present).astype(jnp.int32)

    @pl.when(k == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=(
    "packed", "fold_sign", "group_size", "blocks", "interpret"))
def reuse_matmul_pallas(x: jax.Array, codes: jax.Array, scale: jax.Array,
                        levels: jax.Array, *, packed: bool = False,
                        fold_sign: bool = True, group_size: int = 128,
                        blocks=(8, 128, 256),
                        interpret: bool = False):
    """(y[M, N], mults[1, 1]) = reuse-matmul; see module docstring.

    ``scale`` is [1, N] (per_channel, with /qmax folded for affine) or
    [K/g, N] (per_group). ``levels`` is the [L] f32 alphabet value table
    from ``core.reuse.rc_alphabet``. ``mults`` is the per-activation-row
    multiply count: the sum over (k-row, bn-segment) of distinct alphabet
    cells — multiply by M for the total the lane array would execute.
    """
    m, kdim = x.shape
    n = scale.shape[-1]
    bm, bk, bn = blocks
    bm = min(bm, m)
    bk = min(bk, kdim)
    bn = min(bn, n)
    if m % bm or kdim % bk or n % bn:
        raise ValueError(f"shape ({m},{kdim},{n}) not divisible by blocks "
                         f"({bm},{bk},{bn})")
    n_k = kdim // bk
    per_group = scale.shape[0] > 1
    if per_group and bk % group_size:
        raise ValueError("per_group requires group_size | bk")
    groups = bk // group_size if per_group else 1

    x_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))
    if packed:
        codes_spec = pl.BlockSpec((bk, bn // 2), lambda i, j, k: (k, j))
    else:
        codes_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
    if per_group:
        scale_spec = pl.BlockSpec((groups, bn), lambda i, j, k: (k, j))
    else:
        scale_spec = pl.BlockSpec((1, bn), lambda i, j, k: (0, j))
    levels_spec = pl.BlockSpec((levels.shape[0],), lambda i, j, k: (0,))
    out_specs = [pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
                 pl.BlockSpec((1, 1), lambda i, j, k: (0, 0))]

    kernel = functools.partial(
        _reuse_kernel, packed=packed, fold_sign=fold_sign, groups=groups,
        n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[x_spec, codes_spec, scale_spec, levels_spec],
        out_specs=out_specs,
        out_shape=[jax.ShapeDtypeStruct((m, n), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(x, codes, scale, levels)
