"""chameleon-34b [vlm]: early-fusion VLM backbone (arXiv:2405.09818).

The modality frontend (VQ image tokenizer) is a STUB: image tokens share the
65536-entry vocabulary, so `input_specs()` feeds token ids only. Backbone is
a dense llama-like decoder with qk-norm (chameleon's stabilization trick).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    head_dim=128,
    qk_norm=True,
    act="swiglu",
    grad_accum=16,
    int8_optimizer=True,
)
