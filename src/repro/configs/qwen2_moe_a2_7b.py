"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed experts top-4
(hf:Qwen/Qwen1.5-MoE-A2.7B). 60 experts pad to 64 for even 16-way expert
sharding (dummy experts masked -inf in the router — exact)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    head_dim=128,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    expert_pad_to=16,          # 60 -> 64
    capacity_factor=1.25,
    qkv_bias=True,
    act="swiglu",
    grad_accum=4,
)
