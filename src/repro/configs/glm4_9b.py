"""glm4-9b [dense]: RoPE + GQA with only 2 KV heads (hf:THUDM/glm-4-9b).
kv=2 cannot shard 16-way -> the divisibility fallback replicates KV
projections and the KV cache shards its *sequence* dim instead."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    head_dim=128,
    act="swiglu",
    grad_accum=4,
)
