"""arctic-480b [moe]: 128 experts top-2 + dense residual FFN
(hf:Snowflake/snowflake-arctic-base). The dominant weight surface is the
expert bank — the strongest case for AxLLM reuse (Fig. 8: reuse grows with
matrix size/count) and the framework's expert-parallel + int8-optimizer path.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    head_dim=128,
    n_experts=128,
    top_k=2,
    moe_dense_residual=True,
    capacity_factor=1.25,
    act="swiglu",
    grad_accum=32,
    int8_optimizer=True,
)
