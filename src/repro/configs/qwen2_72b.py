"""qwen2-72b [dense]: GQA with QKV bias (arXiv:2407.10671). The largest dense
arch in the pool — FSDP + TP + grad accumulation are required to fit."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    act="swiglu",
    grad_accum=16,
    int8_optimizer=True,
)
