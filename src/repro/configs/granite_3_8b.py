"""granite-3-8b [dense]: GQA (hf:ibm-granite/granite-3.0-2b-base family).
vocab 49155 pads to 49408 (multiple of 256) with masked logits."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    head_dim=128,
    act="swiglu",
    grad_accum=4,
)
