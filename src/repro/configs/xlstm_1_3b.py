"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks (arXiv:2405.04517), ratio 7:1
(every 8th block is sLSTM -> 6 superblocks of 7 mLSTM + 1 sLSTM = 48).
d_ff=0: blocks carry their own projections (mLSTM pre-up x2, sLSTM GeGLU 4/3).
Constant-size recurrent state => runs the long_500k cell."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm_slstm_every=8,
    ssm_conv=4,
    act="gelu",
    grad_accum=8,
)
