"""repro-100m: the ~100M-parameter dense LM used by the end-to-end training
example (examples/train_lm.py) and as the source of *real trained weights*
for the reuse-rate validation (benchmarks/reuse_rate.py cross-checks Fig. 8
statistics on these weights vs the Gaussian surrogate)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="repro-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab_size=32000,
    head_dim=64,
    act="swiglu",
    grad_accum=1,
    tie_embeddings=True,
)
