"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block every 6
layers (arXiv:2411.15242). ssm_state=64; 38 = 6 groups x 6 + 2 remainder
mamba layers. Sub-quadratic state => runs the long_500k cell (shared-attn KV
at 500k shards its sequence dim over data x model)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    hybrid_attn_every=6,
    act="gelu",
    grad_accum=8,
)
