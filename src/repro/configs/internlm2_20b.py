"""internlm2-20b [dense]: GQA decoder (arXiv:2403.17297)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    head_dim=128,
    act="swiglu",
    grad_accum=8,
)
