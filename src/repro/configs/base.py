"""ModelConfig: one dataclass describing every architecture in the pool.

Exact assigned configs live in sibling modules (one file per arch); reduced
smoke variants are derived via :meth:`ModelConfig.reduced`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_dense_residual: bool = False      # arctic: dense FFN in parallel
    capacity_factor: float = 1.25
    expert_pad_to: int = 16               # pad experts for even sharding

    # --- attention ----------------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4

    # --- block --------------------------------------------------------------
    act: str = "swiglu"                   # swiglu | gelu
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- SSM / xLSTM / hybrid ------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    xlstm_slstm_every: int = 0            # every Nth block is sLSTM (7:1 -> 8)
    hybrid_attn_every: int = 0            # zamba2: shared attn every N layers

    # --- encoder-decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500                   # frontend stub: precomputed frames
    d_feat: int = 80                      # stub feature dim

    # --- padding -------------------------------------------------------------
    vocab_pad_multiple: int = 256

    # --- training / memory knobs (per-arch, used by launch + dry-run) --------
    remat: bool = True
    grad_accum: int = 1
    grad_accum_dtype: str = "float32"     # "bfloat16": halve accumulator mem
    scan_layers: bool = True
    int8_optimizer: bool = False          # blockwise-int8 Adam moments
    dtype: str = "bfloat16"

    # --- AxLLM serving -------------------------------------------------------
    quant_bits: int = 8                   # serve-path weight codes
    quant_kv: bool = False                # int8 KV cache (beyond-paper lever)
    fuse_qkv: bool = False                # fused wqkv/gate_up projections
    decode_chunk: int = 8                 # on-device decode steps per dispatch
    shard_cache_seq: bool = True          # shard KV seq dim when kv heads < axis
    eos_id: Optional[int] = None          # serve-path stop token (None: run to max_new)

    # ------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def padded_experts(self) -> int:
        if not self.n_experts:
            return 0
        m = self.expert_pad_to
        return ((self.n_experts + m - 1) // m) * m

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic state: SSM/hybrid run long_500k; attention archs skip."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every pool arch decodes (whisper via its decoder)

    def n_params(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, dff, hd = self.d_model, self.d_ff, self.resolved_head_dim
        h, hk = self.n_heads, self.n_kv_heads
        attn = d * h * hd + 2 * d * hk * hd + h * hd * d
        if self.act == "swiglu":
            ffn = 3 * d * dff
        else:
            ffn = 2 * d * dff
        per_layer = attn
        if self.family == "moe":
            shared = 3 * d * dff * self.n_shared_experts
            routed = 3 * d * dff * self.n_experts
            dense_res = 3 * d * dff if self.moe_dense_residual else 0
            per_layer += shared + routed + dense_res + d * self.n_experts
        elif self.family in ("ssm",):      # xLSTM: internal projections
            di = self.ssm_expand * d
            per_layer += 2 * d * di + di * d + 4 * (di // 1) * hd
        elif self.family == "hybrid":
            di = self.ssm_expand * d
            per_layer += 2 * d * di + di * d
        else:
            per_layer += ffn
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        enc = 0
        if self.is_encoder_decoder:
            enc = self.n_enc_layers * (attn + ffn)
        return self.n_layers * per_layer + emb + enc

    def n_active_params(self) -> int:
        """Per-token active params (MoE: top_k + shared + dense residual)."""
        if self.family != "moe":
            return self.n_params()
        d, dff = self.d_model, self.d_ff
        full = self.n_params()
        routed_all = self.n_layers * 3 * d * dff * self.n_experts
        routed_active = self.n_layers * 3 * d * dff * self.top_k
        return full - routed_all + routed_active

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2 if not self.xlstm_slstm_every
                         else self.xlstm_slstm_every),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads <
            self.n_heads else 4,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32,
            vocab_pad_multiple=64,
            grad_accum=1,
        )
        if self.n_experts:
            # capacity 8x: no token dropping at smoke scale, so the
            # decode==forward consistency checks are exact (the production
            # 1.25x capacity drops by design)
            small.update(n_experts=8, top_k=min(self.top_k, 2),
                         n_shared_experts=min(self.n_shared_experts, 1),
                         expert_pad_to=8, capacity_factor=8.0)
        if self.ssm_state:
            small.update(ssm_state=16, ssm_head_dim=16)
        if self.is_encoder_decoder:
            small.update(n_enc_layers=2, enc_seq=64, d_feat=16)
        if self.hybrid_attn_every:
            small.update(n_layers=4, hybrid_attn_every=2)
        if self.xlstm_slstm_every:
            small.update(n_layers=4, xlstm_slstm_every=2)
        small.update(overrides)
        return dataclasses.replace(self, **small)
