"""whisper-small [audio]: enc-dec backbone (arXiv:2212.04356); conv/mel
frontend STUBBED — input_specs() supplies precomputed frame embeddings
[B, 1500, 80]. GELU + LayerNorm per the original; embeddings tied."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    head_dim=64,
    is_encoder_decoder=True,
    n_enc_layers=12,
    enc_seq=1500,
    d_feat=80,
    act="gelu",
    norm="layernorm",
    tie_embeddings=True,
    grad_accum=2,
)
