"""Config registry: ``--arch <id>`` resolution for every assigned
architecture (exact configs from the assignment) plus the framework's own
example model."""

from __future__ import annotations

from repro.configs.base import ModelConfig

from repro.configs.chameleon_34b import CONFIG as _chameleon
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen2_moe
from repro.configs.xlstm_1_3b import CONFIG as _xlstm
from repro.configs.internlm2_20b import CONFIG as _internlm2
from repro.configs.qwen2_72b import CONFIG as _qwen2
from repro.configs.granite_3_8b import CONFIG as _granite
from repro.configs.glm4_9b import CONFIG as _glm4
from repro.configs.whisper_small import CONFIG as _whisper
from repro.configs.zamba2_1_2b import CONFIG as _zamba2
from repro.configs.repro_100m import CONFIG as _repro100m

REGISTRY = {c.name: c for c in [
    _chameleon, _arctic, _qwen2_moe, _xlstm, _internlm2, _qwen2,
    _granite, _glm4, _whisper, _zamba2, _repro100m,
]}

ASSIGNED = [c.name for c in [
    _chameleon, _arctic, _qwen2_moe, _xlstm, _internlm2, _qwen2,
    _granite, _glm4, _whisper, _zamba2,
]]


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def apply_overrides(cfg: ModelConfig, overrides: dict) -> ModelConfig:
    """CLI --set key=value support (typed via dataclass field types)."""
    import dataclasses

    fields = {f.name: f for f in dataclasses.fields(cfg)}
    typed = {}
    for k, v in overrides.items():
        if k not in fields:
            raise KeyError(f"unknown config field {k!r}")
        t = fields[k].type
        if t in ("int", int):
            typed[k] = int(v)
        elif t == "Optional[int]":
            typed[k] = None if str(v).lower() in ("none", "") else int(v)
        elif t in ("float", float):
            typed[k] = float(v)
        elif t in ("bool", bool):
            typed[k] = str(v).lower() in ("1", "true", "yes")
        else:
            typed[k] = v
    return dataclasses.replace(cfg, **typed)
