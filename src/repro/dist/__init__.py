"""Distributed substrate: logical-axis sharding, compressed cross-pod
gradient exchange, ring collective matmuls, and stage pipelining.

Modules (each maps to a ROADMAP scaling lever — see README.md here):
  sharding          logical-name -> mesh-axis rule translation + contexts
  compression       int8 error-feedback allreduce for the DCN "pod" axis
  collective_matmul ring all-gather / reduce-scatter matmuls (comm/compute
                    overlap for TP weight shards)
  pipeline          GPipe-style microbatch stage parallelism
"""

from repro.dist import sharding  # noqa: F401
