"""int8 error-feedback gradient exchange for the cross-pod "pod" axis.

The multi-pod mesh (launch/mesh.py) runs pure data parallelism between
pods, so each step moves a full gradient copy over the inter-pod DCN —
the slowest link in the system. This module compresses that exchange to
int8 blocks with per-block scales (~3.9x wire reduction, `wire_bytes`)
and keeps the quantization residual LOCALLY as error feedback: the
residual is added to the next step's gradient before quantizing, so the
accumulated update converges to the exact accumulated gradient (the
1-bit-Adam/EF-SGD argument; tested to <0.5% accumulated error in
tests/test_distributed.py).

Intended call site: inside shard_map over the "pod" axis, after the
in-pod reduce has produced each pod's local gradient.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256          # elements per scale block
_QMAX = 127.0
_SCALE_BYTES = 4     # one f32 scale per block


def _block_quantize(v: jax.Array, block: int) -> jax.Array:
    """Round-trip v through int8 codes with per-block absmax scales.

    Returns the dequantized value (the bits that would cross the wire:
    codes int8 + one f32 scale per block — `wire_bytes` does the
    accounting)."""
    flat = v.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True),
                        1e-12) / _QMAX
    codes = jnp.clip(jnp.round(blocks / scale), -_QMAX, _QMAX)
    deq = (codes.astype(jnp.float32) * scale).reshape(-1)[:n]
    return deq.reshape(v.shape).astype(v.dtype)


def compressed_allreduce_mean(x: jax.Array, axis_name: str,
                              err: Optional[jax.Array] = None,
                              block: int = BLOCK
                              ) -> Tuple[jax.Array, jax.Array]:
    """Mean of `x` over `axis_name` through an int8 wire, with error
    feedback.

    x:   this shard's gradient (any shape).
    err: residual carried from the previous call (same shape; None or
         zeros on the first step).
    Returns (approximate mean, new residual). The residual never crosses
    the wire — feed it back into the next call."""
    v = x if err is None else x + err
    deq = _block_quantize(v, block)
    new_err = v - deq
    n = jax.lax.psum(1, axis_name)
    mean = jax.lax.psum(deq, axis_name) / n
    return mean, new_err


def wire_bytes(x, block: int = BLOCK) -> Tuple[int, int]:
    """(compressed, uncompressed) bytes for one shard's exchange of `x`.

    compressed = 1 byte/element + one f32 scale per block;
    uncompressed = the raw dtype bytes (f32 gradients: 4/element)."""
    n = 1
    for d in x.shape:
        n *= int(d)
    n_blocks = -(-n // block)
    itemsize = jnp.dtype(x.dtype).itemsize
    return n + n_blocks * _SCALE_BYTES, n * itemsize
