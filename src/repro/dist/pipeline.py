"""Stage-parallel (pipeline) execution over a "stage" mesh axis.

GPipe-style schedule inside one shard_map: stage s holds its slice of the
stacked per-stage params; microbatches enter stage 0 one tick apart and
activations hop stage->stage+1 by ppermute each tick. With S stages and M
microbatches the schedule runs M + S - 1 ticks — bubble fraction
(S-1)/(M+S-1), amortized by raising M (the classic GPipe trade).

The returned apply is numerically identical to running the stages
sequentially on each microbatch (tests/test_distributed.py): invalid
ticks are masked out of the output accumulation, and the final psum over
"stage" both gathers the last stage's writes and replicates the result.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def make_pipelined_apply(stage_fn: Callable, mesh, n_micro: int,
                         axis: str = "stage") -> Callable:
    """Build apply(stage_params, x) -> y.

    stage_fn: (params_s, act) -> act, one pipeline stage.
    stage_params: pytree with a leading [S] dim (sharded over `axis`).
    x: [n_micro, micro_batch, ...] microbatched input (replicated).
    """
    n_stages = mesh.shape[axis]
    perm = [(j, (j + 1) % n_stages) for j in range(n_stages)]

    def pipelined(ws_local, x):
        w = jax.tree_util.tree_map(lambda a: a[0], ws_local)
        s = jax.lax.axis_index(axis)
        outs = jnp.zeros_like(x)
        recv = jnp.zeros_like(x[0])
        for t in range(n_micro + n_stages - 1):
            m = t - s                      # microbatch at stage s this tick
            valid = (m >= 0) & (m < n_micro)
            inp = jnp.where(s == 0, x[jnp.clip(t, 0, n_micro - 1)], recv)
            y = stage_fn(w, inp)
            # only the last stage's valid ticks contribute output; invalid
            # ticks compute on stale ring data and are discarded here
            contrib = jnp.where((s == n_stages - 1) & valid, y, 0.0)
            outs = outs.at[jnp.clip(m, 0, n_micro - 1)].add(
                contrib.astype(outs.dtype))
            if t != n_micro + n_stages - 2:
                recv = jax.lax.ppermute(y, axis, perm)
        return jax.lax.psum(outs, axis)

    return shard_map(pipelined, mesh=mesh, in_specs=(P(axis), P()),
                     out_specs=P(), check_rep=False)
