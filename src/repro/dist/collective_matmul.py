"""Ring collective matmuls: overlap TP communication with MXU compute.

Under plain GSPMD a TP matmul lowers to all-gather-then-matmul (or
matmul-then-reduce-scatter): the collective serializes against the
contraction. The ring forms below split the contraction into one block
per shard and alternate matmul-block / ppermute-block, so each hop's
transfer hides behind the previous block's compute (the Wang et al.
"collective matmul" / TPU overlapped-AG pattern; see also the Pallas
ring-collective idiom in kernels/).

Both functions run INSIDE shard_map over `axis_name` and are numerically
equal to the dense x @ w (fp32 tolerance — identical per-block dots,
different summation order for the reduce-scatter form).

  ring_allgather_matmul      x:[B, K/p]  w:[K, N/p]  -> y:[B, N/p]
    (x is column-sharded; instead of all-gathering x up front, rotate
     x blocks around the ring and accumulate x_blk @ w[rows(blk)])

  ring_matmul_reducescatter  x:[B, K/p]  w:[K/p, N]  -> y:[B, N/p]
    (partial products are reduced while rotating: each output block
     travels the ring once, accumulating every shard's contribution)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _ring_perm(n: int):
    return [(j, (j + 1) % n) for j in range(n)]


def ring_allgather_matmul(x: jax.Array, w: jax.Array,
                          axis_name: str) -> jax.Array:
    """y_local = x_global @ w_local without materializing x_global.

    x: [B, K_loc] (this shard's column block of the [B, K] activations);
    w: [K, N_loc] (full contraction dim, this shard's output columns)."""
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    k_loc = x.shape[-1]
    acc = jnp.zeros((x.shape[0], w.shape[-1]), jnp.float32)
    xb = x
    # static trip count: n is the (known) mesh axis size, so the loop
    # unrolls and XLA pipelines ppermute(t) under dot(t)
    for t in range(n):
        src = (idx - t) % n            # owner of the block xb currently holds
        wb = jax.lax.dynamic_slice_in_dim(w, src * k_loc, k_loc, axis=0)
        acc = acc + jnp.dot(xb.astype(jnp.float32), wb.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
        if t != n - 1:
            xb = jax.lax.ppermute(xb, axis_name, _ring_perm(n))
    return acc.astype(x.dtype)


def ring_matmul_reducescatter(x: jax.Array, w: jax.Array,
                              axis_name: str) -> jax.Array:
    """y_local = reduce_scatter(x_local @ w_local) fused into the ring.

    x: [B, K_loc]; w: [K_loc, N] (this shard's rows of the full weight).
    Each shard's [B, N] partial product is never materialized: output
    column blocks circulate the ring, each shard adding its partial for
    the block it currently holds; after p-1 hops every block lands on its
    owner fully reduced."""
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    n_loc = w.shape[-1] // n
    xf = x.astype(jnp.float32)
    acc = jnp.zeros((x.shape[0], n_loc), jnp.float32)
    for t in range(n):
        # the chunk in hand is destined for shard (idx - t - 1); at the
        # final step that is idx itself — own partial added last, kept
        blk = (idx - t - 1) % n
        wb = jax.lax.dynamic_slice_in_dim(w, blk * n_loc, n_loc, axis=1)
        acc = acc + jnp.dot(xf, wb.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
        if t != n - 1:
            acc = jax.lax.ppermute(acc, axis_name, _ring_perm(n))
    return acc.astype(x.dtype)
