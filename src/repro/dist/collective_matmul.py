"""Ring collective matmuls: overlap TP communication with MXU compute.

Under plain GSPMD a TP matmul lowers to all-gather-then-matmul (or
matmul-then-reduce-scatter): the collective serializes against the
contraction. The ring forms below split the contraction into one block
per shard and alternate matmul-block / ppermute-block, so each hop's
transfer hides behind the previous block's compute (the Wang et al.
"collective matmul" / TPU overlapped-AG pattern; see also the Pallas
ring-collective idiom in kernels/).

Both functions run INSIDE shard_map over `axis_name` and are numerically
equal to the dense x @ w (fp32 tolerance — identical per-block dots,
different summation order for the reduce-scatter form).

  ring_allgather_matmul      x:[B, K/p]  w:[K, N/p]  -> y:[B, N/p]
    (x is column-sharded; instead of all-gathering x up front, rotate
     x blocks around the ring and accumulate x_blk @ w[rows(blk)])

  ring_matmul_reducescatter  x:[B, K/p]  w:[K/p, N]  -> y:[B, N/p]
    (partial products are reduced while rotating: each output block
     travels the ring once, accumulating every shard's contribution)

`w` may be a QTensor shard (the serving deploy-quantized layout): the per
-hop block slice then slices codes rows/columns — packed int4 codes pack
along OUT, so K-row slicing never splits a byte — together with the
matching per-group scale rows, and the block dot dispatches through
``ops.axllm_matmul`` (``impl="reuse"`` runs each block through the reuse
(LUT) kernel; in the dyadic regime the accumulated result is bit-exact
against ``ops.reuse_matmul`` on the gathered operand, since every
per-block dot and the fp32 accumulation are exact there regardless of
association — see tests/test_reuse_kernel.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import QTensor
from repro.kernels import ops


def _ring_perm(n: int):
    return [(j, (j + 1) % n) for j in range(n)]


def _qslice(qt: QTensor, start, size: int, axis: int) -> QTensor:
    """Static-size dynamic slice of a 2-D [K, N] QTensor along K (axis=0)
    or N (axis=1), keeping codes/scale/metadata consistent.

    `start` may be traced (it is `block_index * block_size` inside the
    ring); `size` must be static. Constraints are checked statically:
    K-blocks must cover whole scale groups, N-blocks of packed int4 codes
    must cover whole bytes."""
    if axis == 0:
        if qt.granularity == "per_group" and size % qt.group_size:
            raise ValueError(
                f"ring K-block {size} must be a multiple of the scale "
                f"group size {qt.group_size}")
        codes = jax.lax.dynamic_slice_in_dim(qt.codes, start, size, axis=0)
        scale = qt.scale
        if qt.granularity == "per_group":
            g = qt.group_size
            scale = jax.lax.dynamic_slice_in_dim(
                scale, start // g, size // g, axis=0)
        shape = (size, qt.shape[-1])
    else:
        csize = size
        cstart = start
        if qt.packed:
            if size % 2:
                raise ValueError(
                    f"ring N-block {size} of packed int4 codes must be even")
            csize, cstart = size // 2, start // 2
        codes = jax.lax.dynamic_slice_in_dim(qt.codes, cstart, csize, axis=-1)
        scale = qt.scale
        if qt.granularity in ("per_channel", "per_group"):
            scale = jax.lax.dynamic_slice_in_dim(scale, start, size, axis=-1)
        shape = (qt.shape[-2], size)
    return QTensor(codes=codes, scale=scale, codebook=qt.codebook,
                   bits=qt.bits, mode=qt.mode, granularity=qt.granularity,
                   group_size=qt.group_size, packed=qt.packed, shape=shape)


def _block_dot(xb: jax.Array, wb, impl: str) -> jax.Array:
    if isinstance(wb, QTensor):
        return ops.axllm_matmul(xb, wb, impl=impl, out_dtype=jnp.float32)
    return jnp.dot(xb.astype(jnp.float32), wb.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def ring_allgather_matmul(x: jax.Array, w, axis_name: str, *,
                          impl: str = "auto") -> jax.Array:
    """y_local = x_global @ w_local without materializing x_global.

    x: [B, K_loc] (this shard's column block of the [B, K] activations);
    w: [K, N_loc] (full contraction dim, this shard's output columns) —
    dense array or QTensor; `impl` selects the quantized block-dot kernel."""
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    k_loc = x.shape[-1]
    acc = jnp.zeros((x.shape[0], w.shape[-1]), jnp.float32)
    xb = x
    # static trip count: n is the (known) mesh axis size, so the loop
    # unrolls and XLA pipelines ppermute(t) under dot(t)
    for t in range(n):
        src = (idx - t) % n            # owner of the block xb currently holds
        if isinstance(w, QTensor):
            wb = _qslice(w, src * k_loc, k_loc, axis=0)
        else:
            wb = jax.lax.dynamic_slice_in_dim(w, src * k_loc, k_loc, axis=0)
        acc = acc + _block_dot(xb, wb, impl)
        if t != n - 1:
            xb = jax.lax.ppermute(xb, axis_name, _ring_perm(n))
    return acc.astype(x.dtype)


def ring_matmul_reducescatter(x: jax.Array, w, axis_name: str, *,
                              impl: str = "auto") -> jax.Array:
    """y_local = reduce_scatter(x_local @ w_local) fused into the ring.

    x: [B, K_loc]; w: [K_loc, N] (this shard's rows of the full weight —
    dense array or QTensor). Each shard's [B, N] partial product is never
    materialized: output column blocks circulate the ring, each shard
    adding its partial for the block it currently holds; after p-1 hops
    every block lands on its owner fully reduced."""
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    n_loc = w.shape[-1] // n
    xf = x.astype(jnp.float32)
    acc = jnp.zeros((x.shape[0], n_loc), jnp.float32)
    for t in range(n):
        # the chunk in hand is destined for shard (idx - t - 1); at the
        # final step that is idx itself — own partial added last, kept
        blk = (idx - t - 1) % n
        if isinstance(w, QTensor):
            wb = _qslice(w, blk * n_loc, n_loc, axis=1)
        else:
            wb = jax.lax.dynamic_slice_in_dim(w, blk * n_loc, n_loc, axis=1)
        acc = acc + _block_dot(xf, wb, impl)
        if t != n - 1:
            acc = jax.lax.ppermute(acc, axis_name, _ring_perm(n))
    return acc.astype(x.dtype)
