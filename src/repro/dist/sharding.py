"""Logical-axis sharding: rule translation from logical dim names to mesh
axes, with divisibility fallback and duplicate-axis avoidance.

Model code never names mesh axes. It tags dims with *logical* names
("batch", "seq", "mlp", "heads", "cache_seq", ...) via `shard(x, *names)`
and the active rule set decides which mesh axes those names occupy:

    with sharding.activate(mesh):            # DEFAULT_RULES
        step = jax.jit(train_step)           # shard() constraints bind here
        ...

Rules map a logical name to one mesh axis, a tuple of axes (the dim is
sharded over their product, greedy prefix by divisibility), or None
(replicate). A dim whose size does not divide the axis product falls back
to replication — glm4-9b's 2 kv heads on a 16-way "model" axis replicate
instead of erroring — and an axis already consumed by an earlier dim of
the same tensor is never reused (PartitionSpec validity).

Three rule sets cover the production variants (launch/dryrun.py):
  DEFAULT_RULES   train + serve default: FSDP weights ("embed" over
                  "data"), TP over "model", batch over ("pod", "data").
  SERVE_RULES     "-tp": TP-only weights — no FSDP all-gather per token.
  DP_SERVE_RULES  "-dp": replicate weights, spread batch over every axis
                  (small-arch serving).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.quantization import QTensor

# ---------------------------------------------------------------------------
# Rule sets
# ---------------------------------------------------------------------------

DEFAULT_RULES: Dict[str, Any] = {
    # activations / data
    "batch": ("pod", "data"),
    "seq": None,
    "expert": "model",
    # weights: FSDP along the embedding dim, TP along the wide dim
    "embed": "data",
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "vocab": "model",
    # KV-cache sequence dim (decode): shard over "model"; the long-context
    # variant also absorbs the idle "data" axis (batch=1 at 500k)
    "cache_seq": "model",
    "cache_seq_long": ("model", "data"),
    # pipeline stages (dist/pipeline.py meshes)
    "stage": "stage",
}

SERVE_RULES: Dict[str, Any] = dict(DEFAULT_RULES, embed=None)

# Tensor-parallel serving with head-sharded KV caches: when every layer's
# kv-head count divides the "model" axis, shard the cache along heads and
# keep the sequence dim local — decode attention then needs no cross-shard
# softmax combine. ServeEngine picks between this and SERVE_RULES (whose
# "cache_seq" rule routes decode through decode_attention_seqsharded) via
# `serve_rules_for`.
SERVE_HEAD_RULES: Dict[str, Any] = dict(
    SERVE_RULES, cache_seq=None, cache_seq_long=None)

DP_SERVE_RULES: Dict[str, Any] = dict(
    DEFAULT_RULES,
    batch=("pod", "data", "model"),
    embed=None, mlp=None, heads=None, kv_heads=None, vocab=None,
    expert=None, cache_seq=None, cache_seq_long=None,
)


# ---------------------------------------------------------------------------
# Mesh context
# ---------------------------------------------------------------------------

_state = threading.local()


def _stack():
    if not hasattr(_state, "stack"):
        _state.stack = []
    return _state.stack


def _current() -> Optional[Tuple[Any, Dict[str, Any]]]:
    """(mesh, rules) of the innermost active context, or None."""
    stack = _stack()
    return stack[-1] if stack else None


@contextlib.contextmanager
def activate(mesh, rules: Optional[Dict[str, Any]] = None):
    """Bind (mesh, rules) for `shard()` constraints and spec inference.

    The context is consulted at TRACE time — wrap the jit/lower call sites,
    not the executions."""
    _stack().append((mesh, dict(DEFAULT_RULES if rules is None else rules)))
    try:
        yield mesh
    finally:
        _stack().pop()


def _active_rules(rules: Optional[Dict[str, Any]]) -> Dict[str, Any]:
    if rules is not None:
        return rules
    ctx = _current()
    return ctx[1] if ctx is not None else DEFAULT_RULES


# ---------------------------------------------------------------------------
# Rule resolution
# ---------------------------------------------------------------------------

def resolve_spec(shape: Sequence[int], names: Sequence[Optional[str]], mesh,
                 rules: Dict[str, Any]) -> P:
    """Translate logical dim names into a PartitionSpec against `mesh`.

    Per dim: look up the rule (None / missing name -> replicate); keep the
    greedy prefix of rule axes that exist in the mesh, are unused by earlier
    dims, and whose cumulative product divides the dim size. `mesh` only
    needs a `.shape` mapping {axis: size} (tests pass stubs)."""
    axis_sizes = dict(mesh.shape)
    used = set()
    entries = []
    for dim, name in zip(shape, names):
        if name is None or name not in rules or rules[name] is None:
            entries.append(None)
            continue
        rule = rules[name]
        axes = (rule,) if isinstance(rule, str) else tuple(rule)
        chosen = []
        prod = 1
        for ax in axes:
            if ax not in axis_sizes or ax in used:
                continue
            if axis_sizes[ax] == 1:
                # a trivial axis contributes nothing; naming it would only
                # make the spec (and jit cache keys) differ from the
                # single-device program. Mesh size 1 must compile to
                # exactly the unsharded computation.
                continue
            if dim % (prod * axis_sizes[ax]):
                break  # growing the product further cannot restore divisibility
            chosen.append(ax)
            prod *= axis_sizes[ax]
        for ax in chosen:
            used.add(ax)
        if not chosen:
            entries.append(None)
        elif len(chosen) == 1:
            entries.append(chosen[0])
        else:
            entries.append(tuple(chosen))
    return P(*entries)


def named_sharding(shape: Sequence[int], names: Sequence[Optional[str]],
                   mesh, rules: Optional[Dict[str, Any]] = None
                   ) -> NamedSharding:
    return NamedSharding(mesh,
                         resolve_spec(shape, names, mesh,
                                      _active_rules(rules)))


def shard(x, *names: Optional[str]):
    """Logical sharding constraint; identity when no mesh context is active.

    Trailing unnamed dims replicate. Call sites live in models/* on
    activations — the constraint is a hint to GSPMD, never a layout
    obligation on callers."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = resolve_spec(x.shape, names, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Whole-tree spec inference (params / KV caches)
# ---------------------------------------------------------------------------

def _param_names(key: str, ndim: int) -> Tuple[Optional[str], ...]:
    """Logical names for a parameter leaf, right-aligned on its dims.

    Leading dims (stacked layers / super-blocks) replicate; the trailing
    [in, out] matrix takes ("embed", "mlp") -> (FSDP, TP). Named
    exceptions: embeddings, the untied head, MoE expert stacks (expert dim
    is the TP dim; activations stay replicated over "model" between MoE
    layers — see models/moe.py), and routers (tiny, replicated out dim).

    The block-output projections "wo" and "down" flip to ("mlp", "embed"):
    their *input* dim is the wide one, so the TP axis shards the
    contraction (row-parallel). Paired with column-parallel wqkv/gate_up
    this is the Megatron split — each attention/MLP block needs exactly one
    all-reduce, placed by GSPMD after the row-parallel matmul."""
    if ndim < 2:
        return (None,) * ndim
    if key == "embedding":
        return (None,) * (ndim - 2) + ("vocab", "embed")
    if key == "lm_head":
        return (None,) * (ndim - 2) + ("embed", "vocab")
    if key.startswith("expert_") and ndim >= 3:
        return (None,) * (ndim - 3) + ("expert", "embed", "mlp")
    if key == "router":
        return (None,) * (ndim - 2) + ("embed", None)
    if key in ("wo", "down"):
        return (None,) * (ndim - 2) + ("mlp", "embed")
    return (None,) * (ndim - 2) + ("embed", "mlp")


def _qtensor_specs(qt: QTensor, key: str, mesh, rules) -> QTensor:
    """Mirror a QTensor with NamedSharding children (same aux => same
    treedef, so jit in_shardings / tree_map pairing line up leaf-wise).

    Codes keep the weight's logical names (the packed int4 trailing dim
    simply fails divisibility more often and replicates); scales reuse the
    out-dim name on their last axis so dequant temporaries inherit the
    weight spec."""
    names = _param_names(key, len(qt.shape))
    codes_spec = NamedSharding(
        mesh, resolve_spec(qt.codes.shape, names[-qt.codes.ndim:], mesh,
                           rules))
    scale_names = [None] * (qt.scale.ndim - 1) + [names[-1]]
    if qt.granularity == "per_group" and qt.scale.ndim >= 3:
        # per-group scales [*, in//g, 1, out]: the group-row dim tracks the
        # weight's in-dim name so row-parallel codes keep their scale rows
        # local (divisibility falls back to replication as usual)
        scale_names[-3] = names[-2]
    scale_names = tuple(scale_names)
    scale_spec = NamedSharding(
        mesh, resolve_spec(qt.scale.shape, scale_names, mesh, rules))
    # codebook alphabets (tiny [2**bits] vectors) replicate; mirroring the
    # leaf (vs None) keeps the spec treedef identical to the value treedef
    # for tree_map(jax.device_put, params, specs) pairing
    cb_spec = None if qt.codebook is None else NamedSharding(mesh, P())
    return QTensor(codes=codes_spec, scale=scale_spec, codebook=cb_spec,
                   bits=qt.bits, mode=qt.mode, granularity=qt.granularity,
                   group_size=qt.group_size, packed=qt.packed, shape=qt.shape)


def param_specs(params, mesh, rules: Optional[Dict[str, Any]] = None):
    """NamedSharding pytree for a parameter tree (concrete or eval_shape).

    Structure matches `params` exactly — usable as jit in_shardings and
    with tree_map(jax.device_put, params, specs)."""
    rules = _active_rules(rules)

    def walk(key, node):
        if isinstance(node, dict):
            return {k: walk(k, v) for k, v in node.items()}
        if isinstance(node, (list, tuple)) and not hasattr(node, "shape"):
            return type(node)(walk(key, v) for v in node)
        if isinstance(node, QTensor):
            return _qtensor_specs(node, key, mesh, rules)
        names = _param_names(key, len(node.shape))
        return NamedSharding(mesh,
                             resolve_spec(node.shape, names, mesh, rules))

    return walk("", params)


_CACHE_KV_KEYS = ("k", "v", "k_scale", "v_scale")


def _cache_names(key: str, shape, batch: int) -> Tuple[Optional[str], ...]:
    ndim = len(shape)
    if key in _CACHE_KV_KEYS and ndim >= 4:
        # [*stack, B, S, Hk, hd|1]
        return ((None,) * (ndim - 4)
                + ("batch", "cache_seq", "kv_heads", None))
    if key == "pos":
        return ("batch",) + (None,) * (ndim - 1)
    # recurrent state (ssm/xlstm/hybrid): shard the batch dim only — the
    # leftmost dim whose size matches the batch (leading dims are stacked
    # layer counts)
    names = [None] * ndim
    for i, d in enumerate(shape):
        if d == batch:
            names[i] = "batch"
            break
    return tuple(names)


def cache_specs(cache, mesh, batch: int, max_len: int,
                long_context: bool = False,
                rules: Optional[Dict[str, Any]] = None):
    """NamedSharding pytree for a KV/state cache (see models/attention.py
    for the layout). `long_context=True` routes the sequence dim through the
    "cache_seq_long" rule (idle axes absorb the 500k cache)."""
    rules = dict(_active_rules(rules))
    if long_context and "cache_seq_long" in rules:
        rules["cache_seq"] = rules["cache_seq_long"]

    def walk(key, node):
        if isinstance(node, dict):
            return {k: walk(k, v) for k, v in node.items()}
        if isinstance(node, (list, tuple)) and not hasattr(node, "shape"):
            return type(node)(walk(key, v) for v in node)
        names = _cache_names(key, node.shape, batch)
        return NamedSharding(mesh,
                             resolve_spec(node.shape, names, mesh, rules))

    return walk("", cache)


def _paged_names(key: str, shape) -> Tuple[Optional[str], ...]:
    ndim = len(shape)
    if key in _CACHE_KV_KEYS and ndim >= 4:
        # pool leaves [*stack, NB, bs, Hk, hd|1]: shard heads only — the
        # block axis is the pager's address space and must stay whole on
        # every shard so block tables index identically everywhere
        return (None,) * (ndim - 2) + ("kv_heads", None)
    if key == "pos":
        return ("batch",) + (None,) * (ndim - 1)
    return (None,) * ndim  # block_tables replicated (host-written)


def paged_cache_specs(cache, mesh, rules: Optional[Dict[str, Any]] = None):
    """NamedSharding pytree for a block-paged KV cache (attention.py's
    paged layout: pools [L, NB, bs, Hk, hd], block_tables [B, MB]).

    Only the kv-head dim shards ("along heads"): every device holds the
    full block pool address space, so the host-side pager, radix prefix
    index, and copy-on-write block copies stay shard-oblivious."""
    rules = _active_rules(rules)

    def walk(key, node):
        if isinstance(node, dict):
            return {k: walk(k, v) for k, v in node.items()}
        if isinstance(node, (list, tuple)) and not hasattr(node, "shape"):
            return type(node)(walk(key, v) for v in node)
        names = _paged_names(key, node.shape)
        return NamedSharding(mesh,
                             resolve_spec(node.shape, names, mesh, rules))

    return walk("", cache)


def adapter_specs(stacked, mesh, rules: Optional[Dict[str, Any]] = None):
    """NamedSharding pytree for AdapterRegistry's stacked LoRA tensors
    ({target: {"lora_a": [L, M, n_in, r], "lora_b": [L, M, r, n_out]}}).

    A is replicated (its output is the tiny rank dim); B shards its out
    dim with the same logical name as the target projection's out dim, so
    the delta lands already laid out like the base projection's output:
    column-parallel for wq/wk/wv (out dim = sharded heads), replicated for
    wo (out dim = embed, which SERVE rules keep whole)."""
    rules = _active_rules(rules)
    out = {}
    for target, mats in stacked.items():
        b = mats["lora_b"]
        out_name = "embed" if target in ("wo", "down") else "mlp"
        b_names = (None,) * (b.ndim - 1) + (out_name,)
        out[target] = {
            "lora_a": NamedSharding(mesh, P()),
            "lora_b": NamedSharding(
                mesh, resolve_spec(b.shape, b_names, mesh, rules)),
        }
    return out


def serve_rules_for(mesh, n_kv_heads: int) -> Dict[str, Any]:
    """Pick the serving rule set for a mesh: head-sharded KV caches when
    the kv-head count divides the "model" axis (one collective per block,
    no attention-side communication), otherwise SERVE_RULES, whose
    "cache_seq" rule shards the cache sequence dim — models/attention.py
    detects that layout and routes decode through
    kernels.sharded_decode.decode_attention_seqsharded."""
    model = int(dict(mesh.shape).get("model", 1))
    if model <= 1 or n_kv_heads % model == 0:
        return SERVE_HEAD_RULES
    return SERVE_RULES
