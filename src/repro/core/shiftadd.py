"""ShiftAddLLM baseline (paper §V "Comparison with state-of-the-art", ref [9]).

The paper compares AxLLM against ShiftAddLLM: weights reparameterized as
W ≈ sum_i alpha_i * b_i with binary matrices b_i in {±1} and power-of-two
scales alpha_i; activations are processed via a lookup table holding the 2^8
precomputed partial sums of every 8-element activation subvector, and the
binary matrices index the LUT.

Two components here:

* **Numeric reimplementation** (:func:`binarize`, :func:`shiftadd_matmul`) —
  greedy residual binarization with power-of-two scale rounding, column-wise.
  It is an *approximation* (AxLLM is exact w.r.t. the quantized model); the
  reconstruction-error comparison feeds EXPERIMENTS.md.
* **Cycle model** (:func:`shiftadd_cycles`) — 64 shift-add units (matching the
  64-lane AxLLM), a LUT setup phase of 2^8 sums per 8-element subvector
  (AxLLM's zero-setup advantage, §V), and a main phase of q·N/8 LUT
  lookups+adds per output column. The paper states both designs take "the
  same number of steps" and credits AxLLM's 29% with (1) slice-level
  parallelism and (2) no setup phase; the LUT retire rate per unit is the one
  calibrated constant (1.454/cycle ⇒ dual-ported LUT banks at ~73% collision
  efficiency), fixed so DistilBERT reproduces the published 1.29× and then
  used unchanged for scaling analysis on the other models.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.simulator import ModelSpec, SimConfig, simulate_model


# ---------------------------------------------------------------------------
# Numeric reparameterization
# ---------------------------------------------------------------------------

def _round_pow2(x: np.ndarray) -> np.ndarray:
    """Round positive scales to the nearest power of two (in log space)."""
    x = np.maximum(x, 1e-12)
    return 2.0 ** np.round(np.log2(x))


def binarize(w: np.ndarray, q: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """Greedy residual binarization, column-wise.

    Returns (alphas [q, M], bits [q, N, M] in {-1, +1}) such that
    W ≈ sum_i alphas[i] * bits[i].
    """
    w = np.asarray(w, np.float64)
    n, m = w.shape
    alphas = np.zeros((q, m))
    bits = np.zeros((q, n, m), dtype=np.int8)
    r = w.copy()
    for i in range(q):
        b = np.where(r >= 0, 1, -1).astype(np.int8)
        a = np.mean(np.abs(r), axis=0)          # optimal alpha for sign basis
        a = _round_pow2(a)                       # shift-only scaling
        bits[i] = b
        alphas[i] = a
        r = r - a[None, :] * b
    return alphas, bits


def reconstruct(alphas: np.ndarray, bits: np.ndarray) -> np.ndarray:
    return np.einsum("qm,qnm->nm", alphas, bits.astype(np.float64))


def shiftadd_matmul(x: np.ndarray, alphas: np.ndarray,
                    bits: np.ndarray) -> np.ndarray:
    """y = x @ W_hat computed the ShiftAdd way (bit-plane partial sums)."""
    # per bit-plane: (x @ b_i) * alpha_i ; the LUT is an implementation detail
    # of the same arithmetic (8-element subvector sums), so numerics match.
    planes = np.einsum("tn,qnm->qtm", x.astype(np.float64),
                       bits.astype(np.float64))
    return np.einsum("qtm,qm->tm", planes, alphas)


def reconstruction_error(w: np.ndarray, q: int = 8) -> float:
    """Relative Frobenius error of the ShiftAdd reparameterization (AxLLM's
    counterpart error is exactly the int8 quantization error of the model)."""
    alphas, bits = binarize(w, q)
    w_hat = reconstruct(alphas, bits)
    return float(np.linalg.norm(w - w_hat) / np.linalg.norm(w))


# ---------------------------------------------------------------------------
# Cycle model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShiftAddConfig:
    units: int = 64            # parallel shift-add units (§V: matched config)
    q: int = 8                 # bit planes at 8-bit quantization
    group: int = 8             # activation subvector length per LUT
    lut_entries: int = 256     # 2^group precomputed sums
    # CALIBRATED: effective LUT lookups+adds retired per unit per cycle, fixed
    # so DistilBERT gives the published 1.29x AxLLM advantage (see module doc).
    # 1.5 = dual-ported LUT banks at 75% collision efficiency.
    lut_rate: float = 1.5


def shiftadd_cycles(n: int, m: int, tokens: int,
                    cfg: ShiftAddConfig = ShiftAddConfig()) -> float:
    """Cycles for x[tokens, n] @ W[n, m] on the ShiftAdd engine."""
    subvecs = n // cfg.group
    # setup: fill 2^8 sums per subvector (done per token; activations change)
    setup = subvecs * cfg.lut_entries / cfg.units
    # main: q bit-planes x m columns x subvec lookups+adds
    main = cfg.q * m * subvecs / (cfg.units * cfg.lut_rate)
    # power-of-two scale application: one shift-add per (plane, column)
    scales = cfg.q * m / cfg.units
    return tokens * (setup + main + scales)


def shiftadd_model_cycles(spec: ModelSpec,
                          cfg: ShiftAddConfig = ShiftAddConfig()) -> float:
    total = 0.0
    for mat in spec.matrices:
        total += (shiftadd_cycles(mat.n_in, mat.n_out, spec.tokens, cfg)
                  * mat.count * spec.layers)
    return total


def compare_vs_axllm(spec: ModelSpec, sim_cfg: SimConfig = SimConfig(),
                     sa_cfg: ShiftAddConfig = ShiftAddConfig(),
                     seed: int = 0) -> dict:
    rep = simulate_model(spec, sim_cfg, seed=seed)
    sa = shiftadd_model_cycles(spec, sa_cfg)
    return {
        "axllm_cycles": rep.cycles_axllm,
        "shiftadd_cycles": sa,
        "axllm_over_shiftadd": sa / rep.cycles_axllm,
        "shiftadd_over_baseline": rep.cycles_baseline / sa,
    }
