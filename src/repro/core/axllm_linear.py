"""Model-facing AxLLM modules: quantized linear + LoRA (paper §III).

These are the integration points every architecture in `repro.models` uses:
a linear layer whose weight may be a plain bf16 array (training / baseline)
or a :class:`QTensor` (AxLLM serving path — codes + codebook, dispatched to
the Pallas fused dequant-matmul on TPU). Swapping a trained model to the
AxLLM path is `quantize_tree(params, qcfg)` — post-training, zero setup,
exactly the paper's deployment story.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import QTensor, QuantConfig, quantize
from repro.kernels import ops

Array = Any


def linear(x: Array, w, *, impl: str = "auto", out_dtype=None) -> Array:
    """x @ w where w is an Array (dense path) or QTensor (AxLLM path)."""
    if isinstance(w, QTensor):
        return ops.axllm_matmul(x, w, impl=impl, out_dtype=out_dtype)
    y = jnp.dot(x, w.astype(x.dtype))
    return y if out_dtype is None else y.astype(out_dtype)


def concat_weights(ws) -> Array:
    """Concatenate linear weights along the output dim for a fused
    projection. All-dense concatenates arrays; all-QTensor routes through
    :func:`repro.core.quantization.qconcat` (exact — scales travel with
    their columns). Mixing the two is an error: fuse after
    `deploy_quantize`, not across the quantization boundary."""
    ws = list(ws)
    n_q = sum(isinstance(w, QTensor) for w in ws)
    if n_q == len(ws):
        from repro.core.quantization import qconcat
        return qconcat(ws)
    if n_q:
        raise TypeError("concat_weights: cannot fuse a mix of QTensor and "
                        "dense weights — quantize first, then fuse")
    return jnp.concatenate(ws, axis=-1)


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 16
    alpha: float = 32.0
    # which weight names get adapters (paper fine-tunes attention projections)
    targets: tuple = ("wq", "wk", "wv", "wo")

    @property
    def scaling(self) -> float:
        return self.alpha / self.rank


def lora_init(rng: jax.Array, n_in: int, n_out: int,
              cfg: LoRAConfig, dtype=jnp.float32) -> dict:
    """A ~ N(0, 1/r) (quantization-friendly: same value locality as W rows,
    which is what Fig. 5's combined [W ‖ A] reuse exploits), B = 0."""
    ka, _ = jax.random.split(rng)
    a = jax.random.normal(ka, (n_in, cfg.rank), dtype) / jnp.sqrt(cfg.rank)
    b = jnp.zeros((cfg.rank, n_out), dtype)
    return {"lora_a": a, "lora_b": b}


def lora_linear(x: Array, w, adapter: Optional[dict], cfg: LoRAConfig, *,
                impl: str = "auto", out_dtype=None) -> Array:
    """y = x @ W + scaling * (x @ A) @ B; W may be a QTensor (Fig. 5 path)."""
    if adapter is None:
        return linear(x, w, impl=impl, out_dtype=out_dtype)
    if isinstance(w, QTensor):
        return ops.lora_matmul(x, w, adapter["lora_a"], adapter["lora_b"],
                               cfg.scaling, impl=impl, out_dtype=out_dtype)
    y = jnp.dot(x, w.astype(x.dtype))
    xa = jnp.dot(x, adapter["lora_a"].astype(x.dtype))
    y = y + cfg.scaling * jnp.dot(xa, adapter["lora_b"].astype(x.dtype))
    return y if out_dtype is None else y.astype(out_dtype)


def merge_lora(w: Array, adapter: dict, cfg: LoRAConfig) -> Array:
    """Fold the adapter into a dense weight (for equivalence tests)."""
    return w + cfg.scaling * (adapter["lora_a"] @ adapter["lora_b"]).astype(
        w.dtype)


def deploy_quantize(params, qcfg: QuantConfig):
    """Post-training conversion of a trained pytree to the AxLLM serving
    representation (wraps quantize_tree; named for discoverability)."""
    from repro.core.quantization import quantize_tree
    return quantize_tree(params, qcfg)
