"""Model-facing AxLLM modules: quantized linear + LoRA (paper §III).

These are the integration points every architecture in `repro.models` uses:
a linear layer whose weight may be a plain bf16 array (training / baseline)
or a :class:`QTensor` (AxLLM serving path — codes + codebook, dispatched to
the Pallas fused dequant-matmul on TPU). Swapping a trained model to the
AxLLM path is `quantize_tree(params, qcfg)` — post-training, zero setup,
exactly the paper's deployment story.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import QTensor, QuantConfig, quantize
from repro.kernels import ops

Array = Any


def linear(x: Array, w, *, impl: str = "auto", out_dtype=None) -> Array:
    """x @ w where w is an Array (dense path) or QTensor (AxLLM path)."""
    if isinstance(w, QTensor):
        return ops.axllm_matmul(x, w, impl=impl, out_dtype=out_dtype)
    y = jnp.dot(x, w.astype(x.dtype))
    return y if out_dtype is None else y.astype(out_dtype)


def concat_weights(ws) -> Array:
    """Concatenate linear weights along the output dim for a fused
    projection. All-dense concatenates arrays; all-QTensor routes through
    :func:`repro.core.quantization.qconcat` (exact — scales travel with
    their columns). Mixing the two is an error: fuse after
    `deploy_quantize`, not across the quantization boundary."""
    ws = list(ws)
    n_q = sum(isinstance(w, QTensor) for w in ws)
    if n_q == len(ws):
        from repro.core.quantization import qconcat
        return qconcat(ws)
    if n_q:
        raise TypeError("concat_weights: cannot fuse a mix of QTensor and "
                        "dense weights — quantize first, then fuse")
    return jnp.concatenate(ws, axis=-1)


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 16
    alpha: float = 32.0
    # which weight names get adapters (paper fine-tunes attention projections)
    targets: tuple = ("wq", "wk", "wv", "wo")

    @property
    def scaling(self) -> float:
        return self.alpha / self.rank


def lora_init(rng: jax.Array, n_in: int, n_out: int,
              cfg: LoRAConfig, dtype=jnp.float32) -> dict:
    """A ~ N(0, 1/r) (quantization-friendly: same value locality as W rows,
    which is what Fig. 5's combined [W ‖ A] reuse exploits), B = 0."""
    ka, _ = jax.random.split(rng)
    a = jax.random.normal(ka, (n_in, cfg.rank), dtype) / jnp.sqrt(cfg.rank)
    b = jnp.zeros((cfg.rank, n_out), dtype)
    return {"lora_a": a, "lora_b": b}


def lora_linear(x: Array, w, adapter: Optional[dict], cfg: LoRAConfig, *,
                impl: str = "auto", out_dtype=None) -> Array:
    """y = x @ W + scaling * (x @ A) @ B; W may be a QTensor (Fig. 5 path)."""
    if adapter is None:
        return linear(x, w, impl=impl, out_dtype=out_dtype)
    if isinstance(w, QTensor):
        return ops.lora_matmul(x, w, adapter["lora_a"], adapter["lora_b"],
                               cfg.scaling, impl=impl, out_dtype=out_dtype)
    y = jnp.dot(x, w.astype(x.dtype))
    xa = jnp.dot(x, adapter["lora_a"].astype(x.dtype))
    y = y + cfg.scaling * jnp.dot(xa, adapter["lora_b"].astype(x.dtype))
    return y if out_dtype is None else y.astype(out_dtype)


def lora_delta_batched(x: Array, adapter: dict, idx: Array,
                       scaling: float) -> Array:
    """Gathered multi-adapter LoRA delta — the serve-path second pipeline.

    Computes ``scaling * (x @ A[idx]) @ B[idx]`` with a per-batch-row
    adapter selection, so one dispatch serves a mixed batch of base-only
    rows and rows running N different adapters (paper §III dual-pipeline:
    the base weight stays untouched — quantized or dense — while the
    low-rank delta rides alongside in bf16/fp32).

    x:        ``[B, ..., n_in]`` activations (any number of middle dims).
    adapter:  ``{"lora_a": [L, n_in, r], "lora_b": [L, r, n_out]}`` —
              ``L`` stacked adapters (an :class:`~repro.serve.adapters.
              AdapterRegistry` target entry for one layer).
    idx:      ``[B]`` int32 adapter row per batch element; ``-1`` means
              base-only (that row's delta is masked to exact zeros).
    scaling:  the LoRA ``alpha / rank`` factor.

    Returns a float32 ``[B, ..., n_out]`` delta (cast at the call site).
    Row ``i`` of the result is bit-identical to running the unbatched
    two-matmul LoRA path on ``x[i]`` with adapter ``idx[i]`` alone: the
    gather feeds the very same A/B operands into a per-row-independent
    contraction (property-tested in tests/test_adapters.py).
    """
    idx = jnp.asarray(idx, jnp.int32)
    safe = jnp.maximum(idx, 0)                      # -1 rows gather row 0 ...
    a = jnp.take(adapter["lora_a"], safe, axis=0).astype(jnp.float32)
    b = jnp.take(adapter["lora_b"], safe, axis=0).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    xa = jnp.einsum("b...k,bkr->b...r", xf, a)      # [B, ..., r]
    delta = jnp.einsum("b...r,brn->b...n", xa, b)   # [B, ..., n_out]
    mask = (idx >= 0).astype(jnp.float32)           # ... and are masked here
    mask = mask.reshape(idx.shape[0], *([1] * (x.ndim - 1)))
    return scaling * delta * mask


def merge_lora(w: Array, adapter: dict, cfg: LoRAConfig) -> Array:
    """Fold the adapter into a dense weight (for equivalence tests)."""
    return w + cfg.scaling * (adapter["lora_a"] @ adapter["lora_b"]).astype(
        w.dtype)


def deploy_quantize(params, qcfg: QuantConfig):
    """Post-training conversion of a trained pytree to the AxLLM serving
    representation (wraps quantize_tree; named for discoverability)."""
    from repro.core.quantization import quantize_tree
    return quantize_tree(params, qcfg)
