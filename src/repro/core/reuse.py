"""Computation-reuse analytics (paper §III.b, Fig. 8).

The reuse rate is the fraction of multiplications served from the Result Cache:
within one row-segment of the weight matrix (the paper bounds segments to the
W_buff size, 256–512 columns, §IV "Buffer size management"), the first
occurrence of each distinct code pays a multiply and every repeat is an RC hit.

    reuse_rate = 1 - unique_codes / total_codes      (summed over segments)

Sign folding (§V): value and its negative share an RC cell, so "distinct" means
distinct |code| — 128 cells for 8-bit. These functions are pure and vectorized;
they run on real quantized weights (numpy or jax arrays) and feed both the
Fig. 8 benchmark and the cycle simulator.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.quantization import QTensor, decode_codes


def _as_numpy_codes(codes) -> np.ndarray:
    if isinstance(codes, QTensor):
        codes = decode_codes(codes)
    return np.asarray(codes)


def fold_codes(codes, fold_sign: bool = True) -> np.ndarray:
    """Map codes to RC cell indices (|code| under sign folding).

    ``fold_sign=True`` is only meaningful for sign-symmetric alphabets
    (affine mode, where ``value(code) == -value(-code)``): it merges a code
    and its negative into one RC cell. Non-uniform codebooks (NF4) are NOT
    sign-symmetric — folding them would merge codes whose table values
    differ — so codebook-mode consumers must pass ``fold_sign=False``; use
    :func:`rc_alphabet` to get the correct fold for a quant mode. The
    unfolded branch offsets by 128 (the most negative int8 code) so every
    int4/int8 code lands in [0, 256) regardless of bit width; cell counts
    are what matter here, and the offset is injective for any code width
    up to 8 bits.
    """
    c = _as_numpy_codes(codes).astype(np.int32)
    out = np.abs(c) if fold_sign else c + 128
    # legit folded cells top out at |−128| = 128; unfolded at −128..127+128
    hi = 128 if fold_sign else 255
    if out.size and (out.min() < 0 or out.max() > hi):
        # signed codes can never land here; packed-int4 bytes (uint8, two
        # nibbles per entry) read as 0..255 and overflow either mapping —
        # a real bug this guard caught in kernel_bench
        raise ValueError(
            f"codes map outside the RC cell range [0, {hi}] — raw "
            "packed-int4 bytes? pass the QTensor (or decode_codes) so "
            "nibbles are unpacked and sign-extended first")
    return out


def rc_alphabet(bits: int, mode: str):
    """The (levels, fold_sign) contract shared by the analytics, the cycle
    simulator and the reuse (LUT) matmul kernel.

    Returns ``(levels, fold_sign)`` where ``levels`` is the f32 value table
    the reuse kernel's product LUT is built over — one product per
    activation element per level — and ``fold_sign`` says whether a code
    ``c`` indexes the table as ``|c|`` (with the sign applied on read, the
    paper's 128-cell RC for 8-bit) or as ``c + 2**(bits-1)``.

    * affine: levels are the magnitude ramp ``[0 .. qmax]`` (the per-channel
      ``scale/qmax`` factor is applied outside the table, exactly like the
      multiply kernel), folded — ``2**(bits-1)`` RC cells.
    * codebook: levels are the explicit ``2**bits``-entry codebook (NF4 for
      4-bit, identity for 8-bit), unfolded — NF4 is not sign-symmetric and
      the identity table's ``-128`` entry has no positive mirror.

    The cell *counts* produced by this mapping match
    :func:`segment_unique_counts` / :func:`fold_codes` with the same
    ``fold_sign`` (both mappings are injective on the live code range),
    which is what lets the kernel's measured multiply count be compared
    against the simulator's prediction (pinned by
    tests/test_reuse_kernel.py).
    """
    import jax

    from repro.core.quantization import identity_codebook, nf4_codebook
    if mode == "affine":
        qmax = (1 << (bits - 1)) - 1
        return np.arange(qmax + 1, dtype=np.float32), True
    if mode != "codebook":
        raise ValueError(f"unknown quant mode {mode!r}")
    # the codebook builders use jnp ops; force concrete evaluation so the
    # alphabet stays host-side numpy even when called under a jit trace
    # (the serve decode hot path reaches here through ops.reuse_matmul)
    with jax.ensure_compile_time_eval():
        cb = nf4_codebook() if bits == 4 else identity_codebook(8)
    return np.asarray(cb, np.float32), False


def segment_unique_counts(codes, segment: Optional[int] = 256,
                          fold_sign: bool = True) -> np.ndarray:
    """Unique-RC-cell counts per (row, segment).

    codes: [N, M] integer codes (a weight matrix; rows are streamed against one
      input element each, per the input-stationary order of Fig. 2).
    segment: W_buff column budget; None = unbounded (full row).
    Returns int array [N, n_segments].
    """
    c = fold_codes(codes, fold_sign)
    if c.ndim != 2:
        raise ValueError(f"expected [N, M] codes, got {c.shape}")
    n, m = c.shape
    seg = m if segment is None else int(segment)
    n_seg = (m + seg - 1) // seg
    out = np.zeros((n, n_seg), dtype=np.int64)
    n_cells = 256  # upper bound on RC indices either way
    for s in range(n_seg):
        block = c[:, s * seg:(s + 1) * seg]
        # presence via per-row bincount over a flattened (row * n_cells + code)
        flat = (np.arange(n)[:, None] * n_cells + block).ravel()
        counts = np.bincount(flat, minlength=n * n_cells).reshape(n, n_cells)
        out[:, s] = (counts > 0).sum(axis=1)
    return out


def reuse_rate(codes, segment: Optional[int] = 256,
               fold_sign: bool = True) -> float:
    """Fraction of multiplications eliminated by the RC (Fig. 8 metric)."""
    uniq = segment_unique_counts(codes, segment, fold_sign).sum()
    total = _as_numpy_codes(codes).size
    return float(1.0 - uniq / total)


def expected_unique(seg_len: int, n_cells: int = 128,
                    dist: str = "gaussian") -> float:
    """Analytic E[#unique RC cells] for a segment of ``seg_len`` draws.

    E[unique] = sum_v 1 - (1 - p_v)^n.  For "gaussian" the cell probabilities
    follow |N(0, sigma)| quantized with absmax scaling (absmax ~ 4 sigma for
    large matrices), matching the distribution of trained-LLM weight rows; for
    "uniform" p_v = 1/n_cells (a pessimistic bound on reuse).
    """
    if dist == "uniform":
        p = np.full(n_cells, 1.0 / n_cells)
    else:
        from scipy import stats
        qmax = n_cells - 1
        sigma_codes = qmax / 4.0  # absmax ≈ 4σ ⇒ code std ≈ qmax/4
        edges = np.arange(n_cells + 1) - 0.5
        edges[0] = 0.0
        cdf = stats.norm.cdf(edges / sigma_codes)
        # folded |N|: P(|c| in bin) = 2 * (cdf_hi - cdf_lo) for c > 0 bins
        p = 2.0 * np.diff(cdf)
        p[0] = 2.0 * (stats.norm.cdf(0.5 / sigma_codes) - 0.5)  # the 0 cell
        p = p / p.sum()
    return float(np.sum(1.0 - (1.0 - p) ** seg_len))


def expected_reuse_rate(seg_len: int, n_cells: int = 128,
                        dist: str = "gaussian") -> float:
    return 1.0 - expected_unique(seg_len, n_cells, dist) / seg_len


def lora_row_overlap(w_codes, a_codes, fold_sign: bool = True) -> float:
    """Fraction of A's elements whose RC cell already occurs in the same W row.

    Paper §V: "an average of 90% of the elements of each row of the adaptor
    matrix A repeats in the corresponding row in W". W is [N, M], A is [N, r]
    (same row count — Fig. 5 concatenates them).
    """
    w = fold_codes(w_codes, fold_sign)
    a = fold_codes(a_codes, fold_sign)
    if w.shape[0] != a.shape[0]:
        raise ValueError("W and A must share the row (input) dimension")
    n = w.shape[0]
    n_cells = 256
    flat = (np.arange(n)[:, None] * n_cells + w).ravel()
    counts = np.bincount(flat, minlength=n * n_cells).reshape(n, n_cells)
    present = counts > 0                                    # [N, cells]
    hits = np.take_along_axis(present, a, axis=1)           # [N, r]
    return float(hits.mean())


def per_matrix_report(codes, segments=(None, 256), fold_sign: bool = True):
    """Reuse rates at several buffer budgets — one Fig. 8 group."""
    return {("full" if s is None else str(s)): reuse_rate(codes, s, fold_sign)
            for s in segments}
