"""Cycle-approximate simulator of the AxLLM microarchitecture (paper §III.c, §IV).

This is the *paper-faithful reproduction layer*: it models the 64-lane
organization with per-lane W_buff/Out_buff (256 entries as four 64-entry
slices), a single 3-cycle multiplier per lane, a 128-entry sign-folded Result
Cache, dual multiply/reuse pipelines, RC-slice collision queues, and the <2%
RAW hazard stall. Fig. 8 (reuse rate), Fig. 9 (speedup), the LoRA results and
the ShiftAddLLM comparison in EXPERIMENTS.md are produced by this module
running on actually-quantized weights.

Two models are provided:

* :func:`simulate_segment_exact` — a per-segment cycle-accurate event model of
  one lane (fetch/slice queues, multiplier issue, RC fill/hit, back-pressure).
  Used by tests to bound the analytic model.
* :func:`simulate_matrix` / :func:`simulate_model` — the fast vectorized
  analytic model used for whole-model numbers. Its per-segment formula

      cycles ≈ unique + hits / hit_throughput + drain + hazard_stalls

  reflects the serialization between the multiply path (1 issue/cycle) and the
  reuse path (≤P RC slices/cycle, balls-in-bins collision efficiency) observed
  in the paper's reported numbers: with ~70% reuse at 256-entry buffers it
  yields DistilBERT ≈ 1.87× (paper: 159.34M → 85.11M cycles) and a ~1.7×
  average across Table I — the calibration target. An idealized fully
  overlapped datapath would approach min-bound C/max(...) ≈ 3×; the exact
  event model sits between, and EXPERIMENTS.md reports both.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import reuse as reuse_lib


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Matches §V "Simulation setup": 64 lanes, 256-entry buffers in 4 slices."""
    lanes: int = 64
    buf: int = 256                # W_buff / Out_buff entries per lane (segment)
    slices: int = 4               # P-way slicing of W_buff / RC / Out_buff
    rc_entries: int = 128         # sign-folded 8-bit RC (§V)
    mult_latency: int = 3         # §IV pipeline: multiplier 3 cycles
    buf_latency: int = 1          # §IV pipeline: buffer access 1 cycle
    queue_depth: int = 4          # per-slice collision queues (§IV, Fig. 7)
    hazard_penalty: float = 0.0   # RAW-hazard stalls are absorbed into
    #   collision_efficiency (paper §IV: likelihood < 2%, "impact negligible");
    #   _hazard_counts() still *measures* the raw rate as a diagnostic.
    collision_efficiency: float = 0.86  # CALIBRATED constant (see below)
    fold_sign: bool = True

    @property
    def hit_throughput(self) -> float:
        """Effective RC retires/cycle across the P slices.

        Instantaneous balls-in-bins throughput (P·(1-(1-1/P)^P) ≈ 2.73 for
        P=4) ignores the per-slice queues of Fig. 7, which smooth collisions
        across cycles; the steady-state max-load bound (≈ 3.8) ignores
        head-of-line blocking and hazards. The effective value sits between;
        we calibrate ONE scalar, collision_efficiency = 0.86 (⇒ 3.44/cycle for
        P=4), to the single published absolute number — DistilBERT's 85.11M
        AxLLM cycles (§V) — and then treat every other paper result (1.7×
        average speedup, LoRA 1.8×, ShiftAddLLM +29%, power −28%) as a
        *prediction* to validate against. Re-derived by
        tests/test_simulator.py::test_calibration_stability.
        """
        return self.slices * self.collision_efficiency

    @property
    def hit_throughput_ballsbins(self) -> float:
        """Uncalibrated instantaneous lower bound (kept for the bounds test)."""
        p = self.slices
        return p * (1.0 - (1.0 - 1.0 / p) ** p)

    @property
    def drain(self) -> int:
        """Pipeline fill+drain per segment (shared stages, §IV)."""
        return self.mult_latency + 2 * self.buf_latency


@dataclasses.dataclass
class SegmentStats:
    cycles_axllm: float
    cycles_baseline: float
    mults: int
    rc_hits: int
    hazards: int


@dataclasses.dataclass
class SimReport:
    cycles_axllm: float
    cycles_baseline: float
    mults: int                 # multiplications actually executed
    rc_hits: int               # multiplications eliminated (reused)
    hazards: int
    total_ops: int

    @property
    def speedup(self) -> float:
        return self.cycles_baseline / max(self.cycles_axllm, 1.0)

    @property
    def reuse_rate(self) -> float:
        return self.rc_hits / max(self.total_ops, 1)

    @property
    def hazard_rate(self) -> float:
        return self.hazards / max(self.total_ops, 1)

    def merge(self, other: "SimReport") -> "SimReport":
        return SimReport(
            self.cycles_axllm + other.cycles_axllm,
            self.cycles_baseline + other.cycles_baseline,
            self.mults + other.mults,
            self.rc_hits + other.rc_hits,
            self.hazards + other.hazards,
            self.total_ops + other.total_ops,
        )


def _empty_report() -> SimReport:
    return SimReport(0.0, 0.0, 0, 0, 0, 0)


# ---------------------------------------------------------------------------
# Exact per-segment event model (one lane)
# ---------------------------------------------------------------------------

def simulate_segment_exact(cells: np.ndarray, cfg: SimConfig) -> int:
    """Cycle-accurate model of one lane processing one W_buff segment.

    ``cells`` are RC indices (already sign-folded). Structure per §IV/Fig. 7:
    the segment is split into ``slices`` contiguous sub-buffers fetched one
    code per slice per cycle (round-robin); a miss is queued to the single
    multiplier (1 issue/cycle, ``mult_latency`` to complete, then fills RC);
    a hit is queued to its RC slice (cell % slices), each slice retiring one
    read/cycle; a fetch targeting a *pending* cell (RAW hazard, §IV) waits in
    its slice queue until the fill lands. Bounded queues apply back-pressure
    to fetch (credit-based flow control).
    """
    n = len(cells)
    if n == 0:
        return 0
    p = cfg.slices
    # contiguous slice partition of the segment
    bounds = np.linspace(0, n, p + 1).astype(int)
    ptrs = bounds[:-1].copy()
    rc_valid = np.zeros(cfg.rc_entries, dtype=bool)
    rc_pending = np.zeros(cfg.rc_entries, dtype=bool)
    mult_q: deque = deque()
    slice_q: List[deque] = [deque() for _ in range(p)]  # (cell, needs_fill)
    inflight: List[Tuple[int, int]] = []  # (complete_cycle, cell)
    retired = 0
    cycle = 0
    max_cycles = 50 * n + 100  # safety net

    while retired < n and cycle < max_cycles:
        cycle += 1
        # multiplier completion → RC fill + Out_buff write (retire)
        still = []
        for done_at, cell in inflight:
            if done_at <= cycle:
                rc_valid[cell] = True
                rc_pending[cell] = False
                retired += 1
            else:
                still.append((done_at, cell))
        inflight = still
        # multiplier issue (1/cycle)
        if mult_q:
            cell = mult_q.popleft()
            inflight.append((cycle + cfg.mult_latency, cell))
        # RC slice retirement (1 read/cycle/slice); hazard entries wait
        for s in range(p):
            if slice_q[s]:
                cell = slice_q[s][0]
                if rc_valid[cell]:
                    slice_q[s].popleft()
                    retired += 1
                # else: head-of-line wait for the pending fill (hazard stall)
        # fetch: one code per slice, with credit back-pressure
        for s in range(p):
            if ptrs[s] >= bounds[s + 1]:
                continue
            cell = int(cells[ptrs[s]])
            if rc_valid[cell]:
                if len(slice_q[cell % p]) < cfg.queue_depth:
                    slice_q[cell % p].append(cell)
                    ptrs[s] += 1
            elif rc_pending[cell]:
                if len(slice_q[cell % p]) < cfg.queue_depth:
                    slice_q[cell % p].append(cell)  # waits on fill
                    ptrs[s] += 1
            else:
                if len(mult_q) < cfg.queue_depth:
                    mult_q.append(cell)
                    rc_pending[cell] = True
                    ptrs[s] += 1
    return cycle + cfg.drain


# ---------------------------------------------------------------------------
# Analytic per-segment model (calibrated to the paper)
# ---------------------------------------------------------------------------

def _hazard_counts(cells2d: np.ndarray, cfg: SimConfig) -> np.ndarray:
    """Per-row count of repeats arriving within the multiplier latency window
    of the first occurrence of their cell (§IV: measured < 2%)."""
    n_rows, seg = cells2d.shape
    window = cfg.mult_latency * cfg.slices  # positions per mult_latency cycles
    counts = np.zeros(n_rows, dtype=np.int64)
    for r in range(n_rows):
        first: Dict[int, int] = {}
        c = 0
        row = cells2d[r]
        for i in range(seg):
            v = row[i]
            if v in first:
                if i - first[v] <= window:
                    c += 1
                    first[v] = -10 ** 9  # only the immediate-follower stalls
            else:
                first[v] = i
        counts[r] = c
    return counts


def _segment_cycles(unique: np.ndarray, seg_len: int, hazards: np.ndarray,
                    cfg: SimConfig) -> Tuple[np.ndarray, float]:
    """Vectorized per-(row,segment) AxLLM cycles and the baseline scalar."""
    hits = seg_len - unique
    cyc = (unique
           + hits / cfg.hit_throughput
           + hazards * cfg.hazard_penalty
           + cfg.drain)
    baseline = seg_len + cfg.drain
    return cyc, baseline


def simulate_matrix(codes: np.ndarray, cfg: SimConfig = SimConfig(),
                    tokens: int = 1,
                    measure_hazards: bool = True) -> SimReport:
    """Simulate x[T, N] @ W[N, M] on the lane array for ``tokens`` inputs.

    Input-stationary order (Fig. 2): lanes take ``cfg.lanes`` consecutive rows
    of W; columns are processed in W_buff-sized segments (§IV); per (tile,
    segment) the wall time is the max over the lanes (the adder tree
    accumulates streamed partial sums off the critical path, Fig. 3); the RC
    is cleared between inputs/segments (§III.c), so every token pays the
    unique-value multiplies again — exactly the zero-setup-time property the
    paper claims vs LUT approaches.
    """
    cells = reuse_lib.fold_codes(codes, cfg.fold_sign)
    n, m = cells.shape
    # count uniques on the RAW codes with the configured fold — `cells` is
    # already folded, so folding it again (fold_sign=False adds the +128
    # offset a second time) would push cells past the 256-index bound
    uniq = reuse_lib.segment_unique_counts(codes, cfg.buf,
                                           fold_sign=cfg.fold_sign)
    n_seg = uniq.shape[1]

    report = _empty_report()
    ax_total = 0.0
    base_total = 0.0
    mults = 0
    hits_total = 0
    hazards_total = 0

    for s in range(n_seg):
        lo, hi = s * cfg.buf, min((s + 1) * cfg.buf, m)
        seg_len = hi - lo
        if measure_hazards:
            hz = _hazard_counts(cells[:, lo:hi], cfg)
        else:
            hz = np.zeros(n, dtype=np.int64)
        cyc, base = _segment_cycles(uniq[:, s], seg_len, hz, cfg)
        # lane tiling over rows: wall time = max over lanes in each tile
        n_tiles = math.ceil(n / cfg.lanes)
        for t in range(n_tiles):
            rows = slice(t * cfg.lanes, min((t + 1) * cfg.lanes, n))
            ax_total += float(cyc[rows].max())
            base_total += float(base)
        mults += int(uniq[:, s].sum())
        hits_total += int((seg_len - uniq[:, s]).sum())
        hazards_total += int(hz.sum())

    report = SimReport(
        cycles_axllm=ax_total * tokens,
        cycles_baseline=base_total * tokens,
        mults=mults * tokens,
        rc_hits=hits_total * tokens,
        hazards=hazards_total * tokens,
        total_ops=n * m * tokens,
    )
    return report


# ---------------------------------------------------------------------------
# Whole-model simulation (Table I / Fig. 9)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    name: str
    n_in: int
    n_out: int
    count: int = 1  # instances per layer


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A transformer described by its per-layer weight matrices (Table I)."""
    name: str
    layers: int
    matrices: Tuple[MatrixSpec, ...]
    tokens: int = 240  # avg benchmark sequence length


def gaussian_codes(rng: np.random.Generator, n: int, m: int,
                   qmax: int = 127) -> np.ndarray:
    """8-bit absmax-quantized Gaussian weights (trained-LLM-like rows)."""
    w = rng.standard_normal((n, m)).astype(np.float32)
    scale = np.abs(w).max(axis=0, keepdims=True) / qmax
    return np.clip(np.round(w / scale), -qmax, qmax).astype(np.int32)


def simulate_model(spec: ModelSpec, cfg: SimConfig = SimConfig(),
                   seed: int = 0, codes_by_name: Optional[dict] = None,
                   measure_hazards: bool = False) -> SimReport:
    """Full-model cycles: sum over layers x matrices x tokens.

    ``codes_by_name`` may supply real quantized weights (e.g. from a trained
    checkpoint); otherwise realistic Gaussian-quantized rows are drawn. Only
    one layer's worth of distinct matrices is simulated and scaled by
    ``spec.layers`` (weight statistics are layer-stationary — verified on our
    trained 100M model in benchmarks/reuse_rate.py).
    """
    rng = np.random.default_rng(seed)
    total = _empty_report()
    for mat in spec.matrices:
        if codes_by_name and mat.name in codes_by_name:
            codes = np.asarray(codes_by_name[mat.name])
        else:
            codes = gaussian_codes(rng, mat.n_in, mat.n_out)
        rep = simulate_matrix(codes, cfg, tokens=spec.tokens,
                              measure_hazards=measure_hazards)
        scale = mat.count * spec.layers
        total = total.merge(SimReport(
            rep.cycles_axllm * scale, rep.cycles_baseline * scale,
            rep.mults * scale, rep.rc_hits * scale,
            rep.hazards * scale, rep.total_ops * scale))
    return total


def simulate_lora(w_codes: np.ndarray, a_codes: np.ndarray,
                  cfg: SimConfig = SimConfig(), tokens: int = 1) -> dict:
    """Adapter-matrix speedup via the combined [W ‖ A] scheme (Fig. 5).

    A's columns ride in the SAME processing round as W's final column
    segment (the combined matrix is one matrix; the RC is not cleared
    between W's tail and A — that is the whole point of Fig. 5), so A's
    elements hit RC entries already filled while streaming W. The W+A round
    stays within the 512-entry buffer bound of §IV. Adapter-attributable
    AxLLM cycles are the marginal cycles of that round; the baseline pays
    A's full r columns through the multiplier.
    """
    w = reuse_lib.fold_codes(w_codes, cfg.fold_sign)
    a = reuse_lib.fold_codes(a_codes, cfg.fold_sign)
    n, m = w.shape
    r = a.shape[1]
    last = w[:, (m // cfg.buf - 1) * cfg.buf:] if m >= cfg.buf else w
    comb = np.concatenate([last, a], axis=1)
    u_last = reuse_lib.segment_unique_counts(last, None, fold_sign=False)
    u_comb = reuse_lib.segment_unique_counts(comb, None, fold_sign=False)
    marg_u = (u_comb - u_last)[:, 0]                    # new uniques from A
    # both designs pay the pipeline fill/drain on the adapter tail
    ax = marg_u + (r - marg_u) / cfg.hit_throughput + cfg.drain
    base = float(r + cfg.drain)
    ax_total = 0.0
    base_total = 0.0
    for t in range(math.ceil(n / cfg.lanes)):
        rows = slice(t * cfg.lanes, min((t + 1) * cfg.lanes, n))
        ax_total += float(ax[rows].max())
        base_total += base
    overlap = reuse_lib.lora_row_overlap(w_codes, a_codes, cfg.fold_sign)
    rep_c = simulate_matrix(np.concatenate([w_codes, a_codes], 1), cfg,
                            tokens)
    return {
        "adapter_speedup": (base_total * tokens) / max(ax_total * tokens,
                                                       1.0),
        "row_overlap": overlap,
        "combined_speedup": rep_c.speedup,
    }


# ---------------------------------------------------------------------------
# Table I model specs (paper §V)
# ---------------------------------------------------------------------------

def _bert_like(name: str, d: int, layers: int, tokens: int) -> ModelSpec:
    return ModelSpec(name, layers, (
        MatrixSpec("wq", d, d), MatrixSpec("wk", d, d),
        MatrixSpec("wv", d, d), MatrixSpec("wo", d, d),
        MatrixSpec("ffn_up", d, 4 * d), MatrixSpec("ffn_down", 4 * d, d),
    ), tokens=tokens)


def _llama_like(name: str, d: int, d_ff: int, layers: int,
                tokens: int) -> ModelSpec:
    return ModelSpec(name, layers, (
        MatrixSpec("wq", d, d), MatrixSpec("wk", d, d),
        MatrixSpec("wv", d, d), MatrixSpec("wo", d, d),
        MatrixSpec("ffn_gate", d, d_ff), MatrixSpec("ffn_up", d, d_ff),
        MatrixSpec("ffn_down", d_ff, d),
    ), tokens=tokens)


# tokens=236 is fitted to the paper's published DistilBERT *baseline* cycle
# count (159.34M; we get 159.66M) and is consistent with the AG News mean
# sequence length. It is the second and last calibrated constant.
PAPER_MODELS: Dict[str, ModelSpec] = {
    "distilbert": _bert_like("distilbert", 768, 6, tokens=236),
    "bert-base": _bert_like("bert-base", 768, 12, tokens=236),
    "bert-large": _bert_like("bert-large", 1024, 24, tokens=236),
    "llama-7b": _llama_like("llama-7b", 4096, 11008, 32, tokens=236),
    "llama-13b": _llama_like("llama-13b", 5120, 13824, 40, tokens=236),
}
