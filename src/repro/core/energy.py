"""Energy/power model for the AxLLM lane array (paper §V "Power consumption").

Event-based: the simulator reports how many operations took the multiply path
vs the reuse path; each path has a per-op energy decomposed into 15nm-class
unit energies. The paper's published endpoints for one DistilBERT layer —
baseline 0.94 W vs AxLLM 0.67 W at 1.87× speedup — imply a per-op energy ratio
of (0.67/0.94)/1.876 ≈ 0.38 with negligible static share
(P_ax/P_base = (E_ax/E_base)·speedup ⇒ 0.713 = 0.38·1.876 exactly), i.e. a
reuse-path op must cost ≈ 11 fJ vs ≈ 98 fJ for a multiply-path op. The unit
constants below satisfy that and are individually plausible for 15nm
(Horowitz-scaled: 8-bit multiply ≈ 78 fJ; small register-file accesses single
fJ). One global scale factor maps per-lane femtojoules to the paper's absolute
watts (their synthesis' clock/utilization); the *relative* −28% power claim is
the validation target, absolute watts are reported for reference.
"""

from __future__ import annotations

import dataclasses

from repro.core.simulator import SimReport


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    # femtojoules per event (15nm-class)
    e_mult: float = 78.0        # 8x8 multiply + product staging
    e_wbuf_read: float = 6.0    # 64-entry W_buff slice read (1 B)
    e_rc_write: float = 4.0     # 32-entry RC slice write (2 B)
    e_rc_read: float = 2.0      # 32-entry RC slice read (2 B)
    e_out_write: float = 8.0    # Out_buff write (miss path, full event)
    e_out_write_hit: float = 1.3  # hit-path writes retire up to P-wide and
    #   share wordline/precharge energy across the slice's queue drain
    e_tree_add: float = 2.0     # adder-tree contribution per partial sum
    p_static_w: float = 0.0     # implied ≈ 0 by the paper's own endpoints
    # global fJ/lane-event -> system watts calibration (64 lanes, 1 GHz,
    # matched to the paper's absolute 0.94 W baseline for one DistilBERT layer)
    watt_scale: float = 1.0

    @property
    def e_miss_op(self) -> float:
        return (self.e_mult + self.e_wbuf_read + self.e_rc_write
                + self.e_out_write + self.e_tree_add)

    @property
    def e_hit_op(self) -> float:
        return self.e_wbuf_read + self.e_rc_read + self.e_out_write_hit

    def energy_fj(self, rep: SimReport, baseline: bool = False) -> float:
        if baseline:
            # every op pays the multiply path (no RC write in the baseline,
            # but keep it for a conservative baseline; it is 4% of the op)
            ops = rep.total_ops
            return ops * (self.e_mult + self.e_wbuf_read + self.e_out_write
                          + self.e_tree_add)
        return rep.mults * self.e_miss_op + rep.rc_hits * self.e_hit_op

    def power_w(self, rep: SimReport, baseline: bool = False,
                lanes: int = 64, f_hz: float = 1e9) -> float:
        cycles = rep.cycles_baseline if baseline else rep.cycles_axllm
        t_s = cycles / f_hz
        e_j = self.energy_fj(rep, baseline) * 1e-15
        return self.watt_scale * (e_j / max(t_s, 1e-30)) + self.p_static_w


def calibrated_model(rep: SimReport) -> EnergyModel:
    """Fix watt_scale so the *baseline* power equals the paper's 0.94 W for
    the given (DistilBERT-layer) report; everything else is then predicted."""
    m = EnergyModel()
    base = m.power_w(rep, baseline=True)
    return dataclasses.replace(m, watt_scale=0.94 / base)


def power_report(rep: SimReport) -> dict:
    m = calibrated_model(rep)
    p_base = m.power_w(rep, baseline=True)
    p_ax = m.power_w(rep, baseline=False)
    e_base = m.energy_fj(rep, baseline=True)
    e_ax = m.energy_fj(rep, baseline=False)
    return {
        "power_baseline_w": p_base,
        "power_axllm_w": p_ax,
        "power_reduction": 1.0 - p_ax / p_base,
        "energy_reduction": 1.0 - e_ax / e_base,
        "per_op_energy_ratio": (e_ax / max(rep.total_ops, 1))
                               / (e_base / max(rep.total_ops, 1)),
    }
