"""Quantization substrate for AxLLM computation reuse.

The paper (§III.b) builds on q-bit quantized weights: with q bits a weight row
can contain at most 2**q distinct values, and the Result Cache (RC) holds the
product of the current input element with each distinct value. Numerically a
quantized weight is ``value = codebook[code] * scale`` — for symmetric ("affine")
quantization the codebook is the identity ramp, so ``value = code * scale``.

This module provides the :class:`QTensor` pytree used across the framework:
codes are stored in int8 (optionally int4, bit-packed two-per-byte so HBM byte
accounting in the dry-run reflects real traffic), scales are per-tensor,
per-channel, or per-group, and an optional non-uniform codebook (NF4-style
quantile levels) supports the 4-bit beyond-paper variant.

Sign folding (paper §V: "we maintain a 128-element reuse cache … map each value
and its negative to the same cell") is an *analytics/hardware* notion: it halves
the RC size because the lane can negate on read. Numerics here keep signed codes;
:mod:`repro.core.reuse` applies the fold when counting unique values.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = Any


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Configuration of the quantized representation.

    Attributes:
      bits: code width. 8 (paper's operating point) or 4 (beyond-paper).
      mode: "affine" (symmetric uniform; codebook == identity ramp) or
        "codebook" (non-uniform levels, NF4-style; the RC/codebook is an
        explicit 2**bits-entry table — the literal TPU analogue of the paper's
        Result Cache).
      granularity: "per_tensor" | "per_channel" | "per_group".
        per_channel scales are along the *output* dim of a [in, out] weight.
      group_size: rows per scale group along the input dim (per_group only).
      pack: bit-pack int4 codes two-per-byte (storage dtype uint8). int8 codes
        are never packed.
    """

    bits: int = 8
    mode: str = "affine"
    granularity: str = "per_channel"
    group_size: int = 128
    pack: bool = True

    def __post_init__(self):
        if self.bits not in (4, 8):
            raise ValueError(f"bits must be 4 or 8, got {self.bits}")
        if self.mode not in ("affine", "codebook"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.granularity not in ("per_tensor", "per_channel", "per_group"):
            raise ValueError(f"unknown granularity {self.granularity!r}")

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1  # 127 for int8, 7 for int4

    @property
    def n_levels(self) -> int:
        return 1 << self.bits

    @property
    def rc_entries(self) -> int:
        """Result-Cache entries after sign folding (paper §V: 128 for 8-bit)."""
        return 1 << (self.bits - 1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Quantized tensor pytree: ``deq = codebook[codes] * scale`` (or affine).

    codes:    int8 [*leading, in, out]   (or uint8 packed [*, in, out//2] for int4)
    scale:    f32 broadcastable against the dequantized value:
                per_tensor  -> [*, 1, 1]
                per_channel -> [*, 1, out]
                per_group   -> [*, in//g, 1, out]   (dequant reshapes)
    codebook: f32 [2**bits] normalized levels in [-1, 1], or None for affine.
    """

    codes: Array
    scale: Array
    codebook: Optional[Array]
    bits: int
    mode: str
    granularity: str
    group_size: int
    packed: bool
    shape: tuple  # logical (unpacked) shape

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.codes, self.scale, self.codebook)
        aux = (self.bits, self.mode, self.granularity, self.group_size,
               self.packed, self.shape)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scale, codebook = children
        bits, mode, granularity, group_size, packed, shape = aux
        return cls(codes, scale, codebook, bits, mode, granularity,
                   group_size, packed, shape)

    # -- convenience ---------------------------------------------------------
    @property
    def dtype(self):
        return self.scale.dtype

    @property
    def nbytes_codes(self) -> int:
        n = int(np.prod(self.shape))
        return n if self.bits == 8 else (n + 1) // 2

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"QTensor(shape={self.shape}, bits={self.bits}, mode={self.mode},"
                f" granularity={self.granularity}, packed={self.packed})")


# ---------------------------------------------------------------------------
# Codebooks
# ---------------------------------------------------------------------------

def identity_codebook(bits: int) -> jnp.ndarray:
    """Uniform levels code/qmax for code in [-2^(b-1), 2^(b-1)-1]."""
    qmax = (1 << (bits - 1)) - 1
    lo = -(1 << (bits - 1))
    return jnp.arange(lo, qmax + 1, dtype=jnp.float32) / qmax


def nf4_codebook() -> jnp.ndarray:
    """NF4-style non-uniform 16-level codebook (normal-quantile spaced).

    Levels are the quantiles of N(0,1) normalized to [-1, 1]; this matches the
    distribution of trained-LLM weights much better than a uniform ramp and is
    the beyond-paper 4-bit operating point (the RC shrinks to 16 entries).
    """
    from scipy import stats  # available offline in this container

    neg = stats.norm.ppf((np.arange(8) + 0.5) / 16.0)      # 8 negative levels
    pos = -neg[::-1][:7]                                    # 7 positive levels
    levels = np.concatenate([neg, [0.0], pos])              # 16 total, has 0
    levels = levels / np.max(np.abs(levels))
    assert levels.shape == (16,) and np.all(np.isfinite(levels))
    return jnp.asarray(np.sort(levels), dtype=jnp.float32)


def make_codebook(cfg: QuantConfig) -> Optional[jnp.ndarray]:
    if cfg.mode == "affine":
        return None
    return nf4_codebook() if cfg.bits == 4 else identity_codebook(8)


def resolve_codebook(qt: "QTensor") -> Optional[jnp.ndarray]:
    """The codebook is a pure function of (mode, bits) — it is NOT stored as
    a pytree leaf (a shared [2^q] leaf breaks lax.scan over stacked layers)
    but materialized as a constant at use sites."""
    if qt.mode == "affine":
        return None
    return nf4_codebook() if qt.bits == 4 else identity_codebook(8)


# ---------------------------------------------------------------------------
# int4 bit packing (two codes per byte; low nibble = even index)
# ---------------------------------------------------------------------------

def pack_int4(codes: Array) -> Array:
    """[..., out] int8 in [-8, 7] -> [..., out//2] uint8."""
    if codes.shape[-1] % 2:
        raise ValueError("int4 packing requires an even trailing dim")
    u = (codes.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    lo, hi = u[..., 0::2], u[..., 1::2]
    return lo | (hi << 4)


def unpack_int4(packed: Array, out_dim: int) -> Array:
    """[..., out//2] uint8 -> [..., out] int8 in [-8, 7]."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
    return out[..., :out_dim]


# ---------------------------------------------------------------------------
# Quantize / dequantize
# ---------------------------------------------------------------------------

def _scale_reduce_axes(w_shape, cfg: QuantConfig):
    # weight layout [..., in, out]; leading dims (stacked layers / experts)
    # always keep their own scales so scan/vmap slicing stays consistent
    nd = len(w_shape)
    if cfg.granularity == "per_tensor":
        return (nd - 2, nd - 1)
    if cfg.granularity == "per_channel":
        return (nd - 2,)  # reduce the in dim only
    return None  # per_group handled separately


def quantize(w: Array, cfg: QuantConfig) -> QTensor:
    """Quantize a weight of shape [..., in, out] per ``cfg``.

    Exactness contract (paper §II "preserves exact arithmetic semantics"):
    dequantize(quantize(w)) is the model's quantized weights; the AxLLM reuse
    mechanism never changes them further. Round-trip error is bounded by
    scale/2 per element for affine mode (property-tested).
    """
    w = jnp.asarray(w, jnp.float32)
    if w.ndim < 2:
        raise ValueError("quantize expects [..., in, out]")
    eps = 1e-8

    if cfg.granularity == "per_group":
        *lead, n_in, n_out = w.shape
        g = cfg.group_size
        if n_in % g:
            raise ValueError(f"in dim {n_in} not divisible by group {g}")
        wg = w.reshape(*lead, n_in // g, g, n_out)
        absmax = jnp.max(jnp.abs(wg), axis=-2, keepdims=True)  # [*,G,1,out]
        scale = jnp.maximum(absmax, eps)
        normed = wg / scale
        scale_store = scale
    else:
        axes = _scale_reduce_axes(w.shape, cfg)
        absmax = jnp.max(jnp.abs(w), axis=axes, keepdims=True)
        scale = jnp.maximum(absmax, eps)
        normed = w / scale
        scale_store = scale

    cb = make_codebook(cfg)  # used for encoding only; not stored as a leaf
    if cfg.mode == "affine":
        codes = jnp.clip(jnp.round(normed * cfg.qmax), -cfg.qmax, cfg.qmax)
        codes = codes.astype(jnp.int8)
    else:
        if cfg.bits == 8:
            # identity codebook: same as affine but stored with explicit table
            codes = jnp.clip(jnp.round(normed * cfg.qmax), -cfg.qmax, cfg.qmax)
            codes = codes.astype(jnp.int8)
        else:
            # nearest level in the 16-entry codebook
            d = jnp.abs(normed[..., None] - cb)          # [..., 16]
            idx = jnp.argmin(d, axis=-1).astype(jnp.int32)
            codes = (idx - 8).astype(jnp.int8)           # recenter to [-8, 7]

    if cfg.granularity == "per_group":
        codes = codes.reshape(*w.shape)

    packed = False
    if cfg.bits == 4 and cfg.pack:
        codes = pack_int4(codes)
        packed = True

    return QTensor(codes=codes, scale=scale_store, codebook=None,
                   bits=cfg.bits, mode=cfg.mode, granularity=cfg.granularity,
                   group_size=cfg.group_size, packed=packed, shape=w.shape)


def decode_codes(qt: QTensor) -> Array:
    """Return unpacked signed integer codes with qt.shape."""
    if qt.packed:
        return unpack_int4(qt.codes, qt.shape[-1])
    return qt.codes


def lookup(qt: QTensor, codes: Array) -> Array:
    """codebook[codes] in normalized space — the RC-table read, vectorized.

    For affine mode this is ``codes / qmax`` (no gather: the identity codebook
    folds into arithmetic, which is exactly how the TPU kernel implements it).
    """
    if qt.mode == "affine":
        qmax = (1 << (qt.bits - 1)) - 1
        return codes.astype(jnp.float32) / qmax
    cb = resolve_codebook(qt)
    offset = 1 << (qt.bits - 1)
    return jnp.take(cb, codes.astype(jnp.int32) + offset, axis=0)


def dequantize(qt: QTensor, dtype=jnp.float32) -> Array:
    codes = decode_codes(qt)
    normed = lookup(qt, codes)
    if qt.granularity == "per_group":
        *lead, n_in, n_out = qt.shape
        g = qt.group_size
        normed = normed.reshape(*lead, n_in // g, g, n_out)
        w = (normed * qt.scale).reshape(*qt.shape)
    else:
        w = normed * qt.scale
    return w.astype(dtype)


# ---------------------------------------------------------------------------
# Concatenation (fused-projection support)
# ---------------------------------------------------------------------------

def qconcat(qts) -> QTensor:
    """Concatenate QTensors along the output (N) axis without requantizing.

    This is the substrate of the fused-QKV / fused-gate-up projections: a
    single ``[K, N1+N2+...]`` AxLLM matmul replaces several ``[K, Ni]``
    matmuls over the same activations (one activation pass, one codebook
    residency in the kernel). Exactness: per-channel scales travel with
    their columns, so ``dequantize(qconcat(a, b)) ==
    concat(dequantize(a), dequantize(b))`` bit-for-bit.

    Inputs must share K (and any leading stacked dims), bits, mode, packing
    and — for per_group — group_size. Mixing per_tensor/per_channel inputs
    is allowed: per_tensor scales broadcast over their columns and the
    result is per_channel. per_group inputs must all be per_group.
    """
    qts = list(qts)
    if len(qts) < 2:
        raise ValueError("qconcat needs at least two QTensors")
    q0 = qts[0]
    for qt in qts[1:]:
        if not isinstance(qt, QTensor):
            raise TypeError(f"qconcat expects QTensors, got {type(qt)}")
        if (qt.bits, qt.mode, qt.packed) != (q0.bits, q0.mode, q0.packed):
            raise ValueError(
                f"qconcat mismatch: ({qt.bits},{qt.mode},{qt.packed}) vs "
                f"({q0.bits},{q0.mode},{q0.packed})")
        if qt.shape[:-1] != q0.shape[:-1]:
            raise ValueError(f"qconcat K/leading mismatch: {qt.shape} vs "
                             f"{q0.shape}")
    grans = {qt.granularity for qt in qts}
    if "per_group" in grans:
        if grans != {"per_group"}:
            raise ValueError("qconcat cannot mix per_group with other "
                             "granularities")
        if len({qt.group_size for qt in qts}) != 1:
            raise ValueError("qconcat per_group inputs need one group_size")
        granularity = "per_group"
        scale = jnp.concatenate([qt.scale for qt in qts], axis=-1)
    else:
        # per_tensor folds into per_channel: broadcast each input's scale
        # over its own columns, then concatenate along the channel dim
        granularity = "per_channel"
        lead = q0.shape[:-2]
        scale = jnp.concatenate(
            [jnp.broadcast_to(qt.scale.astype(jnp.float32),
                              (*lead, 1, qt.shape[-1])) for qt in qts],
            axis=-1)
    if q0.packed and any(qt.shape[-1] % 2 for qt in qts):
        raise ValueError("packed qconcat inputs need even output dims")
    codes = jnp.concatenate([qt.codes for qt in qts], axis=-1)
    out = sum(qt.shape[-1] for qt in qts)
    return QTensor(codes=codes, scale=scale, codebook=None, bits=q0.bits,
                   mode=q0.mode, granularity=granularity,
                   group_size=q0.group_size, packed=q0.packed,
                   shape=(*q0.shape[:-1], out))


# ---------------------------------------------------------------------------
# Pytree-level helpers (deploy-time conversion of a trained model)
# ---------------------------------------------------------------------------

_EXCLUDE_PREFIXES = (
    # norms and their leaves
    "ln", "norm", "scale", "bias",
    # non-matmul / non-reuse surfaces: gathers, routing, convs, recurrences
    "embedding", "router", "lora_", "conv", "a_log", "dt_bias",
    "d_skip", "gate_bias", "if_bias", "pos_embed",
)
_EXCLUDE_EXACT = ("r",)  # sLSTM per-head recurrent stack


def _is_weight_matrix(path: str, x: Any) -> bool:
    """True for weight matrices that are AxLLM reuse surfaces: 2-D (or
    stacked 3-D) matrices consumed by vector-matrix products. Norm scales,
    biases, embeddings (gather), routers, depthwise convs and per-head
    recurrent matrices stay full precision."""
    if not hasattr(x, "ndim") or x.ndim < 2:
        return False
    comps = [c for c in path.split("/") if c]
    for c in comps:
        if c in _EXCLUDE_EXACT:
            return False
        # substring match: catches suffixed names like "wq_bias" too
        if any(p in c for p in _EXCLUDE_PREFIXES):
            return False
    return True


def quantize_tree(params, cfg: QuantConfig, predicate=_is_weight_matrix):
    """Quantize every weight matrix in a param pytree (paper: post-training,
    zero offline setup beyond this conversion; no retraining)."""

    def walk(prefix, node):
        if isinstance(node, dict):
            return {k: walk(f"{prefix}/{k}", v) for k, v in node.items()}
        if predicate(prefix, node):
            return quantize(node, cfg)
        return node

    return walk("", params)


def derive_draft_params(params, *, bits: int = 4, mode: str = "affine",
                        predicate=_is_weight_matrix):
    """Derive a low-precision *draft* model from raw (pre-quantization)
    params for self-speculative decoding.

    The repo's quantization ladder means the draft is the SAME model at a
    cheaper precision — no separate training, no second tokenizer, same
    cache layout — which is all speculative decoding needs from a
    proposer (correctness never depends on it; the target re-verifies
    every token). Modes:

    - ``"affine"`` / ``"codebook"``: :func:`quantize_tree` at ``bits``
      (int4 is the intended draft point; int8 is a sharper, pricier
      draft for bf16 targets).
    - ``"shiftadd"``: the ShiftAddLLM reparameterization (binary planes
      x power-of-two scales, ``repro.core.shiftadd``) reconstructed to
      dense float32 — an *approximate* draft exercising a genuinely
      different numeric path than the affine ladder. ``bits`` is the
      number of binary planes.

    Must be fed the ORIGINAL float params: deriving a draft from
    already-quantized weights would compound two quantization errors.
    """
    if mode in ("affine", "codebook"):
        return quantize_tree(
            params, QuantConfig(bits=bits, mode=mode), predicate=predicate)
    if mode != "shiftadd":
        raise ValueError(f"unknown draft mode {mode!r} "
                         "(expected affine | codebook | shiftadd)")
    # function-local import: shiftadd pulls in the cycle simulator, which
    # this module must not depend on at import time
    from repro.core.shiftadd import binarize, reconstruct

    def reparam(x):
        w = np.asarray(x, np.float64)
        flat = w.reshape((-1,) + w.shape[-2:])   # binarize() is 2-D only
        out = np.empty_like(flat)
        for i in range(flat.shape[0]):
            out[i] = reconstruct(*binarize(flat[i], q=bits))
        return jnp.asarray(out.reshape(w.shape), jnp.float32)

    def walk(prefix, node):
        if isinstance(node, dict):
            return {k: walk(f"{prefix}/{k}", v) for k, v in node.items()}
        if predicate(prefix, node):
            return reparam(node)
        return node

    return walk("", params)


def tree_reuse_surface(params) -> int:
    """Total quantized weight elements (the surface AxLLM's RC acts on)."""
    n = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            n += int(np.prod(leaf.shape))
    return n
