"""Render dry-run records into the EXPERIMENTS.md tables.

  PYTHONPATH=src python -m repro.launch.report                 # roofline table
  PYTHONPATH=src python -m repro.launch.report --compare results/dryrun_iter0
  PYTHONPATH=src python -m repro.launch.report --variants      # serve variants
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import get_config
from repro.launch.shapes import SHAPES
from repro.roofline import analysis as ra


def load(results_dir):
    recs = {}
    for f in glob.glob(os.path.join(results_dir, "*.json")):
        with open(f) as fh:
            r = json.load(fh)
        recs[(r["cell"], r["mesh"], r.get("variant", "axllm-int8"))] = r
    return recs


def corrected(rec):
    from benchmarks.roofline_table import corrected_totals
    return corrected_totals(rec)


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def roofline_rows(recs, mesh="pod16x16", variant="axllm-int8"):
    rows = []
    for (cell, m, v), rec in sorted(recs.items()):
        if m != mesh or v != variant:
            continue
        arch, shape = cell.split(":")
        if rec["status"] == "skipped":
            rows.append((cell, "SKIP", rec["reason"][:48], "", "", "", "",
                         ""))
            continue
        if rec["status"] != "ok":
            rows.append((cell, "ERR", rec.get("error", "")[:48], "", "", "",
                         "", ""))
            continue
        cfg = get_config(arch)
        spec = SHAPES[shape]
        corr = corrected(rec)
        if corr:
            fl, by, co = (corr["flops_global"], corr["bytes_global"],
                          corr["coll_global"])
            tag = ""
        else:
            fl = (rec["cost_analysis"].get("flops") or 0) * rec["chips"]
            by = (rec["cost_analysis"].get("bytes accessed") or 0) \
                * rec["chips"]
            co = rec["collective_bytes"] * rec["chips"]
            tag = "*"
        t = ra.roofline_terms(fl, by, co, rec["chips"])
        mf = ra.model_flops(cfg, spec.kind, spec.seq, spec.global_batch)
        ratio = mf / fl if fl else float("nan")
        temp = rec["memory"].get("temp_size_in_bytes")
        rows.append((cell, t["dominant"] + tag,
                     f"{t['compute_s']:.2e}", f"{t['memory_s']:.2e}",
                     f"{t['collective_s']:.2e}", f"{ratio:.2f}",
                     fmt_bytes(temp), f"{rec.get('compile_s', '-')}s"))
    return rows


def print_roofline(recs, mesh, variant):
    print(f"\n### Roofline — {mesh} / {variant} "
          f"(terms in s; * = raw scan-undercounted)\n")
    print("| cell | dominant | compute | memory | collective | "
          "model/HLO flops | temp/dev | compile |")
    print("|---|---|---|---|---|---|---|---|")
    for r in roofline_rows(recs, mesh, variant):
        print("| " + " | ".join(str(x) for x in r) + " |")


def print_compare(recs_new, recs_old, mesh="pod16x16", variant="axllm-int8"):
    print(f"\n### before/after (temp bytes + collective bytes per device)\n")
    print("| cell | temp before | temp after | coll before | coll after |")
    print("|---|---|---|---|---|")
    for key in sorted(recs_new):
        cell, m, v = key
        if m != mesh or v != variant:
            continue
        a, b = recs_old.get(key), recs_new[key]
        if not a or a["status"] != "ok" or b["status"] != "ok":
            continue
        ta = a["memory"].get("temp_size_in_bytes")
        tb = b["memory"].get("temp_size_in_bytes")
        ca, cb = a.get("collective_bytes"), b.get("collective_bytes")
        print(f"| {cell} | {fmt_bytes(ta)} | {fmt_bytes(tb)} | "
              f"{fmt_bytes(ca)} | {fmt_bytes(cb)} |")


def print_variants(recs, cells, mesh="pod16x16"):
    print("\n### serve-variant comparison (per-device)\n")
    print("| cell | variant | mem term (s) | coll term (s) | args bytes | "
          "temp |")
    print("|---|---|---|---|---|---|")
    for cell in cells:
        for (c, m, v), rec in sorted(recs.items()):
            if c != cell or m != mesh or rec["status"] != "ok":
                continue
            corr = corrected(rec)
            chips = rec["chips"]
            if corr:
                by, co = corr["bytes_global"], corr["coll_global"]
            else:
                by = (rec["cost_analysis"].get("bytes accessed") or 0) * chips
                co = rec["collective_bytes"] * chips
            t = ra.roofline_terms(1.0, by, co, chips)
            print(f"| {cell} | {v} | {t['memory_s']:.2e} | "
                  f"{t['collective_s']:.2e} | "
                  f"{fmt_bytes(rec['memory'].get('argument_size_in_bytes'))} |"
                  f" {fmt_bytes(rec['memory'].get('temp_size_in_bytes'))} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--variant", default="axllm-int8")
    ap.add_argument("--compare", default="")
    ap.add_argument("--variants", action="store_true")
    ap.add_argument("--cells", default="")
    args = ap.parse_args()
    recs = load(args.dir)
    if args.compare:
        print_compare(recs, load(args.compare), args.mesh, args.variant)
    elif args.variants:
        cells = args.cells.split(",") if args.cells else sorted(
            {c for (c, m, v) in recs if SHAPES[c.split(":")[1]].kind
             != "train"})
        print_variants(recs, cells, args.mesh)
    else:
        print_roofline(recs, args.mesh, args.variant)


if __name__ == "__main__":
    main()
