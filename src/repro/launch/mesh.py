"""Production mesh construction.

Single pod: (16, 16) = ("data", "model") — 256 chips (one v5e pod).
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips; the "pod"
axis is pure data parallelism whose gradient sync crosses the inter-pod DCN
(the axis dist/compression.py targets with int8 error-feedback exchange).

Functions, not module constants: importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2, pod: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))
