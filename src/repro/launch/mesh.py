"""Production mesh construction.

Single pod: (16, 16) = ("data", "model") — 256 chips (one v5e pod).
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips; the "pod"
axis is pure data parallelism whose gradient sync crosses the inter-pod DCN
(the axis dist/compression.py targets with int8 error-feedback exchange).

Functions, not module constants: importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import os

import jax


def force_host_device_count(n: int) -> None:
    """Best-effort: expose >= ``n`` host CPU devices for serving meshes.

    Appends ``--xla_force_host_platform_device_count`` to XLA_FLAGS —
    effective only BEFORE the first jax backend initialization (call it
    at the top of a launcher main(), as tests/conftest.py does for
    pytest). A no-op when the flag is already set."""
    if n <= 1:
        return
    flag = "--xla_force_host_platform_device_count"
    flags = os.environ.get("XLA_FLAGS", "")
    if flag not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {flag}={n}".strip()


def parse_mesh_shape(spec: str):
    """Parse a ``--mesh-shape`` string into (data, model) sizes.

    Accepts a bare model-axis size ("8" -> data=1, model=8) or an
    explicit "DATAxMODEL" / "DATA,MODEL" pair ("2x4" -> data=2, model=4).

    >>> parse_mesh_shape("8")
    (1, 8)
    >>> parse_mesh_shape("2x4")
    (2, 4)
    """
    parts = [int(p) for p in spec.lower().replace("x", ",").split(",") if p]
    if not parts or any(p < 1 for p in parts) or len(parts) > 2:
        raise ValueError(f"mesh shape {spec!r}: expected 'MODEL' or "
                         "'DATAxMODEL' with positive sizes")
    if len(parts) == 1:
        return 1, parts[0]
    return parts[0], parts[1]


def make_serve_mesh(spec: str):
    """Build the ("data", "model") serving mesh for a --mesh-shape value.

    Forces enough host CPU devices first (no-op once jax initialized or
    on real accelerator backends with sufficient devices)."""
    data, model = parse_mesh_shape(spec)
    force_host_device_count(data * model)
    return make_host_mesh(data=data, model=model)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2, pod: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))
