import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, record memory/cost/collective analysis per cell.

MUST be the process entry (the XLA_FLAGS line above precedes every other
import because jax locks the device count on first init). Never set that
flag globally — smoke tests and benchmarks see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                      # all cells
  ... --cells granite-3-8b:train_4k,glm4-9b:decode_32k              # subset
  ... --mesh multi                                                  # 2-pod
  ... --variant baseline-bf16                                       # serve cells unquantized
  ... --aux                                                         # 1/2-group unrolled roofline aux runs

Results append to results/dryrun/<cell>__<mesh>__<variant>.json (incremental
and resumable — one CPU core compiles these serially).
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core.axllm_linear import deploy_quantize
from repro.core.quantization import QuantConfig
from repro.dist import sharding as shd
from repro.launch import shapes as shp
from repro.launch.mesh import make_production_mesh
from repro.models.model import get_model
from repro.optim import adamw
from repro.roofline import analysis as ra
from repro.train.loop import make_train_step

RESULTS_DIR = "results/dryrun"


def _sds_with(tree_abs, spec_tree):
    """Attach NamedShardings to an eval_shape pytree."""
    return jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        tree_abs, spec_tree)


def _aux_config(cfg: ModelConfig, groups: int) -> ModelConfig:
    """Unrolled `groups`-group variant for the per-layer cost delta."""
    upd = dict(scan_layers=False, remat=False, grad_accum=1)
    if cfg.family == "ssm":
        upd["n_layers"] = groups * cfg.xlstm_slstm_every
    elif cfg.family == "hybrid":
        upd["n_layers"] = groups * cfg.hybrid_attn_every
    else:
        upd["n_layers"] = groups
        if cfg.is_encoder_decoder:
            upd["n_enc_layers"] = groups
    return dataclasses.replace(cfg, **upd)


def _n_groups(cfg: ModelConfig) -> float:
    if cfg.family == "ssm":
        return cfg.n_layers / cfg.xlstm_slstm_every
    if cfg.family == "hybrid":
        return cfg.n_layers / cfg.hybrid_attn_every
    return cfg.n_layers


def build_cell(cfg: ModelConfig, spec: shp.ShapeSpec, mesh, variant: str,
               aux_batch: int = 0):
    """Returns (jitted_fn, args) ready to .lower(*args).

    Variant grammar (serve cells): base in {baseline-bf16, axllm-int8,
    axllm-int4} with optional modifiers "-kvq" (int8 KV cache) and "-tp"
    (TP-only weight sharding — handled by _variant_rules)."""
    if "-kvq" in variant and spec.kind != "train":
        cfg = dataclasses.replace(cfg, quant_kv=True)
    if variant.startswith("axllm-int4"):
        cfg = dataclasses.replace(cfg, quant_bits=4)
    api = get_model(cfg, impl="auto")
    rng = jax.random.PRNGKey(0)
    b = aux_batch or spec.global_batch
    quantize = variant.startswith("axllm-int") and spec.kind != "train"
    long_ctx = spec.name == "long_500k"

    if spec.kind == "train":
        ocfg = adamw.AdamWConfig(int8_moments=cfg.int8_optimizer)
        params_abs = jax.eval_shape(api.init, rng)
        opt_abs = jax.eval_shape(lambda p: adamw.init(p, ocfg), params_abs)
        pspec = shd.param_specs(params_abs, mesh)
        ospec = _opt_specs(opt_abs, params_abs, pspec, mesh)
        # grad accumulators MUST be constrained to the param specs — XLA
        # otherwise replicates the f32 carry (§Perf iteration 1)
        step = make_train_step(api, ocfg, grad_specs=pspec)
        batch_abs = shp.batch_input_specs(cfg, spec, mesh)
        if aux_batch:
            batch_abs = {
                k: jax.ShapeDtypeStruct((b,) + v.shape[1:], v.dtype,
                                        sharding=v.sharding)
                for k, v in batch_abs.items()}
        args = (_sds_with(params_abs, pspec), _sds_with(opt_abs, ospec),
                batch_abs, jax.ShapeDtypeStruct((), jnp.int32))
        return jax.jit(step, donate_argnums=(0, 1)), args

    # serving cells
    if quantize:
        qcfg = QuantConfig(
            bits=cfg.quant_bits,
            mode="codebook" if cfg.quant_bits == 4 else "affine",
            granularity="per_channel", pack=cfg.quant_bits == 4)
        params_abs = jax.eval_shape(
            lambda r: deploy_quantize(api.init(r), qcfg), rng)
    else:
        params_abs = jax.eval_shape(api.init, rng)
    pspec = shd.param_specs(params_abs, mesh)
    cache_abs = jax.eval_shape(lambda: api.init_cache(b, spec.seq))
    cspec = shd.cache_specs(cache_abs, mesh, b, spec.seq,
                            long_context=long_ctx)
    cache_args = _sds_with(cache_abs, cspec)

    if spec.kind == "prefill":
        batch_abs = shp.batch_input_specs(cfg, spec, mesh, targets=False)
        if aux_batch:
            batch_abs = {
                k: jax.ShapeDtypeStruct((b,) + v.shape[1:], v.dtype)
                for k, v in batch_abs.items()}
        fn = lambda p, bt, c: api.prefill(p, bt, c)
        return (jax.jit(fn, donate_argnums=(2,)),
                (_sds_with(params_abs, pspec), batch_abs, cache_args))

    token = shp.token_input_specs(cfg, spec, mesh)
    if aux_batch:
        token = jax.ShapeDtypeStruct((b,), jnp.int32)
    fn = lambda p, t, c: api.decode(p, t, c)
    return (jax.jit(fn, donate_argnums=(2,)),
            (_sds_with(params_abs, pspec), token, cache_args))


def _opt_specs(opt_abs, params_abs, pspec, mesh):
    """Optimizer-state shardings: moments follow their parameter's spec;
    Q8 moments are param-shaped, so codes take the param spec directly and
    scales take it minus the (blocked) last dim."""
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.optim.adamw import Q8

    def follow(m_abs, p_spec):
        if isinstance(m_abs, Q8):
            codes = p_spec
            lead = tuple(p_spec.spec)[: m_abs.codes.ndim - 1]
            lead = lead + (None,) * (m_abs.scale.ndim - len(lead))
            scale = NamedSharding(mesh, PartitionSpec(
                *lead[: m_abs.scale.ndim]))
            return Q8(codes, scale, m_abs.shape, m_abs.pad)
        return p_spec

    is_leaf = lambda x: isinstance(x, Q8) or hasattr(x, "shape")
    m = jax.tree_util.tree_map(follow, opt_abs["m"], pspec,
                               is_leaf=lambda x: isinstance(x, Q8) or
                               not isinstance(x, dict))
    v = jax.tree_util.tree_map(follow, opt_abs["v"], pspec,
                               is_leaf=lambda x: isinstance(x, Q8) or
                               not isinstance(x, dict))
    from jax.sharding import NamedSharding, PartitionSpec as P
    return {"m": m, "v": v, "count": NamedSharding(mesh, P())}


def _variant_rules(variant: str, kind: str):
    """Hillclimb levers: '-tp' serve variants use TP-only weight sharding
    (no FSDP all-gather per token); '-dp' replicates weights entirely and
    spreads batch over all axes (small-arch serving)."""
    if kind == "train":
        return shd.DEFAULT_RULES
    if variant.endswith("-dp"):
        return shd.DP_SERVE_RULES
    if variant.endswith("-tp"):
        return shd.SERVE_RULES
    return shd.DEFAULT_RULES


def run_cell(cell: shp.Cell, multi_pod: bool, variant: str,
             with_aux: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = get_config(cell.arch)
    spec = shp.SHAPES[cell.shape]
    rec = {"cell": cell.key, "mesh": mesh_name, "variant": variant,
           "chips": 512 if multi_pod else 256}
    if cell.skip:
        rec.update(status="skipped", reason=cell.skip)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = dict(_variant_rules(variant, spec.kind))
    if spec.name == "long_500k":
        # the idle data axis absorbs the 500k cache (batch=1)
        rules["cache_seq"] = rules["cache_seq_long"]
    t0 = time.time()
    try:
        with shd.activate(mesh, rules):
            fn, args = build_cell(cfg, spec, mesh, variant)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = ra.memory_dict(compiled)
            cost = ra.cost_dict(compiled)
            text = compiled.as_text()
            coll = ra.parse_collectives(text)
            del text, compiled, lowered
        rec.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1), memory=mem,
                   cost_analysis={k: cost.get(k) for k in
                                  ("flops", "bytes accessed",
                                   "transcendentals") if k in cost},
                   collectives=coll,
                   collective_bytes=ra.total_collective_bytes(coll))
        if with_aux and not multi_pod:
            rec["aux"] = run_aux(cfg, spec, mesh, variant)
    except Exception as e:  # record, don't abort the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def run_aux(cfg: ModelConfig, spec: shp.ShapeSpec, mesh, variant: str) -> dict:
    """1-group / 2-group unrolled lowering for the per-layer cost delta
    (scan bodies are counted once by XLA cost analysis — see roofline doc).
    Batch is scaled down for train (grad_accum=1 microbatch equivalent)."""
    from repro.kernels import ops as kops

    out = {}
    aux_batch = max(mesh.shape.get("data", 1) * mesh.shape.get("pod", 1),
                    spec.global_batch // max(cfg.grad_accum, 1)) \
        if spec.kind == "train" else spec.global_batch
    kops.set_analysis_mode(True)
    try:
        for g in (1, 2):
            acfg = _aux_config(cfg, g)
            fn, args = build_cell(acfg, spec, mesh, variant,
                                  aux_batch=aux_batch)
            compiled = fn.lower(*args).compile()
            cost = ra.cost_dict(compiled)
            text = compiled.as_text()
            coll = ra.parse_collectives(text)
            out[f"g{g}"] = {
                "flops": cost.get("flops"),
                "bytes": cost.get("bytes accessed"),
                "collective_bytes": ra.total_collective_bytes(coll),
                "aux_batch": aux_batch,
            }
            del text, compiled
    finally:
        kops.set_analysis_mode(False)
    out["n_groups"] = _n_groups(cfg)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default="",
                    help="comma-separated arch:shape filters")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--variant", default="axllm-int8",
                    help="baseline-bf16 | axllm-int8 | axllm-int4, with "
                    "optional -kvq / -tp modifiers (e.g. axllm-int8-kvq-tp)")
    ap.add_argument("--aux", action="store_true",
                    help="run 1/2-group unrolled roofline aux lowering")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value applied to every cell "
                    "in this invocation (hillclimb lever); use with --tag")
    ap.add_argument("--tag", default="",
                    help="suffix for result filenames (override experiments)")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    overrides = dict(kv.split("=", 1) for kv in args.set)
    if overrides:
        from repro.configs import apply_overrides
        global get_config
        _orig_get = get_config
        get_config = lambda name: apply_overrides(_orig_get(name), overrides)

    os.makedirs(args.out, exist_ok=True)
    wanted = set(args.cells.split(",")) if args.cells else None
    meshes = {"single": [False], "multi": [True], "both": [False, True]}
    tag = f"__{args.tag}" if args.tag else ""
    for cell in shp.all_cells():
        if wanted and cell.key not in wanted:
            continue
        for multi in meshes[args.mesh]:
            mesh_name = "pod2x16x16" if multi else "pod16x16"
            fname = os.path.join(
                args.out,
                f"{cell.key.replace(':', '__')}__{mesh_name}"
                f"__{args.variant}{tag}.json")
            if os.path.exists(fname):
                with open(fname) as f:
                    prev = json.load(f)
                if prev.get("status") in ("ok", "skipped") and \
                        (not args.aux or "aux" in prev or
                         prev.get("status") == "skipped" or multi):
                    print(f"[skip-cached] {cell.key} {mesh_name}")
                    continue
            print(f"[run] {cell.key} {mesh_name} {args.variant}", flush=True)
            rec = run_cell(cell, multi, args.variant, with_aux=args.aux)
            with open(fname, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"  -> {rec['status']} "
                  f"(compile {rec.get('compile_s', '-')}s)", flush=True)


if __name__ == "__main__":
    main()
