"""Production training launcher.

Single host (this container):
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
      --batch 8 --seq 256 --steps 100 --set n_layers=4 --set d_model=256

Multi-host pods: the same entry point runs under one process per host with
jax.distributed (see launch/pod_launch.sh); device mesh axes come from
--mesh. Checkpoints are elastic — a run stopped on one mesh resumes on
another (train/checkpoint.py resharding).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs import apply_overrides, get_config
from repro.data.pipeline import make_dataset, shard_batch
from repro.dist import sharding as shd
from repro.models.model import get_model
from repro.optim import adamw
from repro.train.fault_tolerance import StepMonitor, resilient_train
from repro.train.loop import make_train_step


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="auto",
                    help='"auto", "DxM" (e.g. 4x2), or "PxDxM"')
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--data", default="synthetic", choices=["synthetic",
                                                            "bytes"])
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--distributed", action="store_true",
                    help="initialize jax.distributed from env (multi-host)")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable)")
    return ap.parse_args(argv)


def build_mesh(spec: str):
    n = len(jax.devices())
    if spec == "auto":
        model = 1
        while model * 2 <= n and n % (model * 2) == 0 and model < 8:
            model *= 2
        return jax.make_mesh((n // model, model), ("data", "model"))
    dims = tuple(int(x) for x in spec.split("x"))
    axes = {2: ("data", "model"), 3: ("pod", "data", "model")}[len(dims)]
    return jax.make_mesh(dims, axes)


def main(argv=None):
    args = parse_args(argv)
    if args.distributed:
        jax.distributed.initialize()
    cfg = get_config(args.arch)
    overrides = dict(kv.split("=", 1) for kv in args.set)
    if overrides:
        cfg = apply_overrides(cfg, overrides)
    mesh = build_mesh(args.mesh)
    api = get_model(cfg)
    print(f"arch={cfg.name} devices={len(jax.devices())} "
          f"mesh={dict(mesh.shape)}")

    with shd.activate(mesh):
        params = api.init(jax.random.PRNGKey(0))
        pspec = shd.param_specs(params, mesh)
        params = jax.tree_util.tree_map(jax.device_put, params, pspec)
        ocfg = adamw.AdamWConfig(lr=args.lr,
                                 int8_moments=cfg.int8_optimizer)
        opt = adamw.init(params, ocfg)
        step_jit = jax.jit(make_train_step(api, ocfg,
                                           total_steps=args.steps,
                                           warmup=max(args.steps // 20, 5),
                                           grad_specs=pspec))

        def step_fn(p, o, batch, s):
            return step_jit(p, o, shard_batch(batch, mesh), s)

        ds = make_dataset(cfg, batch=args.batch, seq=args.seq, seed=0,
                          source=args.data)
        monitor = StepMonitor()
        params, opt, history, restarts = resilient_train(
            train_step=step_fn, params=params, opt_state=opt, dataset=ds,
            ckpt_dir=args.ckpt, total_steps=args.steps,
            save_every=args.save_every, monitor=monitor)
    for s, l in history:
        print(f"step {s:5d}  loss {l:.4f}")
    print(f"done: restarts={restarts} stragglers={len(monitor.events)}")
    return params


if __name__ == "__main__":
    main()
