"""Assigned input-shape sets and the 40-cell (arch x shape) enumeration.

    train_4k     seq 4,096   global_batch 256   lowers train_step
    prefill_32k  seq 32,768  global_batch 32    lowers prefill_step
    decode_32k   seq 32,768  global_batch 128   lowers serve_step (1 token,
                                                KV/state cache of seq_len)
    long_500k    seq 524,288 global_batch 1     lowers serve_step; ONLY for
                                                sub-quadratic-state archs
                                                (ssm/hybrid) — pure-attention
                                                archs skip (DESIGN.md §4)

`input_specs(cfg, shape, mesh)` returns weak-type-correct ShapeDtypeStructs
with shardings attached — no device allocation anywhere on the dry-run path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config
from repro.configs.base import ModelConfig
from repro.dist import sharding as shd


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    skip: Optional[str] = None    # reason, when sanctioned by the assignment

    @property
    def key(self) -> str:
        return f"{self.arch}:{self.shape}"


def all_cells() -> List[Cell]:
    cells = []
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for sname in SHAPES:
            skip = None
            if sname == "long_500k" and not cfg.supports_long_context:
                skip = ("pure full-attention arch: 500k context requires "
                        "sub-quadratic state (assignment-sanctioned skip)")
            cells.append(Cell(arch, sname, skip))
    return cells


def sds(shape, dtype, names, mesh):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=shd.named_sharding(shape, names, mesh))


def batch_input_specs(cfg: ModelConfig, spec: ShapeSpec, mesh,
                      targets: bool = True):
    b, s = spec.global_batch, spec.seq
    out = {"tokens": sds((b, s), jnp.int32, ("batch", "seq"), mesh)}
    if targets:
        out["targets"] = out["tokens"]
    if cfg.is_encoder_decoder:
        out["frames"] = sds((b, cfg.enc_seq, cfg.d_feat), jnp.float32,
                            ("batch", None, None), mesh)
    return out


def token_input_specs(cfg: ModelConfig, spec: ShapeSpec, mesh):
    """Decode-step inputs: one new token per sequence."""
    b = spec.global_batch
    return sds((b,), jnp.int32, ("batch",), mesh)
