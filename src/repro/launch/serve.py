"""Serving launcher: loads a checkpoint (or fresh weights), deploys through
the AxLLM quantized path, and serves a synthetic request stream through the
batched engine.

  PYTHONPATH=src python -m repro.launch.serve --arch repro-100m \
      --requests 16 --max-new 32 [--no-quantize] [--kv-int8]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import apply_overrides, get_config
from repro.models.model import get_model
from repro.serve.engine import ServeEngine
from repro.train import checkpoint as C


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--no-quantize", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--set", action="append", default=[])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    overrides = dict(kv.split("=", 1) for kv in args.set)
    if args.kv_int8:
        overrides["quant_kv"] = "true"
    if overrides:
        cfg = apply_overrides(cfg, overrides)

    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    if args.ckpt and C.latest_step(args.ckpt) is not None:
        from repro.optim import adamw
        opt = adamw.init(params, adamw.AdamWConfig())
        (params, _), step = C.restore(args.ckpt, (params, opt))
        print(f"restored step {step} from {args.ckpt}")

    eng = ServeEngine(cfg, params, n_slots=args.slots,
                      max_len=args.max_len,
                      quantize=not args.no_quantize)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)
               for _ in range(args.requests)]
    t0 = time.time()
    outs = eng.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    toks = sum(len(o) for o in outs)
    mode = "bf16" if args.no_quantize else f"axllm-int{cfg.quant_bits}"
    print(f"[{mode}] {len(outs)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s (host fallback path)")
    for o in outs[:3]:
        print("  ->", o[:12])


if __name__ == "__main__":
    main()
