"""Serving launcher: loads a checkpoint (or fresh weights), deploys through
the AxLLM quantized path, and serves a synthetic mixed-length request stream
through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch repro-100m \
      --requests 16 --max-new 32 [--no-quantize] [--kv-int8] \
      [--eos-id 0] [--long-prompt reject] [--lora 2] [--stats]

Flags of note:
  --decode-chunk N  on-device decode steps per dispatch (default cfg value,
                    8; 1 reproduces the per-token host round-trip loop)
  --paged           serve through the block-paged KV pool with radix-tree
                    prefix reuse (attention families; shared prompt heads
                    prefill once — see --kv-block-size/--prefix-cache)
  --kv-block-size N tokens per KV pool block (power of two, default 16)
  --prefix-cache    radix prefix index on the paged pool (default on;
                    --no-prefix-cache keeps paging but disables reuse)
  --num-blocks N    KV pool size in blocks (default: 2x dense equivalent)
  --fuse-qkv        rewrite deployed params to fused wqkv/gate_up
                    projections (one activation pass per block)
  --reuse           run quantized matmuls through the reuse (LUT) kernel
                    path (impl="reuse": Result-Cache gather on TPU, jnp
                    oracle elsewhere — token-identical to the multiply path)
  --quant-bits N    serve-path weight code width (default cfg.quant_bits)
  --quant-mode M    'affine' (symmetric uniform, default) or 'codebook'
                    (NF4 for 4-bit) deploy-quantization alphabet
  --eos-id N        per-slot stop token (overrides cfg.eos_id; -1 disables)
  --long-prompt P   'truncate' (keep the prompt tail, default) or 'reject'
                    prompts longer than max_len-1
  --prompt-lens L   comma list of prompt lengths cycled over the stream
                    (mixed lengths exercise the ragged prefill waves)
  --lora N          register N synthetic LoRA adapters and cycle requests
                    over base + adapters (the dual-pipeline serving path;
                    see also --lora-rank/--lora-alpha/--lora-targets/
                    --max-loras)
  --mesh-shape S    tensor-parallel serving mesh: a model-axis size ("8")
                    or "DATAxMODEL" ("2x4"); default "1" serves
                    single-device. Sizes > 1 on CPU force host devices
                    (see launch/mesh.py); sharded decode is
                    token-identical to single-device
  --arrival-rate A  open-loop arrivals ('poisson:<r>' / 'fixed:<r>'
                    requests/s) instead of submitting everything up front;
                    pairs with --admission/--max-queue/--priority/
                    --deadline-s for overload behavior
  --prefill-budget N  chunked prefill: cap prompt tokens prefilled per
                    engine step (paged only) so long prompts interleave
                    with running decodes instead of stalling them
  --stream          streaming output: tokens emitted via submit(on_token=)
                    at chunk-harvest time; prints per-stream counts
  --ttft-deadline-s / --itl-deadline-s
                    mid-run execution deadlines (time-to-first-token /
                    inter-token); a stream that blows one finishes as
                    'expired' with its resources freed
  --stats           print the engine's scheduler stats as JSON
                    (admitted/finished/truncated, tokens/step, occupancy)

The full flags table is documented in docs/ARCHITECTURE.md (CI's docs job
fails when this parser and that table drift apart).
"""

from __future__ import annotations

import argparse
import json
import math
import time

import jax
import numpy as np

from repro.configs import apply_overrides, get_config
from repro.models.model import get_model
from repro.serve.engine import ServeEngine
from repro.train import checkpoint as C


def make_synthetic_adapters(cfg, n: int, rank: int = 8, alpha: float = 16.0,
                            targets=("wq", "wv"), max_loras=None, seed=0):
    """Build an AdapterRegistry with ``n`` random (non-zero-B) adapters.

    Stands in for trained adapters in the launcher/benchmark: each
    adapter's B matrices are small random values so the delta pipeline
    measurably changes outputs without wrecking the base distribution.
    Returns (registry, [adapter names]).
    """
    import jax.numpy as jnp

    from repro.core.axllm_linear import LoRAConfig
    from repro.serve.adapters import AdapterRegistry, target_dims

    lcfg = LoRAConfig(rank=rank, alpha=alpha, targets=tuple(targets))
    reg = AdapterRegistry(cfg, lcfg,
                          max_loras=max_loras or max(4, n))
    rng = np.random.default_rng(seed)
    names = []
    for i in range(n):
        ad = {}
        for t in lcfg.targets:
            n_in, n_out = target_dims(cfg, t)
            ad[t] = {
                "lora_a": jnp.asarray(
                    rng.normal(size=(cfg.n_layers, n_in, rank))
                    / np.sqrt(rank), jnp.float32),
                "lora_b": jnp.asarray(
                    rng.normal(size=(cfg.n_layers, rank, n_out)) * 0.05,
                    jnp.float32),
            }
        name = f"adapter{i}"
        reg.add(name, ad)
        names.append(name)
    return reg, names


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--no-quantize", action="store_true")
    ap.add_argument("--reuse", action="store_true",
                    help="dispatch quantized matmuls through the reuse "
                         "(LUT) kernel path instead of multiply-dequant")
    ap.add_argument("--quant-bits", type=int, default=None,
                    help="weight code width for deploy quantization "
                         "(default: cfg.quant_bits)")
    ap.add_argument("--quant-mode", choices=("affine", "codebook"),
                    default="affine",
                    help="deploy-quantization alphabet (codebook = NF4 "
                         "for 4-bit)")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--decode-chunk", type=int, default=None,
                    help="on-device decode steps per dispatch (default: "
                         "cfg.decode_chunk)")
    ap.add_argument("--fuse-qkv", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="fused wqkv/gate_up projections (--no-fuse-qkv "
                         "overrides a config that enables them)")
    ap.add_argument("--paged", action="store_true",
                    help="block-paged KV cache with radix-tree prefix "
                         "reuse (attention families only)")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="tokens per KV pool block (power of two)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="radix prefix index on the paged pool (disable "
                         "to page without reuse)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV pool blocks (default: 2x the dense-equivalent "
                         "capacity plus trash and CoW spare)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop token id (-1: disable even if cfg sets one)")
    ap.add_argument("--long-prompt", choices=("truncate", "reject"),
                    default="truncate")
    ap.add_argument("--prompt-lens", default="8,12,31",
                    help="comma list of prompt lengths cycled over requests")
    ap.add_argument("--lora", type=int, default=0,
                    help="register N synthetic LoRA adapters and cycle "
                         "requests over base + adapters (0: base only)")
    ap.add_argument("--lora-rank", type=int, default=8,
                    help="adapter rank (all registered adapters share it)")
    ap.add_argument("--lora-alpha", type=float, default=16.0,
                    help="adapter alpha (scaling = alpha / rank)")
    ap.add_argument("--lora-targets", default="wq,wv",
                    help="comma list of attention projections the adapters "
                         "target (subset of wq,wk,wv,wo)")
    ap.add_argument("--max-loras", type=int, default=None,
                    help="registry capacity (default: max(4, --lora))")
    ap.add_argument("--mesh-shape", default="1",
                    help="tensor-parallel serving mesh: model-axis size "
                         "('8') or 'DATAxMODEL' ('2x4'); '1' (default) "
                         "serves single-device")
    ap.add_argument("--arrival-rate", default=None,
                    help="open-loop arrivals: 'poisson:<rate>' or "
                         "'fixed:<rate>' requests/s submitted on their own "
                         "clock (default: closed-loop, all requests "
                         "submitted up front)")
    ap.add_argument("--admission", choices=("block", "reject", "evict"),
                    default="block",
                    help="policy when the wait queue is full: block the "
                         "submitter, reject the newcomer, or evict the "
                         "lowest-priority queued request")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="wait-queue bound that arms --admission "
                         "(default: unbounded)")
    ap.add_argument("--priority", default="0",
                    help="comma list of priorities cycled over requests "
                         "(higher preempts lower under overload)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="queue-wait deadline per request; requests not "
                         "admitted in time finish as 'expired'")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="chunked prefill: max prompt tokens prefilled per "
                         "engine step (paged only; bounds step time so "
                         "long prompts interleave with decode)")
    ap.add_argument("--stream", action="store_true",
                    help="streaming output: emit tokens through "
                         "submit(on_token=) at chunk-harvest time and "
                         "report per-stream counts")
    ap.add_argument("--ttft-deadline-s", type=float, default=None,
                    help="execution deadline on time-to-first-token; a "
                         "request that blows it finishes as 'expired'")
    ap.add_argument("--itl-deadline-s", type=float, default=None,
                    help="execution deadline on inter-token latency; a "
                         "stream that stalls longer finishes as 'expired'")
    ap.add_argument("--speculate", action="store_true",
                    help="self-speculative decoding: a low-bit draft of the "
                         "same model proposes --spec-k tokens per round, the "
                         "serving-precision target verifies them in one "
                         "chunked dispatch (bit-identical to target-only "
                         "greedy)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per speculation round")
    ap.add_argument("--draft-bits", type=int, default=4,
                    help="draft quantization width (default int4)")
    ap.add_argument("--draft-mode",
                    choices=("affine", "codebook", "shiftadd"),
                    default="affine",
                    help="draft weight reconstruction: affine/codebook "
                         "low-bit quantization or the shift-add binary "
                         "reparameterization")
    ap.add_argument("--stats", action="store_true",
                    help="print scheduler stats JSON after the run")
    ap.add_argument("--set", action="append", default=[])
    args = ap.parse_args(argv)

    # mesh construction precedes the first jax computation: on CPU the
    # host-device forcing flag only takes effect before backend init
    from repro.launch.mesh import make_serve_mesh, parse_mesh_shape
    mesh = None
    if math.prod(parse_mesh_shape(args.mesh_shape)) > 1:
        mesh = make_serve_mesh(args.mesh_shape)

    cfg = get_config(args.arch)
    overrides = dict(kv.split("=", 1) for kv in args.set)
    if args.kv_int8:
        overrides["quant_kv"] = "true"
    if overrides:
        cfg = apply_overrides(cfg, overrides)

    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    if args.ckpt and C.latest_step(args.ckpt) is not None:
        from repro.optim import adamw
        opt = adamw.init(params, adamw.AdamWConfig())
        (params, _), step = C.restore(args.ckpt, (params, opt))
        print(f"restored step {step} from {args.ckpt}")

    eos_id = args.eos_id
    if eos_id is not None and eos_id < 0:
        eos_id = None
        cfg = apply_overrides(cfg, {"eos_id": "none"})

    registry = None
    adapter_cycle = [None]
    if args.lora > 0:
        registry, names = make_synthetic_adapters(
            cfg, n=args.lora, rank=args.lora_rank, alpha=args.lora_alpha,
            targets=tuple(t for t in args.lora_targets.split(",") if t),
            max_loras=args.max_loras)
        adapter_cycle = [None] + names
        print(f"registered {len(names)} LoRA adapters "
              f"(rank {args.lora_rank}, targets {args.lora_targets}); "
              f"requests cycle over base + {names}")

    eng = ServeEngine(cfg, params, n_slots=args.slots,
                      max_len=args.max_len,
                      quantize=not args.no_quantize,
                      quant_bits=args.quant_bits,
                      quant_mode=args.quant_mode,
                      impl="reuse" if args.reuse else "auto",
                      eos_id=eos_id, long_prompt=args.long_prompt,
                      decode_chunk=args.decode_chunk,
                      fuse_qkv=args.fuse_qkv, adapters=registry,
                      paged=args.paged, kv_block_size=args.kv_block_size,
                      num_blocks=args.num_blocks,
                      prefix_cache=args.prefix_cache, mesh=mesh,
                      max_queue=args.max_queue, admission=args.admission,
                      speculate=args.speculate, spec_k=args.spec_k,
                      draft_bits=args.draft_bits,
                      draft_mode=args.draft_mode,
                      prefill_budget=args.prefill_budget)
    rng = np.random.default_rng(0)
    lens = [int(x) for x in args.prompt_lens.split(",") if x]
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=lens[i % len(lens)]).astype(np.int32)
               for i in range(args.requests)]
    adapters = [adapter_cycle[i % len(adapter_cycle)]
                for i in range(args.requests)]
    prios = [int(x) for x in args.priority.split(",") if x] or [0]
    streamed = {"tokens": 0, "streams": set()}
    on_token = None
    if args.stream:
        def on_token(req, tok):
            streamed["tokens"] += 1
            streamed["streams"].add(req.rid)
    per_req = dict(on_token=on_token,
                   ttft_deadline_s=args.ttft_deadline_s,
                   itl_deadline_s=args.itl_deadline_s)
    t0 = time.time()
    if args.arrival_rate:
        # open-loop: requests land on their own clock; the engine keeps
        # stepping between arrivals and sheds per --admission/--deadline-s
        from repro.serve.scheduler import arrival_times
        at = arrival_times(args.arrival_rate, len(prompts))
        i = 0
        while True:
            now = time.time() - t0
            while i < len(prompts) and at[i] <= now:
                eng.submit(prompts[i], max_new=args.max_new,
                           adapter=adapters[i],
                           priority=prios[i % len(prios)],
                           deadline_s=args.deadline_s, **per_req)
                i += 1
            if eng.step():
                continue
            if i >= len(prompts):
                break
            time.sleep(min(0.002, max(0.0, at[i] - (time.time() - t0))))
        reqs = list(eng.finished)
    elif args.stream or args.ttft_deadline_s is not None \
            or args.itl_deadline_s is not None:
        # closed-loop but per-request streaming/deadline state: submit
        # explicitly instead of going through generate()
        for i, p in enumerate(prompts):
            eng.submit(p, max_new=args.max_new, adapter=adapters[i],
                       priority=prios[i % len(prios)],
                       deadline_s=args.deadline_s, **per_req)
        while eng.step():
            pass
        reqs = list(eng.finished)
    else:
        reqs = eng.generate(prompts, max_new=args.max_new,
                            return_requests=True, adapters=adapters)
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in reqs)
    bits = cfg.quant_bits if args.quant_bits is None else args.quant_bits
    mode = "bf16" if args.no_quantize else (
        f"axllm-{args.quant_mode}{bits}"
        + ("+reuse" if args.reuse else ""))
    lora_tag = f", {eng.stats.lora_requests} LoRA requests" if args.lora \
        else ""
    mesh_tag = f", mesh {args.mesh_shape}" if mesh is not None else ""
    print(f"[{mode}] {len(reqs)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s, occupancy "
          f"{eng.stats.mean_occupancy:.2f}{lora_tag}{mesh_tag} "
          f"(host fallback path)")
    if args.arrival_rate:
        st = eng.stats
        print(f"  open-loop [{args.arrival_rate}, admission="
              f"{args.admission}]: rejected={st.rejected} "
              f"expired={st.expired} preempted={st.preempted} "
              f"restored={st.restored} ({st.fast_restores} fast)")
    if args.stream:
        st = eng.stats
        print(f"  streaming: {streamed['tokens']} tokens emitted across "
              f"{len(streamed['streams'])} streams at chunk harvest "
              f"(cancelled={st.cancelled}, expired={st.expired})")
    if args.prefill_budget:
        st = eng.stats
        print(f"  chunked prefill [budget={args.prefill_budget}]: "
              f"{st.prefill_chunks} chunks over {st.prefill_waves} waves, "
              f"{st.preempted_prefill} mid-prefill preemptions")
    if args.speculate:
        st = eng.stats
        print(f"  speculative [k={args.spec_k}, "
              f"{args.draft_mode}{args.draft_bits} draft]: "
              f"{st.accepted_draft_tokens}/{st.drafted_tokens} drafts "
              f"accepted ({st.acceptance_rate:.2f}), "
              f"{st.accepted_tokens_per_step:.2f} tokens/slot-round "
              f"over {st.spec_rounds} rounds")
    if args.paged:
        print(f"  paged: {eng.stats.prefix_hit_tokens} prefix-hit tokens, "
              f"{eng.stats.blocks_in_use} blocks cached, "
              f"{eng.stats.cow_copies} CoW copies "
              f"(block={args.kv_block_size}, "
              f"prefix_cache={'on' if args.prefix_cache else 'off'})")
    for r in reqs[:3]:
        tag = " [truncated]" if r.truncated else ""
        ad = f" [{r.adapter}]" if r.adapter else ""
        print(f"  -> {r.tokens[:12]}{tag}{ad}")
    if args.stats:
        print(json.dumps(eng.stats.as_dict(), indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
