"""Serving launcher: loads a checkpoint (or fresh weights), deploys through
the AxLLM quantized path, and serves a synthetic mixed-length request stream
through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch repro-100m \
      --requests 16 --max-new 32 [--no-quantize] [--kv-int8] \
      [--eos-id 0] [--long-prompt reject] [--stats]

Flags of note:
  --decode-chunk N  on-device decode steps per dispatch (default cfg value,
                    8; 1 reproduces the per-token host round-trip loop)
  --fuse-qkv        rewrite deployed params to fused wqkv/gate_up
                    projections (one activation pass per block)
  --eos-id N        per-slot stop token (overrides cfg.eos_id; -1 disables)
  --long-prompt P   'truncate' (keep the prompt tail, default) or 'reject'
                    prompts longer than max_len-1
  --prompt-lens L   comma list of prompt lengths cycled over the stream
                    (mixed lengths exercise the ragged prefill waves)
  --stats           print the engine's scheduler stats as JSON
                    (admitted/finished/truncated, tokens/step, occupancy)
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import apply_overrides, get_config
from repro.models.model import get_model
from repro.serve.engine import ServeEngine
from repro.train import checkpoint as C


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--no-quantize", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--decode-chunk", type=int, default=None,
                    help="on-device decode steps per dispatch (default: "
                         "cfg.decode_chunk)")
    ap.add_argument("--fuse-qkv", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="fused wqkv/gate_up projections (--no-fuse-qkv "
                         "overrides a config that enables them)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop token id (-1: disable even if cfg sets one)")
    ap.add_argument("--long-prompt", choices=("truncate", "reject"),
                    default="truncate")
    ap.add_argument("--prompt-lens", default="8,12,31",
                    help="comma list of prompt lengths cycled over requests")
    ap.add_argument("--stats", action="store_true",
                    help="print scheduler stats JSON after the run")
    ap.add_argument("--set", action="append", default=[])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    overrides = dict(kv.split("=", 1) for kv in args.set)
    if args.kv_int8:
        overrides["quant_kv"] = "true"
    if overrides:
        cfg = apply_overrides(cfg, overrides)

    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    if args.ckpt and C.latest_step(args.ckpt) is not None:
        from repro.optim import adamw
        opt = adamw.init(params, adamw.AdamWConfig())
        (params, _), step = C.restore(args.ckpt, (params, opt))
        print(f"restored step {step} from {args.ckpt}")

    eos_id = args.eos_id
    if eos_id is not None and eos_id < 0:
        eos_id = None
        cfg = apply_overrides(cfg, {"eos_id": "none"})
    eng = ServeEngine(cfg, params, n_slots=args.slots,
                      max_len=args.max_len,
                      quantize=not args.no_quantize,
                      eos_id=eos_id, long_prompt=args.long_prompt,
                      decode_chunk=args.decode_chunk,
                      fuse_qkv=args.fuse_qkv)
    rng = np.random.default_rng(0)
    lens = [int(x) for x in args.prompt_lens.split(",") if x]
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=lens[i % len(lens)]).astype(np.int32)
               for i in range(args.requests)]
    t0 = time.time()
    reqs = eng.generate(prompts, max_new=args.max_new, return_requests=True)
    dt = time.time() - t0
    toks = sum(len(r.tokens) for r in reqs)
    mode = "bf16" if args.no_quantize else f"axllm-int{cfg.quant_bits}"
    print(f"[{mode}] {len(reqs)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s, occupancy "
          f"{eng.stats.mean_occupancy:.2f} (host fallback path)")
    for r in reqs[:3]:
        tag = " [truncated]" if r.truncated else ""
        print(f"  -> {r.tokens[:12]}{tag}")
    if args.stats:
        print(json.dumps(eng.stats.as_dict(), indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
