"""Shared neural-net building blocks (pure functions over param pytrees).

No module framework in the container (no flax) — params are nested dicts of
arrays, initialized by `init_*` helpers and consumed by matching `*_fwd`
functions. Every weight matrix is stored [in, out] so the AxLLM serving
conversion (quantize_tree) and the sharding rules apply uniformly.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.axllm_linear import linear
from repro.dist.sharding import shard as _shard


def maybe_scan(body, carry, xs, use_scan: bool = True):
    """lax.scan or an unrolled python loop over the leading dim of `xs`.

    The unrolled form exists for the roofline aux lowering: XLA's HLO cost
    analysis counts a while-loop body once, so per-layer cost deltas are
    measured on 1-/2-group UNROLLED variants (launch/dryrun.run_aux)."""
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def truncated_normal(rng, shape, std, dtype=jnp.float32):
    return jax.random.truncated_normal(rng, -3.0, 3.0, shape, jnp.float32) \
        .astype(dtype) * std


def init_linear(rng, n_in, n_out, dtype=jnp.float32, std=None):
    std = std if std is not None else (1.0 / jnp.sqrt(n_in)).astype(jnp.float32)
    return truncated_normal(rng, (n_in, n_out), std, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def norm_fwd(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if "bias" in p:
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"] + p["bias"]
    else:
        var = (xf ** 2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def init_mlp(rng, cfg, d=None, d_ff=None, dtype=jnp.float32):
    d = d or cfg.d_model
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    if cfg.act == "swiglu":
        return {"gate": init_linear(ks[0], d, d_ff, dtype),
                "up": init_linear(ks[1], d, d_ff, dtype),
                "down": init_linear(ks[2], d_ff, d, dtype)}
    return {"up": init_linear(ks[0], d, d_ff, dtype),
            "down": init_linear(ks[1], d_ff, d, dtype)}


def fuse_mlp_params(p):
    """Replace gate/up with one fused gate_up (``[d, 2·d_ff]``) — the MLP
    analogue of the fused-QKV projection. GELU MLPs (no gate) are returned
    unchanged; `mlp_fwd` dispatches on key presence."""
    if "gate_up" in p or "gate" not in p:
        return p
    from repro.core.axllm_linear import concat_weights
    p2 = {k: v for k, v in p.items() if k not in ("gate", "up")}
    p2["gate_up"] = concat_weights([p["gate"], p["up"]])
    return p2


def mlp_fwd(p, x, cfg, impl: str = "auto"):
    if "gate_up" in p:   # fused path: one activation pass over [d, 2·d_ff]
        gu = linear(x, p["gate_up"], impl=impl)
        g, u = jnp.split(gu, 2, axis=-1)
        h = jax.nn.silu(g) * u
    elif "gate" in p:
        h = jax.nn.silu(linear(x, p["gate"], impl=impl)) \
            * linear(x, p["up"], impl=impl)
    else:
        h = jax.nn.gelu(linear(x, p["up"], impl=impl))
    h = _shard(h, "batch", "seq", "mlp")
    return linear(h, p["down"], impl=impl)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float = 1e4):
    """x: [..., S, H, d]; positions: broadcastable [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------

def init_embed(rng, cfg, dtype=jnp.float32):
    v, d = cfg.padded_vocab, cfg.d_model
    ks = jax.random.split(rng, 2)
    p = {"embedding": truncated_normal(ks[0], (v, d), 0.02, dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = init_linear(ks[1], d, v, dtype)
    return p


def embed_fwd(p, tokens):
    return jnp.take(p["embedding"], tokens, axis=0)


def head_fwd(p, x, cfg, impl: str = "auto"):
    if cfg.tie_embeddings:
        w = p["embedding"]
        from repro.core.quantization import QTensor
        if isinstance(w, QTensor):
            from repro.core.quantization import dequantize
            w = dequantize(w, x.dtype)
        return jnp.dot(x, w.T.astype(x.dtype))
    return linear(x, p["lm_head"], impl=impl)


def cross_entropy(logits, targets, vocab_size: int):
    """Mean CE over all positions; ids >= vocab_size (padding) are masked in
    the normalizer (padded logit columns are trained toward -inf only via the
    softmax denominator, never as targets)."""
    lf = logits.astype(jnp.float32)
    padded_v = lf.shape[-1]
    if padded_v > vocab_size:
        # elementwise iota mask (partitionable along a sharded vocab dim;
        # a scatter here would force an all-gather under GSPMD)
        mask = jnp.arange(padded_v) >= vocab_size
        lf = jnp.where(mask, -1e30, lf)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()
