"""xLSTM-1.3B: alternating mLSTM / sLSTM blocks (arXiv:2405.04517).

Structure xLSTM[7:1]: every `cfg.xlstm_slstm_every`-th block is an sLSTM,
the rest are mLSTM. Layers are organised into super-blocks of
(every-1 mLSTM + 1 sLSTM) so the whole stack is two nested scans over
homogeneous stacked params.

mLSTM (matrix memory): C_t = f_t C_{t-1} + i_t k_t v_t^T, n_t = f_t n_{t-1}
+ i_t k_t, h = (C_t q_t) / max(|n_t . q_t|, 1). The training path reuses the
chunkwise SSD core (per-head B=k, C=q, decay=log sigmoid(f)) with v augmented
by a ones-column so the normalizer n rides along as an extra value channel.
The decode path implements the exact stabilized recurrence (running max m_t);
the two agree in exact arithmetic (tested to f32 tolerance). The exponential
input gate is clamped (log i <= EXP_CLAMP) identically in both paths.

sLSTM (scalar memory): recurrent gates with block-diagonal per-head R
matrices, stabilized exponential gating, followed by the paper's
post-up-projection GeGLU FFN (factor 4/3). Sequential lax.scan over time —
inherently recurrent (this is the arch family whose O(1) state makes the
long_500k cell feasible).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.axllm_linear import linear
from repro.dist.sharding import shard
from repro.models import layers as L
from repro.models import ssm as S

EXP_CLAMP = 10.0


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg):
    di = 2 * cfg.d_model                 # up-projection factor 2
    nh = cfg.n_heads
    hd = di // nh
    return di, nh, hd


def init_mlstm(rng, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di, nh, hd = _mlstm_dims(cfg)
    ks = jax.random.split(rng, 7)
    return {
        "ln": L.init_norm(cfg, d),
        "up": L.init_linear(ks[0], d, 2 * di, dtype),        # [x_in, z-gate]
        "conv_w": L.truncated_normal(ks[1], (cfg.ssm_conv, di), 0.2, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": L.init_linear(ks[2], di, di, dtype),
        "wk": L.init_linear(ks[3], di, di, dtype),
        "wif": L.init_linear(ks[4], di, 2 * nh, jnp.float32),
        "if_bias": jnp.concatenate([jnp.zeros((nh,)),
                                    jnp.linspace(3.0, 6.0, nh)]).astype(
                                        jnp.float32),
        "norm_h": L.init_norm(cfg, di),
        "down": L.init_linear(ks[5], di, d, dtype),
    }


def _mlstm_gates(p, xc, nh):
    raw = linear(xc.astype(jnp.float32), p["wif"],
                 out_dtype=jnp.float32) + p["if_bias"]
    log_i = jnp.minimum(raw[..., :nh], EXP_CLAMP)     # exponential input gate
    log_f = jax.nn.log_sigmoid(raw[..., nh:])          # sigmoid forget gate
    return log_i, log_f


def _mlstm_qk(p, xc):
    """q/k projections over the conv stream; fused wqk when deployed so."""
    if "wqk" in p:
        return jnp.split(linear(xc, p["wqk"]), 2, axis=-1)
    return linear(xc, p["wq"]), linear(xc, p["wk"])


def mlstm_fwd(p, x, cfg, state=None, *, return_state: bool = False):
    """x: [B, S, d] -> [B, S, d] (chunkwise-parallel training form)."""
    b, s, d = x.shape
    di, nh, hd = _mlstm_dims(cfg)
    xn = L.norm_fwd(p["ln"], x, cfg.norm_eps)
    xin, z = jnp.split(linear(xn, p["up"]), 2, axis=-1)
    conv_prev = state[0] if state is not None else None
    xc, new_conv = S._causal_conv(xin, p["conv_w"], p["conv_b"], conv_prev)
    q, k = _mlstm_qk(p, xc)
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, s, nh, hd)
    v = xin.reshape(b, s, nh, hd)
    log_i, log_f = _mlstm_gates(p, xc, nh)             # [B,S,H]

    # v augmented with ones so the normalizer n = sum decayed i*k rides along
    vf = v.astype(jnp.float32) * jnp.exp(log_i)[..., None]
    v_aug = jnp.concatenate([vf, jnp.exp(log_i)[..., None]], axis=-1)
    kf = k.astype(jnp.float32) / (hd ** 0.5)
    qf = q.astype(jnp.float32)
    y_aug, h_t = S.ssd_chunked(v_aug, log_f, kf, qf)   # [B,S,H,hd+1]
    y, nq = y_aug[..., :hd], y_aug[..., hd:]
    y = y / jnp.maximum(jnp.abs(nq), 1.0)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = L.norm_fwd(p["norm_h"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = linear(y, p["down"])
    out = shard(out, "batch", "seq")
    if return_state:
        # SSD state is [B,H,P=v,N=k]; the step path keeps [B,H,k,v] with the
        # stabilizer m (relative, so m=0 is valid for a fresh conversion)
        c_aug = h_t.swapaxes(-1, -2)
        return out, (new_conv, c_aug, jnp.zeros((b, nh), jnp.float32))
    return out


def mlstm_step(p, x, cfg, state):
    """Exact stabilized recurrence for one token. state = (conv, C_aug, m)
    with C_aug: [B, H, hd, hd+1] holding [C | n] columns, scaled by
    exp(-m)."""
    b, _, d = x.shape
    di, nh, hd = _mlstm_dims(cfg)
    conv_prev, c_aug, m = state
    xn = L.norm_fwd(p["ln"], x, cfg.norm_eps)
    xin, z = jnp.split(linear(xn, p["up"]), 2, axis=-1)
    xc, new_conv = S._causal_conv(xin, p["conv_w"], p["conv_b"], conv_prev)
    q, k = _mlstm_qk(p, xc)
    q = q.reshape(b, nh, hd)
    k = k.reshape(b, nh, hd) / (hd ** 0.5)
    v = xin.reshape(b, nh, hd)
    log_i, log_f = _mlstm_gates(p, xc[:, 0], nh)       # [B,H]

    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    v_aug = jnp.concatenate(
        [v.astype(jnp.float32), jnp.ones((b, nh, 1), jnp.float32)], -1)
    outer = jnp.einsum("bhk,bhv->bhkv", k.astype(jnp.float32), v_aug)
    c_new = c_aug * f_s[..., None, None] + outer * i_s[..., None, None]
    y_aug = jnp.einsum("bhkv,bhk->bhv", c_new, q.astype(jnp.float32))
    y, nq = y_aug[..., :hd], y_aug[..., hd]
    # stabilized normalizer: states carry exp(-m), so the floor is exp(-m)
    y = y / jnp.maximum(jnp.abs(nq), jnp.exp(-m_new))[..., None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = L.norm_fwd(p["norm_h"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = linear(y, p["down"])
    return out, (new_conv, c_new, m_new)


def init_mlstm_state(cfg, batch: int, dtype=jnp.float32):
    di, nh, hd = _mlstm_dims(cfg)
    conv = jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype)
    c_aug = jnp.zeros((batch, nh, hd, hd + 1), jnp.float32)
    m = jnp.zeros((batch, nh), jnp.float32)
    return conv, c_aug, m


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def init_slstm(rng, cfg, dtype=jnp.float32):
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    dff = ((4 * d // 3) + 63) // 64 * 64     # paper: GeGLU factor 4/3
    ks = jax.random.split(rng, 5)
    return {
        "ln": L.init_norm(cfg, d),
        "wx": L.init_linear(ks[0], d, 4 * d, dtype),         # i,f,z,o gates
        "r": L.truncated_normal(ks[1], (nh, hd, 4 * hd),
                                1.0 / jnp.sqrt(hd).astype(jnp.float32),
                                dtype),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((d,)), jnp.ones((d,)),                # i, f
             jnp.zeros((2 * d,))]).astype(jnp.float32),      # z, o
        "norm_h": L.init_norm(cfg, d),
        "ln_ff": L.init_norm(cfg, d),
        "ff_gate": L.init_linear(ks[2], d, dff, dtype),
        "ff_up": L.init_linear(ks[4], d, dff, dtype),
        "ff_down": L.init_linear(ks[3], dff, d, dtype),
    }


def _slstm_cell(p, gx_t, state, nh, hd):
    """One sLSTM step. gx_t: [B, 4d] pre-activations from the input path."""
    c, n, h, m = state                                  # [B, d]x3, [B, d]
    b = gx_t.shape[0]
    d = nh * hd
    hh = h.reshape(b, nh, hd)
    gr = jnp.einsum("bhk,hkj->bhj", hh, p["r"].astype(h.dtype))  # [B,H,4hd]
    gr = gr.reshape(b, nh, 4, hd).transpose(0, 2, 1, 3).reshape(b, 4 * d)
    g = gx_t + gr + p["gate_bias"]
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    log_i = jnp.minimum(gi, EXP_CLAMP)
    log_f = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(gz)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_fwd(p, x, cfg, state=None, *, return_state: bool = False):
    b, s, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    xn = L.norm_fwd(p["ln"], x, cfg.norm_eps)
    gx = linear(xn.astype(jnp.float32), p["wx"], out_dtype=jnp.float32)
    if state is None:
        state = init_slstm_state(cfg, b)

    def step(carry, gx_t):
        return _slstm_cell(p, gx_t, carry, nh, hd)

    new_state, hs = jax.lax.scan(step, state, gx.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)            # [B, S, d]
    y = L.norm_fwd(p["norm_h"], y, cfg.norm_eps)
    x = x + y
    hn = L.norm_fwd(p["ln_ff"], x, cfg.norm_eps)
    if "ff_gateup" in p:   # fused GeGLU: one [d, 2·dff] activation pass
        fg, fu = jnp.split(linear(hn, p["ff_gateup"]), 2, axis=-1)
        ff = jax.nn.gelu(fg) * fu
    else:
        ff = jax.nn.gelu(linear(hn, p["ff_gate"])) * linear(hn, p["ff_up"])
    x = x + linear(ff, p["ff_down"])
    if return_state:
        return x, new_state
    return x


def slstm_step(p, x, cfg, state):
    out, new_state = slstm_fwd(p, x, cfg, state, return_state=True)
    return out, new_state


def init_slstm_state(cfg, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, z, jnp.zeros((batch, d), jnp.float32))


# ---------------------------------------------------------------------------
# Full model: super-block scan
# ---------------------------------------------------------------------------

def _superblock_counts(cfg) -> Tuple[int, int]:
    every = cfg.xlstm_slstm_every or (cfg.n_layers + 1)
    if cfg.xlstm_slstm_every:
        assert cfg.n_layers % every == 0, "n_layers must divide into superblocks"
        return cfg.n_layers // every, every - 1          # (n_super, m_per_super)
    return 1, cfg.n_layers


def init_params(rng, cfg):
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    n_super, m_per = _superblock_counts(cfg)
    ke, km, ks = jax.random.split(rng, 3)
    mkeys = jax.random.split(km, n_super * m_per).reshape(n_super, m_per, -1)
    skeys = jax.random.split(ks, n_super)
    mlstm = jax.vmap(jax.vmap(lambda k: init_mlstm(k, cfg, dtype)))(mkeys)
    slstm = jax.vmap(lambda k: init_slstm(k, cfg, dtype))(skeys)
    return {
        "embed": L.init_embed(ke, cfg, dtype),
        "mlstm": mlstm,                                  # [n_super, m_per, ...]
        "slstm": slstm,                                  # [n_super, ...]
        "final_norm": L.init_norm(cfg),
    }


def fuse_params(params, cfg):
    """Deploy-time fused-projection rewrite (cfg.fuse_qkv): mLSTM q/k run
    over the same conv stream and fuse into wqk; the sLSTM GeGLU gate/up
    fuse into ff_gateup. (The mLSTM up-projection is already fused at init:
    one matmul emits x_in and the z-gate.) Apply AFTER deploy_quantize so
    QTensors concat exactly."""
    from repro.core.axllm_linear import concat_weights
    mlstm = dict(params["mlstm"])
    if "wqk" not in mlstm and "wq" in mlstm:    # idempotent, like wqkv
        mlstm["wqk"] = concat_weights([mlstm.pop("wq"), mlstm.pop("wk")])
    slstm = dict(params["slstm"])
    if "ff_gateup" not in slstm and "ff_gate" in slstm:
        slstm["ff_gateup"] = concat_weights(
            [slstm.pop("ff_gate"), slstm.pop("ff_up")])
    return {**params, "mlstm": mlstm, "slstm": slstm}


def forward(params, tokens, cfg, impl: str = "auto"):
    x = L.embed_fwd(params["embed"], tokens)
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)

    def m_body(carry, mp):
        return carry + mlstm_fwd(mp, carry, cfg), None

    def super_body(carry, inp):
        mp, sp = inp
        body = jax.checkpoint(m_body, prevent_cse=False) if cfg.remat \
            else m_body
        carry, _ = L.maybe_scan(body, carry, mp, cfg.scan_layers)
        carry = slstm_fwd(sp, carry, cfg)
        return carry, None

    x, _ = L.maybe_scan(super_body, x,
                        (params["mlstm"], params["slstm"]), cfg.scan_layers)
    x = L.norm_fwd(params["final_norm"], x, cfg.norm_eps)
    logits = L.head_fwd(params["embed"], x, cfg, impl=impl)
    return shard(logits, "batch", "seq", "vocab")


def loss_fn(params, batch, cfg, impl: str = "auto"):
    logits = forward(params, batch["tokens"], cfg, impl=impl)
    return L.cross_entropy(logits, batch["targets"], cfg.vocab_size)


def init_cache(cfg, batch: int, max_len: int = 0, dtype=None):
    """Recurrent state only — O(1) in sequence length (the long_500k story)."""
    n_super, m_per = _superblock_counts(cfg)
    dtype = dtype or (jnp.bfloat16 if cfg.dtype == "bfloat16"
                      else jnp.float32)

    def stack(tree, n):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape), tree)

    m_state = stack(stack(init_mlstm_state(cfg, batch, dtype), m_per),
                    n_super)
    s_state = stack(init_slstm_state(cfg, batch), n_super)
    return {"mlstm": m_state, "slstm": s_state,
            "pos": jnp.zeros((batch,), jnp.int32)}


def cache_spec(cfg):
    """Batch axis per cache leaf. mLSTM states are stacked
    [n_super, m_per, B, ...] (batch axis 2), sLSTM [n_super, B, ...]
    (axis 1), pos [B] (axis 0)."""
    return {
        "mlstm": (2, 2, 2),        # (conv, c_aug, m)
        "slstm": (1, 1, 1, 1),     # (c, n, h, m)
        "pos": 0,
    }


def decode_step(params, token, cfg, cache, impl: str = "auto"):
    x = L.embed_fwd(params["embed"], token[:, None])
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)

    def m_body(carry, inp):
        mp, ms = inp
        out, new_ms = mlstm_step(mp, carry, cfg, ms)
        return carry + out, new_ms

    def super_body(carry, inp):
        mp, sp, ms, ss = inp
        carry, new_ms = L.maybe_scan(m_body, carry, (mp, ms),
                                     cfg.scan_layers)
        carry, new_ss = slstm_step(sp, carry, cfg, ss)
        return carry, (new_ms, new_ss)

    x, (new_m, new_s) = L.maybe_scan(
        super_body, x,
        (params["mlstm"], params["slstm"], cache["mlstm"], cache["slstm"]),
        cfg.scan_layers)
    x = L.norm_fwd(params["final_norm"], x, cfg.norm_eps)
    logits = L.head_fwd(params["embed"], x, cfg, impl=impl)[:, 0]
    return logits, {"mlstm": new_m, "slstm": new_s, "pos": cache["pos"] + 1}


def prefill(params, tokens, cfg, cache, impl: str = "auto", lengths=None):
    """Parallel prefill: chunkwise mLSTM + sequential sLSTM over the prompt,
    emitting every block's recurrent state for subsequent decode.

    Recurrent state folds every input position in, so right-padding would
    corrupt it — ragged (`lengths`) prefill is rejected; the serve engine
    splits mixed-length waves into equal-length sub-batches instead."""
    if lengths is not None:
        raise NotImplementedError(
            "xlstm prefill is recurrent: padded positions would enter the "
            "state. Batch equal-length prompts only (ragged_prefill=False).")
    b, s = tokens.shape
    x = L.embed_fwd(params["embed"], tokens)
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)

    def m_body(carry, mp):
        out, st = mlstm_fwd(mp, carry, cfg, return_state=True)
        return carry + out, st

    def super_body(carry, inp):
        mp, sp = inp
        carry, m_states = L.maybe_scan(m_body, carry, mp, cfg.scan_layers)
        carry, s_state = slstm_fwd(sp, carry, cfg, return_state=True)
        return carry, (m_states, s_state)

    x, (m_states, s_states) = L.maybe_scan(
        super_body, x, (params["mlstm"], params["slstm"]), cfg.scan_layers)
    x = L.norm_fwd(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = L.head_fwd(params["embed"], x, cfg, impl=impl)[:, 0]
    return logits, {"mlstm": m_states, "slstm": s_states,
                    "pos": jnp.full((b,), s, jnp.int32)}
