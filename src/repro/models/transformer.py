"""Unified decoder-only transformer LM (dense / GQA / MoE / VLM-backbone).

Covers chameleon-34b (qk-norm, early-fusion vocab), arctic-480b and
qwen2-moe-a2.7b (MoE FFN variants), internlm2-20b, qwen2-72b (QKV bias),
granite-3-8b, glm4-9b. Layers are stacked and scanned (HLO size O(1) in
depth; remat per layer); prefill/decode thread the stacked KV cache through
the same scan.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M


def _param_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_layer(rng, cfg, dtype):
    k1, k2 = jax.random.split(rng)
    p = {
        "ln1": L.init_norm(cfg),
        "attn": A.init_attention(k1, cfg, dtype),
        "ln2": L.init_norm(cfg),
    }
    if cfg.family == "moe":
        p["ffn"] = M.init_moe(k2, cfg, dtype)
    else:
        p["ffn"] = L.init_mlp(k2, cfg, dtype=dtype)
    return p


def init_params(rng, cfg):
    dtype = _param_dtype(cfg)
    ke, kl = jax.random.split(rng)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys)
    return {
        "embed": L.init_embed(ke, cfg, dtype),
        "layers": layers,
        "final_norm": L.init_norm(cfg),
    }


def fuse_params(params, cfg):
    """Deploy-time fused-projection rewrite (cfg.fuse_qkv): wq/wk/wv ->
    wqkv and gate/up -> gate_up across the stacked layers. MoE routed
    experts keep their einsum layout (only the shared/dense mlp_fwd paths
    fuse); apply AFTER deploy_quantize so QTensors concat exactly."""
    layers = dict(params["layers"])
    layers["attn"] = A.fuse_attention_params(layers["attn"])
    ffn = dict(layers["ffn"])
    if cfg.family == "moe":
        for key in ("shared", "dense"):
            if key in ffn:
                ffn[key] = L.fuse_mlp_params(ffn[key])
    else:
        ffn = L.fuse_mlp_params(ffn)
    layers["ffn"] = ffn
    return {**params, "layers": layers}


def _ffn_fwd(p, x, cfg, impl):
    if cfg.family == "moe":
        return M.moe_ffn(p, x, cfg, impl=impl)
    return L.mlp_fwd(p, x, cfg, impl=impl)


def _layer_fwd(lp, x, cfg, impl):
    h = L.norm_fwd(lp["ln1"], x, cfg.norm_eps)
    x = x + A.attention_fwd(lp["attn"], h, cfg, impl=impl)
    x = shard(x, "batch", "seq")
    h = L.norm_fwd(lp["ln2"], x, cfg.norm_eps)
    x = x + _ffn_fwd(lp["ffn"], h, cfg, impl)
    return shard(x, "batch", "seq")


def forward(params, tokens, cfg, impl: str = "auto"):
    """tokens: [B, S] -> logits [B, S, V_padded]."""
    x = L.embed_fwd(params["embed"], tokens).astype(_param_dtype(cfg))
    x = shard(x, "batch", "seq")

    def body(carry, lp):
        return _layer_fwd(lp, carry, cfg, impl), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["layers"])
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, _ = body(x, lp)
    x = L.norm_fwd(params["final_norm"], x, cfg.norm_eps)
    logits = L.head_fwd(params["embed"], x, cfg, impl=impl)
    return shard(logits, "batch", "seq", "vocab")


def loss_fn(params, batch, cfg, impl: str = "auto"):
    logits = forward(params, batch["tokens"], cfg, impl=impl)
    return L.cross_entropy(logits, batch["targets"], cfg.vocab_size)


# ---------------------------------------------------------------------------
# Serving: prefill + decode through the same layer scan
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype=None):
    dtype = dtype or (jnp.bfloat16 if cfg.dtype == "bfloat16"
                      else jnp.float32)
    return A.init_cache(cfg, batch, max_len, dtype)


def cache_spec(cfg):
    """Batch axis per cache leaf (see attention.cache_spec)."""
    return A.cache_spec(cfg)


def init_paged_cache(cfg, batch: int, n_blocks: int, block_size: int,
                     max_blocks: int, dtype=None):
    """Block-paged KV pool + per-slot tables (attention.init_paged_cache)."""
    dtype = dtype or (jnp.bfloat16 if cfg.dtype == "bfloat16"
                      else jnp.float32)
    return A.init_paged_cache(cfg, batch, n_blocks, block_size, max_blocks,
                              dtype)


def paged_cache_spec(cfg):
    """Block/slot axis per paged-cache leaf (attention.paged_cache_spec)."""
    return A.paged_cache_spec(cfg)


# host-side per-slot leaves excluded from the layer scan's xs
_SLOT_LEAVES = ("pos", "block_tables")


def _cache_xs(cache):
    return {k: v for k, v in cache.items() if k not in _SLOT_LEAVES}


def prefill(params, tokens, cfg, cache, impl: str = "auto", lengths=None,
            adapters=None, adapter_idx=None, lora_scaling: float = 1.0,
            prefix=None):
    """tokens: [B, S] -> (last-position logits [B, V], filled cache).

    With `lengths` ([B] int32, ragged right-padded prompts), logits are
    gathered at each row's final real position and the cache cursor is set
    to `lengths`. Causal masking keeps real tokens from attending to the
    padding (pads sit *after* them); pad-position KV entries are garbage
    but live beyond the per-row cursor, so decode's length mask never
    reads them and subsequent writes overwrite them in place.

    ``adapters`` ({target: {"lora_a": [n_layers, max_loras, n_in, r],
    "lora_b": [n_layers, max_loras, r, n_out]}}) and ``adapter_idx``
    ([B] int32, -1 = base-only) enable the multi-LoRA delta pipeline:
    the stacked per-layer adapter slices scan together with the layer
    params, and each attention block adds its gathered per-row delta.

    ``prefix`` (requires ``lengths``) makes this a *suffix-only* prefill
    against an already-cached prompt head: ``{"k"/"v":
    [L, B, P, Hk, hd]`` (+ ``k_scale``/``v_scale`` when cfg.quant_kv),
    ``"len": [B]}``. ``tokens``/``lengths`` then describe only the
    un-cached tail; every row is position-offset by its prefix length and
    the cursor lands at ``prefix_len + lengths``. The returned cache
    holds suffix KV only — the prefix stays wherever it was cached.
    """
    b, s = tokens.shape
    x = L.embed_fwd(params["embed"], tokens).astype(_param_dtype(cfg))
    prefix_len = None
    prefix_kv = None
    if prefix is not None:
        if lengths is None:
            raise ValueError("prefix-reuse prefill needs per-row lengths")
        prefix_len = jnp.asarray(prefix["len"], jnp.int32)
        prefix_kv = {k: v for k, v in prefix.items() if k != "len"}

    def body(carry, inp):
        inp = list(inp)
        lp, lc = inp[0], inp[1]
        pf = inp[2] if prefix_kv is not None else None
        ad = inp[-1] if adapters is not None else None
        h = L.norm_fwd(lp["ln1"], carry, cfg.norm_eps)
        att, new_lc = A.attention_prefill(
            lp["attn"], h, cfg, lc, impl=impl, adapters=ad,
            adapter_idx=adapter_idx, lora_scaling=lora_scaling,
            prefix=pf, prefix_len=prefix_len)
        x1 = carry + att
        h2 = L.norm_fwd(lp["ln2"], x1, cfg.norm_eps)
        x2 = x1 + _ffn_fwd(lp["ffn"], h2, cfg, impl)
        return shard(x2, "batch", "seq"), new_lc

    xs = (params["layers"], _cache_xs(cache))
    if prefix_kv is not None:
        xs = xs + (prefix_kv,)
    if adapters is not None:
        xs = xs + (adapters,)
    x, new_kv = L.maybe_scan(body, x, xs, cfg.scan_layers)
    if lengths is None:
        x = x[:, -1:]
        pos = jnp.full((b,), s, jnp.int32)
    else:
        pos = jnp.asarray(lengths, jnp.int32)
        x = x[jnp.arange(b), pos - 1][:, None]
    x = L.norm_fwd(params["final_norm"], x, cfg.norm_eps)
    logits = L.head_fwd(params["embed"], x, cfg, impl=impl)[:, 0]
    new_cache = dict(new_kv)
    new_cache["pos"] = pos if prefix_len is None else pos + prefix_len
    return logits, new_cache


def decode_step(params, token, cfg, cache, impl: str = "auto",
                adapters=None, adapter_idx=None, lora_scaling: float = 1.0):
    """token: [B] int32 -> (logits [B, V], cache advanced by one).

    ``adapters``/``adapter_idx``/``lora_scaling`` as in :func:`prefill` —
    the same stacked-adapter slices scan with the layers so a mixed batch
    of base and N distinct adapters decodes in one dispatch.

    A cache carrying ``block_tables`` (built by :func:`init_paged_cache`)
    routes every layer through the block-paged decode path: KV writes land
    at ``(table[pos // bs], pos % bs)`` in the shared pool and attention
    gathers through the table (``ops.decode_attention(block_tables=)``).
    """
    pos = cache["pos"]
    block_tables = cache.get("block_tables")
    x = L.embed_fwd(params["embed"], token[:, None]).astype(_param_dtype(cfg))

    def body(carry, inp):
        if adapters is None:
            (lp, lc), ad = inp, None
        else:
            lp, lc, ad = inp
        h = L.norm_fwd(lp["ln1"], carry, cfg.norm_eps)
        if block_tables is not None:
            att, new_lc = A.attention_decode_paged(
                lp["attn"], h, cfg, lc, pos, block_tables, impl=impl,
                adapters=ad, adapter_idx=adapter_idx,
                lora_scaling=lora_scaling)
        else:
            att, new_lc = A.attention_decode(
                lp["attn"], h, cfg, lc, pos, impl=impl, adapters=ad,
                adapter_idx=adapter_idx, lora_scaling=lora_scaling)
        x1 = carry + att
        h2 = L.norm_fwd(lp["ln2"], x1, cfg.norm_eps)
        x2 = x1 + _ffn_fwd(lp["ffn"], h2, cfg, impl)
        return x2, new_lc

    xs = (params["layers"], _cache_xs(cache))
    if adapters is not None:
        xs = xs + (adapters,)
    x, new_kv = L.maybe_scan(body, x, xs, cfg.scan_layers)
    x = L.norm_fwd(params["final_norm"], x, cfg.norm_eps)
    logits = L.head_fwd(params["embed"], x, cfg, impl=impl)[:, 0]
    new_cache = dict(new_kv)
    new_cache["pos"] = pos + 1
    if block_tables is not None:
        new_cache["block_tables"] = block_tables
    return logits, new_cache
