"""GQA attention block: full-sequence (train/prefill) and cached decode.

KV cache layout: {"k"/"v": [B, S_max, Hk, hd]} (+ "k_scale"/"v_scale"
[B, S_max, Hk, 1] when cfg.quant_kv — the int8-KV beyond-paper lever), plus
"pos": [B] write cursor. Stacked per-layer caches carry a leading L dim and
are scanned together with the stacked layer params.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.axllm_linear import concat_weights, linear, \
    lora_delta_batched
from repro.dist.sharding import shard
from repro.kernels import ops
from repro.models import layers as L


def init_attention(rng, cfg, dtype=jnp.float32):
    d, h, hk, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim)
    ks = jax.random.split(rng, 6)
    p = {
        "wq": L.init_linear(ks[0], d, h * hd, dtype),
        "wk": L.init_linear(ks[1], d, hk * hd, dtype),
        "wv": L.init_linear(ks[2], d, hk * hd, dtype),
        "wo": L.init_linear(ks[3], h * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["wq_bias"] = jnp.zeros((h * hd,), dtype)
        p["wk_bias"] = jnp.zeros((hk * hd,), dtype)
        p["wv_bias"] = jnp.zeros((hk * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((hd,), jnp.float32)}
    return p


def fuse_attention_params(p):
    """Replace wq/wk/wv with one fused wqkv (``[d, (H+2Hk)·hd]``): one
    activation pass and one codebook residency per attention block instead
    of three (deploy-time transform; works on dense or deploy-quantized
    params, stacked-layer leading dims included). The unfused layout keeps
    working — `_project_qkv` dispatches on key presence."""
    if "wqkv" in p or "wq" not in p:
        return p
    p2 = {k: v for k, v in p.items()
          if k not in ("wq", "wk", "wv", "wq_bias", "wk_bias", "wv_bias")}
    p2["wqkv"] = concat_weights([p["wq"], p["wk"], p["wv"]])
    if "wq_bias" in p:
        p2["wqkv_bias"] = jnp.concatenate(
            [p["wq_bias"], p["wk_bias"], p["wv_bias"]], axis=-1)
    return p2


def _project_qkv(p, x, cfg, impl, adapters=None, adapter_idx=None,
                 lora_scaling: float = 1.0):
    """Project x -> (q, k, v) heads; fused wqkv or separate wq/wk/wv.

    ``adapters``/``adapter_idx`` enable the serve-path LoRA pipeline: the
    base matmul (dense or quantized, fused included) is untouched and each
    targeted projection adds its gathered per-row low-rank delta. On the
    fused path the wqkv output is split into its q/k/v column blocks
    first and each block receives its target's delta — elementwise
    identical to scattering a concatenated [dq ‖ dk ‖ dv] delta into the
    fused output's columns, so fused and unfused LoRA decode stay
    token-for-token equal (tests/test_adapters.py).
    """
    b, s, d = x.shape
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    if "wqkv" in p:  # fused path: one [d, (H+2Hk)·hd] AxLLM matmul
        qkv = linear(x, p["wqkv"], impl=impl)
        if "wqkv_bias" in p:
            qkv = qkv + p["wqkv_bias"].astype(qkv.dtype)
        q, k, v = jnp.split(qkv, (h * hd, (h + hk) * hd), axis=-1)
    else:
        q = linear(x, p["wq"], impl=impl)
        k = linear(x, p["wk"], impl=impl)
        v = linear(x, p["wv"], impl=impl)
        if cfg.qkv_bias:
            q = q + p["wq_bias"].astype(q.dtype)
            k = k + p["wk_bias"].astype(k.dtype)
            v = v + p["wv_bias"].astype(v.dtype)
    if adapters is not None:
        if "wq" in adapters:
            q = q + lora_delta_batched(x, adapters["wq"], adapter_idx,
                                       lora_scaling).astype(q.dtype)
        if "wk" in adapters:
            k = k + lora_delta_batched(x, adapters["wk"], adapter_idx,
                                       lora_scaling).astype(k.dtype)
        if "wv" in adapters:
            v = v + lora_delta_batched(x, adapters["wv"], adapter_idx,
                                       lora_scaling).astype(v.dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hk, hd)
    v = v.reshape(b, s, hk, hd)
    if cfg.qk_norm:  # chameleon: per-head RMS norm on q/k
        q = L.norm_fwd(p["q_norm"], q, cfg.norm_eps)
        k = L.norm_fwd(p["k_norm"], k, cfg.norm_eps)
    return q, k, v


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
               n_layers: Optional[int] = None):
    """Stacked-over-layers KV cache (leading L dim matches layer scan)."""
    hk, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    nl = n_layers if n_layers is not None else cfg.n_layers
    kv_dtype = jnp.int8 if cfg.quant_kv else dtype
    cache = {
        "k": jnp.zeros((nl, batch, max_len, hk, hd), kv_dtype),
        "v": jnp.zeros((nl, batch, max_len, hk, hd), kv_dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if cfg.quant_kv:
        cache["k_scale"] = jnp.zeros((nl, batch, max_len, hk, 1), jnp.float32)
        cache["v_scale"] = jnp.zeros((nl, batch, max_len, hk, 1), jnp.float32)
    return cache


def cache_spec(cfg):
    """Batch axis per cache leaf — the serve-engine slot-insertion contract.

    KV leaves are stacked over layers (leading L dim), so batch sits at
    axis 1; the per-row write cursor ``pos`` is batch-leading (axis 0).
    Must mirror :func:`init_cache` leaf-for-leaf (tested against shape
    inference in tests/test_serve.py).
    """
    spec = {"k": 1, "v": 1, "pos": 0}
    if cfg.quant_kv:
        spec["k_scale"] = 1
        spec["v_scale"] = 1
    return spec


def init_paged_cache(cfg, batch: int, n_blocks: int, block_size: int,
                     max_blocks: int, dtype=jnp.bfloat16,
                     n_layers: Optional[int] = None):
    """Block-paged KV cache: one shared pool + per-slot block tables.

    KV lives in ``n_blocks`` fixed-size blocks of ``block_size`` tokens in
    a pool shared by every slot; each slot's logical sequence is the
    concatenation of the blocks its row of ``block_tables`` names
    (position p -> block ``table[p // block]``, offset ``p % block``).
    Block 0 is the trash block: table entries past a row's allocation
    point there, and out-of-range writes are routed to it — nothing ever
    reads it (the length mask stops first). Ownership (free list,
    refcounts, prefix index) is host-side state in
    :class:`repro.serve.paged_cache.PagedKVCache`.
    """
    hk, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    nl = n_layers if n_layers is not None else cfg.n_layers
    kv_dtype = jnp.int8 if cfg.quant_kv else dtype
    cache = {
        "k": jnp.zeros((nl, n_blocks, block_size, hk, hd), kv_dtype),
        "v": jnp.zeros((nl, n_blocks, block_size, hk, hd), kv_dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
        "block_tables": jnp.zeros((batch, max_blocks), jnp.int32),
    }
    if cfg.quant_kv:
        cache["k_scale"] = jnp.zeros((nl, n_blocks, block_size, hk, 1),
                                     jnp.float32)
        cache["v_scale"] = jnp.zeros((nl, n_blocks, block_size, hk, 1),
                                     jnp.float32)
    return cache


def paged_cache_spec(cfg):
    """Paged variant of :func:`cache_spec`: pool leaves name their *block*
    axis (the allocation unit — there is no per-slot batch axis in the
    pool), while ``pos`` / ``block_tables`` stay slot-leading (axis 0).
    Mirrors :func:`init_paged_cache` leaf-for-leaf.
    """
    spec = {"k": 1, "v": 1, "pos": 0, "block_tables": 0}
    if cfg.quant_kv:
        spec["k_scale"] = 1
        spec["v_scale"] = 1
    return spec


def _quantize_kv(x):
    """Per-(pos, head) int8 quantization of new KV entries."""
    s = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-8) / 127.0
    codes = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return codes, s.astype(jnp.float32)


def attention_fwd(p, x, cfg, *, positions=None, impl: str = "auto"):
    """Full-sequence causal attention (train / prefill)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(p, x, cfg, impl)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads")
    k = shard(k, "batch", "seq", "kv_heads")
    out = ops.flash_attention(q, k, v, causal=True, impl=impl)
    out = out.reshape(b, s, -1)
    return linear(out, p["wo"], impl=impl)


def _wo_project(p, out, impl, adapters, adapter_idx, lora_scaling):
    """Output projection with an optional gathered LoRA delta on wo."""
    y = linear(out, p["wo"], impl=impl)
    if adapters is not None and "wo" in adapters:
        y = y + lora_delta_batched(out, adapters["wo"], adapter_idx,
                                   lora_scaling).astype(y.dtype)
    return y


def attention_prefill(p, x, cfg, layer_cache, *, impl: str = "auto",
                      adapters=None, adapter_idx=None,
                      lora_scaling: float = 1.0, prefix=None,
                      prefix_len=None):
    """Full-seq attention that also fills this layer's cache slice.

    layer_cache: {"k": [B, S_max, Hk, hd], ...} (no leading L — the scan
    slices it). Returns (out, updated_layer_cache).

    ``adapters``: this layer's stacked-adapter slice ``{target:
    {"lora_a": [max_loras, n_in, r], "lora_b": [max_loras, r, n_out]}}``;
    ``adapter_idx``: [B] int32 per-row adapter selection (-1 = base).

    ``prefix``/``prefix_len``: suffix-only prefill against a cached prompt
    head (the prefix-reuse path). ``prefix`` is this layer's gathered
    prefix KV ``{"k"/"v": [B, P, Hk, hd]}`` (int8 codes + ``k_scale``/
    ``v_scale`` [B, P, Hk, 1] when cfg.quant_kv), right-padded with
    per-row valid lengths ``prefix_len`` [B]. Rows are position-offset by
    their prefix length (RoPE and masking), queries attend the valid
    prefix plus the causal suffix, and only the suffix KV is written to
    ``layer_cache`` — the prefix already lives in the shared pool.
    """
    b, s, _ = x.shape
    if prefix is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    else:
        positions = prefix_len[:, None] + jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, x, cfg, impl, adapters, adapter_idx,
                           lora_scaling)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    if prefix is None:
        out = ops.flash_attention(q, k, v, causal=True, impl=impl)
    else:
        kp, vp = prefix["k"], prefix["v"]
        if cfg.quant_kv:      # pool holds int8 codes + per-position scales
            kp = kp.astype(jnp.float32) * prefix["k_scale"]
            vp = vp.astype(jnp.float32) * prefix["v_scale"]
        out = ops.prefix_attention(q, kp, vp, prefix_len, k, v, impl=impl)
    out = out.reshape(b, s, -1)
    new_cache = dict(layer_cache)
    if cfg.quant_kv:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            layer_cache["k"], kq, 0, axis=1)
        new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            layer_cache["v"], vq, 0, axis=1)
        new_cache["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
            layer_cache["k_scale"], ks, 0, axis=1)
        new_cache["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
            layer_cache["v_scale"], vs, 0, axis=1)
    else:
        new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            layer_cache["k"], k.astype(layer_cache["k"].dtype), 0, axis=1)
        new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            layer_cache["v"], v.astype(layer_cache["v"].dtype), 0, axis=1)
    return _wo_project(p, out, impl, adapters, adapter_idx,
                       lora_scaling), new_cache


def _seq_shard_ctx(cfg, batch: int, cache_len: int):
    """If a mesh context is active and the cache's seq dim actually shards,
    return (mesh, seq_axes, batch_axes) for the fused shard_map decode."""
    from repro.dist import sharding as shd
    ctx = shd._current()
    if ctx is None:
        return None
    mesh, rules = ctx
    shape = (batch, cache_len, cfg.n_kv_heads, cfg.resolved_head_dim)
    spec = shd.resolve_spec(shape, ("batch", "cache_seq", "kv_heads", None),
                            mesh, rules)
    seq_entry = spec[1]
    if seq_entry is None:
        return None
    seq_axes = (seq_entry,) if isinstance(seq_entry, str) \
        else tuple(seq_entry)
    b_entry = spec[0]
    batch_axes = () if b_entry is None else (
        (b_entry,) if isinstance(b_entry, str) else tuple(b_entry))
    return mesh, seq_axes, batch_axes


def attention_decode_paged(p, x, cfg, layer_pool, pos, block_tables, *,
                           impl: str = "auto", adapters=None,
                           adapter_idx=None, lora_scaling: float = 1.0):
    """One-token decode through a block-paged KV pool.

    x: [B, 1, d]; pos: [B] current positions; layer_pool: this layer's
    pool slice ``{"k"/"v": [NB, bs, Hk, hd], ...}``; block_tables:
    [B, MB] int32. The new KV entry is written at
    ``(table[pos // bs], pos % bs)`` — the scheduler guarantees the
    written block is uniquely owned (copy-on-write resolves sharing
    before the chunk dispatches), and rows whose position ran past their
    table (stopped slots riding through a scan) are routed to trash
    block 0. Attention reads gather through the table in the paged
    flash-decode kernel. ``adapters``/``adapter_idx`` as in
    :func:`attention_prefill`.
    """
    b = x.shape[0]
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    bs = layer_pool["k"].shape[1]
    mb = block_tables.shape[1]
    q, k, v = _project_qkv(p, x, cfg, impl, adapters, adapter_idx,
                           lora_scaling)             # [B, 1, ...]
    q = L.rope(q, pos[:, None], cfg.rope_theta)
    k = L.rope(k, pos[:, None], cfg.rope_theta)

    bidx_row = jnp.arange(b)
    blk = pos // bs
    in_range = blk < mb
    bid = jnp.where(in_range,
                    block_tables[bidx_row, jnp.clip(blk, 0, mb - 1)], 0)
    off = jnp.where(in_range, pos % bs, 0)
    pool = dict(layer_pool)
    if cfg.quant_kv:
        kq, ksc = _quantize_kv(k)
        vq, vsc = _quantize_kv(v)
        pool["k"] = layer_pool["k"].at[bid, off].set(kq[:, 0])
        pool["v"] = layer_pool["v"].at[bid, off].set(vq[:, 0])
        pool["k_scale"] = layer_pool["k_scale"].at[bid, off].set(ksc[:, 0])
        pool["v_scale"] = layer_pool["v_scale"].at[bid, off].set(vsc[:, 0])
        out = ops.decode_attention(
            q[:, 0], pool["k"], pool["v"], pos + 1,
            k_scale=pool["k_scale"], v_scale=pool["v_scale"],
            block_tables=block_tables, impl=impl)
    else:
        pool["k"] = layer_pool["k"].at[bid, off].set(
            k[:, 0].astype(layer_pool["k"].dtype))
        pool["v"] = layer_pool["v"].at[bid, off].set(
            v[:, 0].astype(layer_pool["v"].dtype))
        out = ops.decode_attention(q[:, 0], pool["k"], pool["v"], pos + 1,
                                   block_tables=block_tables, impl=impl)
    out = out.reshape(b, 1, h * hd)
    return _wo_project(p, out, impl, adapters, adapter_idx,
                       lora_scaling), pool


def attention_decode(p, x, cfg, layer_cache, pos, *, impl: str = "auto",
                     adapters=None, adapter_idx=None,
                     lora_scaling: float = 1.0):
    """One-token decode. x: [B, 1, d]; pos: [B] current positions.

    ``adapters``/``adapter_idx`` as in :func:`attention_prefill` — the
    LoRA delta pipeline rides through the same cached-decode step.
    """
    b = x.shape[0]
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(p, x, cfg, impl, adapters, adapter_idx,
                           lora_scaling)             # [B, 1, ...]
    q = L.rope(q, pos[:, None], cfg.rope_theta)
    k = L.rope(k, pos[:, None], cfg.rope_theta)

    ctx = _seq_shard_ctx(cfg, b, layer_cache["k"].shape[1])
    if ctx is not None:
        # seq-sharded cache: fused local update + flash combine (avoids the
        # GSPMD cache all-gather — §Perf decode lever)
        from repro.kernels import sharded_decode as SD
        mesh, seq_axes, batch_axes = ctx
        cache = dict(layer_cache)
        if cfg.quant_kv:
            kq, ksc = _quantize_kv(k)
            vq, vsc = _quantize_kv(v)
            out, cache["k"], cache["v"], cache["k_scale"], cache["v_scale"] \
                = SD.decode_attention_seqsharded(
                    q[:, 0], layer_cache["k"], layer_cache["v"],
                    kq[:, 0], vq[:, 0], pos, pos + 1, mesh, seq_axes,
                    batch_axes, k_scale=layer_cache["k_scale"],
                    v_scale=layer_cache["v_scale"],
                    new_k_scale=ksc[:, 0], new_v_scale=vsc[:, 0])
        else:
            out, cache["k"], cache["v"] = SD.decode_attention_seqsharded(
                q[:, 0], layer_cache["k"], layer_cache["v"],
                k[:, 0], v[:, 0], pos, pos + 1, mesh, seq_axes, batch_axes)
        out = out.reshape(b, 1, h * hd)
        return _wo_project(p, out, impl, adapters, adapter_idx,
                           lora_scaling), cache

    cache = dict(layer_cache)
    bidx = jnp.arange(b)
    if cfg.quant_kv:
        kq, ksc = _quantize_kv(k)
        vq, vsc = _quantize_kv(v)
        cache["k"] = layer_cache["k"].at[bidx, pos].set(kq[:, 0])
        cache["v"] = layer_cache["v"].at[bidx, pos].set(vq[:, 0])
        cache["k_scale"] = layer_cache["k_scale"].at[bidx, pos].set(ksc[:, 0])
        cache["v_scale"] = layer_cache["v_scale"].at[bidx, pos].set(vsc[:, 0])
        out = ops.decode_attention(
            q[:, 0], cache["k"], cache["v"], pos + 1,
            k_scale=cache["k_scale"], v_scale=cache["v_scale"], impl=impl)
    else:
        cache["k"] = layer_cache["k"].at[bidx, pos].set(
            k[:, 0].astype(layer_cache["k"].dtype))
        cache["v"] = layer_cache["v"].at[bidx, pos].set(
            v[:, 0].astype(layer_cache["v"].dtype))
        out = ops.decode_attention(q[:, 0], cache["k"], cache["v"], pos + 1,
                                   impl=impl)
    out = out.reshape(b, 1, h * hd)
    return _wo_project(p, out, impl, adapters, adapter_idx,
                       lora_scaling), cache
