"""Mamba2 (SSD) mixer: chunkwise-parallel training form + recurrent decode.

The chunked SSD algorithm (Dao & Gu, 2024) is implemented with per-head B/C
tensors so the same core serves both Mamba2 (ngroups=1: B/C broadcast over
heads) and the xLSTM mLSTM cell (k/q are per-head). State recurrence:

    h_t = a_t * h_{t-1} + x_t ⊗ B_t          h: [H, P, N]
    y_t = (h_t · C_t) + D * x_raw_t

with a_t = exp(A * dt_t) ∈ (0,1), x_t pre-scaled by dt_t. Chunkwise:
intra-chunk attention-like term + inter-chunk state scan — sub-quadratic in
sequence length, which is exactly why the zamba2/xlstm cells run the
long_500k shape the pure-attention archs must skip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.axllm_linear import linear
from repro.dist.sharding import shard
from repro.models import layers as L


def _segsum(la):
    """la: [..., Q] log-decays -> [..., Q, Q] cumulative segment sums,
    M[i, j] = sum_{j < t <= i} la_t for i >= j, -inf above the diagonal."""
    q = la.shape[-1]
    cs = jnp.cumsum(la, axis=-1)
    m = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, m, -jnp.inf)


def ssd_chunked(x, log_a, b, c, chunk: int = 128):
    """Chunkwise SSD scan.

    x:     [B, S, H, P]   (dt/input-gate pre-scaled)
    log_a: [B, S, H]      log decay per step (<= 0)
    b, c:  [B, S, H, N]   per-head input/output projections
    Returns y: [B, S, H, P] and final state h: [B, H, P, N].
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // q
    xc = x.reshape(bsz, nc, q, h, p)
    lac = log_a.reshape(bsz, nc, q, h).transpose(0, 1, 3, 2)  # [B,nc,H,Q]
    bc = b.reshape(bsz, nc, q, h, n)
    cc = c.reshape(bsz, nc, q, h, n)

    # 1) intra-chunk (masked attention-like term)
    lmat = jnp.exp(_segsum(lac))                               # [B,nc,H,Q,Q]
    scores = jnp.einsum("bcqhn,bckhn->bchqk", cc, bc)
    y_diag = jnp.einsum("bchqk,bchqk,bckhp->bcqhp",
                        scores, lmat, xc)

    # 2) per-chunk end states
    acum = jnp.cumsum(lac, axis=-1)                            # [B,nc,H,Q]
    decay_to_end = jnp.exp(acum[..., -1:] - acum)              # [B,nc,H,Q]
    states = jnp.einsum("bckhn,bchk,bckhp->bchpn",
                        bc, decay_to_end, xc)                  # [B,nc,H,P,N]

    # 3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(acum[..., -1])                       # [B,nc,H]

    def scan_fn(hstate, inp):
        st, dk = inp                                           # [B,H,P,N],[B,H]
        new = hstate * dk[..., None, None] + st
        return new, hstate                                     # emit state BEFORE chunk

    h0 = jnp.zeros((bsz, h, p, n), x.dtype)
    hT, h_prev = jax.lax.scan(
        scan_fn, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                   # [B,nc,H,P,N]

    # 4) contribution of the carried-in state
    state_decay = jnp.exp(acum)                                # [B,nc,H,Q]
    y_off = jnp.einsum("bcqhn,bchpn,bchq->bcqhp", cc, h_prev, state_decay)

    y = (y_diag + y_off).reshape(bsz, nc * q, h, p)
    return y[:, :s], hT


def ssd_step(hstate, x_t, log_a_t, b_t, c_t):
    """Single recurrent step. hstate: [B,H,P,N]; x_t: [B,H,P];
    log_a_t: [B,H]; b_t, c_t: [B,H,N] -> (y_t [B,H,P], new state)."""
    a = jnp.exp(log_a_t)[..., None, None]
    new = hstate * a + jnp.einsum("bhp,bhn->bhpn", x_t, b_t)
    y = jnp.einsum("bhpn,bhn->bhp", new, c_t)
    return y, new


# ---------------------------------------------------------------------------
# Mamba2 mixer block
# ---------------------------------------------------------------------------

def init_mamba2(rng, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = di // hd
    ks = jax.random.split(rng, 4)
    # fused in_proj: [z (di), x (di), B (n), C (n), dt (nh)]
    out_dim = 2 * di + 2 * n + nh
    p = {
        "ln": L.init_norm(cfg, d),
        "in_proj": L.init_linear(ks[0], d, out_dim, dtype),
        "conv_w": L.truncated_normal(ks[1], (cfg.ssm_conv, di + 2 * n),
                                     0.2, dtype),
        "conv_b": jnp.zeros((di + 2 * n,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (nh,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_y": L.init_norm(cfg, di),
        "out_proj": L.init_linear(ks[3], di, d, dtype),
    }
    return p


def _mamba_preact(p, x, cfg):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    zxbcdt = linear(x, p["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt, di, n, nh


def _causal_conv(xbc, w, b, prev=None):
    """Depthwise causal conv. xbc: [B, S, C]; w: [K, C]; prev: [B, K-1, C]."""
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
    full = jnp.concatenate([prev, xbc], axis=1)
    out = sum(full[:, i:i + xbc.shape[1]] * w[i].astype(xbc.dtype)
              for i in range(k))
    new_prev = full[:, -(k - 1):] if k > 1 else prev
    return jax.nn.silu(out + b.astype(xbc.dtype)), new_prev


def mamba2_fwd(p, x, cfg, conv_state=None, ssm_state=None, *,
               return_state: bool = False):
    """Full-sequence Mamba2 mixer. x: [B, S, d] -> [B, S, d]."""
    xn = L.norm_fwd(p["ln"], x, cfg.norm_eps)
    z, xbc, dt, di, n, nh = _mamba_preact(p, xn, cfg)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xi, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    bsz, s, _ = x.shape
    hd = cfg.ssm_head_dim
    xh = xi.reshape(bsz, s, nh, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"])                        # [B,S,H]
    log_a = (-jnp.exp(p["a_log"]) * dt)                         # [B,S,H] <= 0
    xs = (xh.astype(jnp.float32) * dt[..., None])
    bh = jnp.broadcast_to(bmat.astype(jnp.float32)[:, :, None, :],
                          (bsz, s, nh, n))
    ch = jnp.broadcast_to(cmat.astype(jnp.float32)[:, :, None, :],
                          (bsz, s, nh, n))
    y, h_t = ssd_chunked(xs, log_a, bh, ch)
    y = y + xh.astype(jnp.float32) * p["d_skip"][:, None]
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = L.norm_fwd(p["norm_y"], y * jax.nn.silu(z), cfg.norm_eps)
    out = linear(y, p["out_proj"])
    out = shard(out, "batch", "seq")
    if return_state:
        return out, (new_conv, h_t)
    return out


def mamba2_step(p, x, cfg, conv_state, ssm_state):
    """Single-token decode. x: [B, 1, d]; conv_state: [B, K-1, di+2n];
    ssm_state: [B, H, P, N]."""
    xn = L.norm_fwd(p["ln"], x, cfg.norm_eps)
    z, xbc, dt, di, n, nh = _mamba_preact(p, xn, cfg)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xi, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    bsz = x.shape[0]
    hd = cfg.ssm_head_dim
    xh = xi.reshape(bsz, nh, hd).astype(jnp.float32) if xi.ndim == 2 else \
        xi[:, 0].reshape(bsz, nh, hd).astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    log_a = -jnp.exp(p["a_log"]) * dt                           # [B,H]
    xs = xh * dt[..., None]
    bh = jnp.broadcast_to(bmat[:, 0].astype(jnp.float32)[:, None, :],
                          (bsz, nh, n))
    ch = jnp.broadcast_to(cmat[:, 0].astype(jnp.float32)[:, None, :],
                          (bsz, nh, n))
    y, new_ssm = ssd_step(ssm_state, xs, log_a, bh, ch)
    y = y + xh * p["d_skip"][:, None]
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = L.norm_fwd(p["norm_y"], y * jax.nn.silu(z), cfg.norm_eps)
    out = linear(y, p["out_proj"])
    return out, (new_conv, new_ssm)


def init_mamba_state(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    conv = jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n), dtype)
    ssm = jnp.zeros((batch, nh, cfg.ssm_head_dim, n), jnp.float32)
    return conv, ssm
