"""Mixture-of-Experts FFN with sort-based capacity dispatch (EP-friendly).

Production dispatch (DESIGN.md §5): tokens are argsorted by expert id,
truncated to a per-expert capacity, scattered into an [E, C, d] buffer
(expert dim sharded over "model"), run through batched expert FFNs
(einsum over the stacked expert weights), and combined back with the router
weights. FLOPs are linear in tokens (no dense one-hot dispatch einsum) and no
all_to_all is required because activations are replicated over "model"
between layers — each expert shard processes the tokens routed to its local
experts and the combine is the psum TP already performs.

Supports the two assigned MoE variants:
  arctic-480b     128 routed top-2 + dense residual FFN in parallel
  qwen2-moe-a2.7b 60 routed top-4 (padded to 64) + 4 shared experts
Dummy padded experts are masked to -inf in the router, so padding is exact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.axllm_linear import linear
from repro.core.quantization import QTensor, dequantize
from repro.dist.sharding import shard
from repro.models import layers as L


def _w(p, name, dtype):
    """Expert weight as a dense array (dequantize-on-the-fly for the AxLLM
    serve path: codes stream from HBM, dequant fuses into the einsum)."""
    w = p[name]
    if isinstance(w, QTensor):
        return dequantize(w, dtype)
    return w.astype(dtype)


def init_moe(rng, cfg, dtype=jnp.float32):
    d, dff = cfg.d_model, cfg.d_ff
    e = cfg.padded_experts
    ks = jax.random.split(rng, 6)
    std = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    p = {
        "router": L.init_linear(ks[0], d, e, dtype=jnp.float32),
        "expert_gate": L.truncated_normal(ks[1], (e, d, dff), std, dtype),
        "expert_up": L.truncated_normal(ks[2], (e, d, dff), std, dtype),
        "expert_down": L.truncated_normal(
            ks[3], (e, dff, d), 1.0 / jnp.sqrt(dff).astype(jnp.float32),
            dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(rng=ks[4], cfg=cfg, d=d,
                                 d_ff=dff * cfg.n_shared_experts, dtype=dtype)
    if cfg.moe_dense_residual:
        p["dense"] = L.init_mlp(rng=ks[5], cfg=cfg, d=d, d_ff=dff,
                                dtype=dtype)
    return p


def _route(p, x2, cfg):
    """x2: [T, d] -> (weights [T, k], experts [T, k])."""
    logits = jnp.dot(x2.astype(jnp.float32), p["router"].astype(jnp.float32))
    e_real = cfg.n_experts
    if cfg.padded_experts > e_real:
        pad_mask = jnp.arange(cfg.padded_experts) >= e_real
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    weights, experts = jax.lax.top_k(logits, cfg.top_k)
    weights = jax.nn.softmax(weights, axis=-1)  # normalize over selected k
    return weights, experts


def _dispatch_row(xr, weights, experts, e: int, cap: int, k: int):
    """Per-batch-row dispatch. xr: [S, d]; weights/experts: [S, k].
    Returns (buf [E, cap, d], combine metadata). Keeping the sort LOCAL to a
    row keeps every dispatch intermediate leading-dim=batch, so under pjit
    they stay sharded over ("pod","data") — the global-sort formulation
    forced GSPMD to replicate [T·k, d] gathers (measured +30 GB/device on
    arctic prefill_32k, §Perf iteration 1)."""
    s, d = xr.shape
    e_flat = experts.reshape(-1)                     # [S*k]
    w_flat = weights.reshape(-1)
    order = jnp.argsort(e_flat)
    e_sorted = e_flat[order]
    tok_sorted = order // k
    w_sorted = w_flat[order]
    seg_starts = jnp.searchsorted(e_sorted, jnp.arange(e), side="left")
    pos_in_e = jnp.arange(s * k) - seg_starts[e_sorted]
    keep = pos_in_e < cap
    pos_clip = jnp.where(keep, pos_in_e, cap)        # cap index drops (OOB)
    buf = jnp.zeros((e, cap, d), xr.dtype)
    buf = buf.at[e_sorted, pos_clip].set(xr[tok_sorted], mode="drop")
    return buf, (e_sorted, pos_clip, tok_sorted, w_sorted, keep)


def _combine_row(out_buf, meta, s: int, k: int, dtype):
    e_sorted, pos_clip, tok_sorted, w_sorted, keep = meta
    y_sorted = out_buf[e_sorted, pos_clip]
    y_sorted = jnp.where(keep[:, None], y_sorted, 0.0)
    y = jnp.zeros((s, out_buf.shape[-1]), dtype)
    return y.at[tok_sorted].add(y_sorted * w_sorted[:, None].astype(dtype))


def moe_ffn(p, x, cfg, impl: str = "auto"):
    """x: [B, S, d] -> [B, S, d]. Capacity is per batch row (standard
    group-limited dropping): cap = cf * S * k / E."""
    b, s, d = x.shape
    k = cfg.top_k
    e = cfg.padded_experts
    cap = int(cfg.capacity_factor * s * k / max(cfg.n_experts, 1))
    cap = max(4, min(cap, s * k))

    weights, experts = _route(p, x.reshape(-1, d), cfg)
    weights = weights.reshape(b, s, k)
    experts = experts.reshape(b, s, k)

    buf, meta = jax.vmap(
        lambda xr, wr, er: _dispatch_row(xr, wr, er, e, cap, k))(
            x, weights, experts)                     # buf: [B, E, cap, d]
    buf = shard(buf, "batch", "expert")

    h = jnp.einsum("becd,edf->becf", buf, _w(p, "expert_gate", x.dtype))
    u = jnp.einsum("becd,edf->becf", buf, _w(p, "expert_up", x.dtype))
    h = jax.nn.silu(h) * u
    h = shard(h, "batch", "expert")
    out_buf = jnp.einsum("becf,efd->becd", h,
                         _w(p, "expert_down", x.dtype))  # [B, E, cap, d]

    y = jax.vmap(lambda ob, m: _combine_row(ob, m, s, k, x.dtype))(
        out_buf, meta)
    y = shard(y, "batch", "seq")

    if "shared" in p:
        y = y + L.mlp_fwd(p["shared"], x, cfg, impl=impl)
    if "dense" in p:
        y = y + L.mlp_fwd(p["dense"], x, cfg, impl=impl)
    return y


def moe_ffn_dense_oracle(p, x, cfg):
    """O(T·E) reference: every expert on every token, masked by router —
    the correctness oracle for the sort-based dispatch (tests)."""
    b, s, d = x.shape
    x2 = x.reshape(-1, d)
    weights, experts = _route(p, x2, cfg)            # [T, k]
    e = cfg.padded_experts
    h = jnp.einsum("td,edf->tef", x2, _w(p, "expert_gate", x.dtype))
    u = jnp.einsum("td,edf->tef", x2, _w(p, "expert_up", x.dtype))
    y_all = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u,
                       _w(p, "expert_down", x.dtype))   # [T, E, d]
    comb = jnp.zeros((x2.shape[0], e), jnp.float32)
    comb = comb.at[jnp.arange(x2.shape[0])[:, None], experts].add(weights)
    y2 = jnp.einsum("te,ted->td", comb.astype(x.dtype), y_all)
    if "shared" in p:
        y2 = y2 + L.mlp_fwd(p["shared"], x2, cfg)
    if "dense" in p:
        y2 = y2 + L.mlp_fwd(p["dense"], x2, cfg)
    return y2.reshape(b, s, d)
