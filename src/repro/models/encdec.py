"""Whisper-small style encoder-decoder (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: `input_specs()` supplies
precomputed frame features [B, enc_seq, d_feat]; a linear projection maps
them to d_model (the backbone — bidirectional encoder + causal decoder with
cross-attention — is what is exercised). Sinusoidal positions on both sides.
Decode caches: per-layer self-attention KV (stacked) + cross-attention KV
computed once at prefill from the encoder output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.kernels import ops
from repro.models import attention as A
from repro.models import layers as L


def _sinusoid(s, d, offset=0):
    pos = jnp.arange(offset, offset + s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((s, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


def init_enc_layer(rng, cfg, dtype):
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": L.init_norm(cfg), "attn": A.init_attention(k1, cfg, dtype),
        "ln2": L.init_norm(cfg), "mlp": L.init_mlp(k2, cfg, dtype=dtype),
    }


def init_dec_layer(rng, cfg, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "ln1": L.init_norm(cfg), "self_attn": A.init_attention(k1, cfg, dtype),
        "ln_x": L.init_norm(cfg), "cross_attn": A.init_attention(k2, cfg,
                                                                 dtype),
        "ln2": L.init_norm(cfg), "mlp": L.init_mlp(k3, cfg, dtype=dtype),
    }


def init_params(rng, cfg):
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ke, kf, kenc, kdec = jax.random.split(rng, 4)
    enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": L.init_embed(ke, cfg, dtype),
        "frontend": L.init_linear(kf, cfg.d_feat, cfg.d_model, dtype),
        "enc_layers": jax.vmap(
            lambda k: init_enc_layer(k, cfg, dtype))(enc_keys),
        "dec_layers": jax.vmap(
            lambda k: init_dec_layer(k, cfg, dtype))(dec_keys),
        "enc_norm": L.init_norm(cfg),
        "final_norm": L.init_norm(cfg),
    }


def encode(params, frames, cfg, impl: str = "auto"):
    """frames: [B, F, d_feat] -> [B, F, d]."""
    from repro.core.axllm_linear import linear
    x = linear(frames.astype(jnp.float32), params["frontend"])
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)
    x = shard(x, "batch", "seq")

    def body(carry, lp):
        h = L.norm_fwd(lp["ln1"], carry, cfg.norm_eps)
        q, k, v = A._project_qkv(lp["attn"], h, cfg, impl)
        att = ops.flash_attention(q, k, v, causal=False, impl=impl)
        att = att.reshape(carry.shape[0], carry.shape[1], -1)
        from repro.core.axllm_linear import linear
        x1 = carry + linear(att, lp["attn"]["wo"], impl=impl)
        h2 = L.norm_fwd(lp["ln2"], x1, cfg.norm_eps)
        return x1 + L.mlp_fwd(lp["mlp"], h2, cfg, impl=impl), None

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = L.maybe_scan(body_fn, x, params["enc_layers"], cfg.scan_layers)
    return L.norm_fwd(params["enc_norm"], x, cfg.norm_eps)


def fuse_cross_attention_params(p):
    """Cross-attention fusion: only wk/wv share an input (the encoder
    output), so they fuse into wkv; wq runs on the decoder stream and
    stays separate."""
    if "wkv" in p or "wk" not in p:
        return p
    from repro.core.axllm_linear import concat_weights
    p2 = {k: v for k, v in p.items() if k not in ("wk", "wv")}
    p2["wkv"] = concat_weights([p["wk"], p["wv"]])
    return p2


def fuse_params(params, cfg):
    """Deploy-time fused-projection rewrite (cfg.fuse_qkv) over encoder
    self-attention, decoder self/cross attention and both MLP stacks.
    Apply AFTER deploy_quantize so QTensors concat exactly."""
    enc = dict(params["enc_layers"])
    enc["attn"] = A.fuse_attention_params(enc["attn"])
    enc["mlp"] = L.fuse_mlp_params(enc["mlp"])
    dec = dict(params["dec_layers"])
    dec["self_attn"] = A.fuse_attention_params(dec["self_attn"])
    dec["cross_attn"] = fuse_cross_attention_params(dec["cross_attn"])
    dec["mlp"] = L.fuse_mlp_params(dec["mlp"])
    return {**params, "enc_layers": enc, "dec_layers": dec}


def _cross_kv(lp, enc_out, cfg):
    """Precompute cross-attention K/V from encoder output: [B, F, Hk, hd]."""
    from repro.core.axllm_linear import linear
    b, f, _ = enc_out.shape
    hk, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    ca = lp["cross_attn"]
    if "wkv" in ca:      # fused: one [d, 2·Hk·hd] pass over the encoder out
        kv = linear(enc_out, ca["wkv"])
        k, v = jnp.split(kv, 2, axis=-1)
    else:
        k = linear(enc_out, ca["wk"])
        v = linear(enc_out, ca["wv"])
    return k.reshape(b, f, hk, hd), v.reshape(b, f, hk, hd)


def _dec_layer(lp, x, cfg, impl, enc_out=None, cross_kv=None,
               self_cache=None, pos=None, mode="train"):
    from repro.core.axllm_linear import linear
    b = x.shape[0]
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    # self attention
    hh = L.norm_fwd(lp["ln1"], x, cfg.norm_eps)
    if mode == "train":
        att = A.attention_fwd(lp["self_attn"], hh, cfg, impl=impl)
        new_self = None
    elif mode == "prefill":
        att, new_self = A.attention_prefill(lp["self_attn"], hh, cfg,
                                            self_cache, impl=impl)
    else:
        att, new_self = A.attention_decode(lp["self_attn"], hh, cfg,
                                           self_cache, pos, impl=impl)
    x = x + att
    # cross attention
    hx = L.norm_fwd(lp["ln_x"], x, cfg.norm_eps)
    q = linear(hx, lp["cross_attn"]["wq"], impl=impl).reshape(
        b, hx.shape[1], h, hd)
    if mode == "train":
        ck = _cross_kv(lp, enc_out, cfg)
        catt = ops.flash_attention(q, ck[0], ck[1], causal=False, impl=impl)
    else:
        ck, cv = cross_kv
        f = ck.shape[1]
        if mode == "decode":
            lengths = jnp.full((b,), f, jnp.int32)
            catt = ops.decode_attention(q[:, 0], ck, cv, lengths,
                                        impl=impl)[:, None]
        else:
            catt = ops.flash_attention(q, ck, cv, causal=False, impl=impl)
    catt = catt.reshape(b, x.shape[1], -1)
    x = x + linear(catt, lp["cross_attn"]["wo"], impl=impl)
    # mlp
    h2 = L.norm_fwd(lp["ln2"], x, cfg.norm_eps)
    x = x + L.mlp_fwd(lp["mlp"], h2, cfg, impl=impl)
    return shard(x, "batch", "seq"), new_self


def forward(params, batch, cfg, impl: str = "auto"):
    """batch: {"frames": [B,F,df], "tokens": [B,S]} -> logits [B,S,V]."""
    enc_out = encode(params, batch["frames"], cfg, impl=impl)
    tokens = batch["tokens"]
    x = L.embed_fwd(params["embed"], tokens).astype(enc_out.dtype)
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)

    def body(carry, lp):
        out, _ = _dec_layer(lp, carry, cfg, impl, enc_out=enc_out,
                            mode="train")
        return out, None

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = L.maybe_scan(body_fn, x, params["dec_layers"], cfg.scan_layers)
    x = L.norm_fwd(params["final_norm"], x, cfg.norm_eps)
    logits = L.head_fwd(params["embed"], x, cfg, impl=impl)
    return shard(logits, "batch", "seq", "vocab")


def loss_fn(params, batch, cfg, impl: str = "auto"):
    logits = forward(params, batch, cfg, impl=impl)
    return L.cross_entropy(logits, batch["targets"], cfg.vocab_size)


def init_cache(cfg, batch: int, max_len: int, dtype=None):
    dtype = dtype or (jnp.bfloat16 if cfg.dtype == "bfloat16"
                      else jnp.float32)
    hk, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    cache = A.init_cache(cfg, batch, max_len, dtype)
    cache["cross_k"] = jnp.zeros(
        (cfg.n_layers, batch, cfg.enc_seq, hk, hd), dtype)
    cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    return cache


def cache_spec(cfg):
    """Batch axis per cache leaf: self-attention KV per attention.cache_spec,
    cross KV stacked over layers (batch axis 1)."""
    spec = A.cache_spec(cfg)
    spec["cross_k"] = 1
    spec["cross_v"] = 1
    return spec


def prefill(params, batch, cfg, cache, impl: str = "auto", lengths=None):
    """Encode frames, precompute cross KV, prefill decoder self KV.

    `lengths` ([B] int32) enables ragged right-padded decoder prompts: the
    decoder self-attention is causal, so real tokens never see the padding;
    logits are gathered at each row's last real position and the cursor is
    set per row (pad KV beyond it is dead and overwritten by decode)."""
    enc_out = encode(params, batch["frames"], cfg, impl=impl)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed_fwd(params["embed"], tokens).astype(enc_out.dtype)
    x = x + _sinusoid(s, cfg.d_model).astype(x.dtype)

    def body(carry, inp):
        lp, self_kv = inp
        ck = _cross_kv(lp, enc_out, cfg)
        out, new_self = _dec_layer(lp, carry, cfg, impl, cross_kv=ck,
                                   self_cache=self_kv, mode="prefill")
        return out, (new_self, ck[0], ck[1])

    self_kv = {k: v for k, v in cache.items()
               if k not in ("pos", "cross_k", "cross_v")}
    x, (new_self, ck, cv) = L.maybe_scan(
        body, x, (params["dec_layers"], self_kv), cfg.scan_layers)
    if lengths is None:
        x = x[:, -1:]
        pos = jnp.full((b,), s, jnp.int32)
    else:
        pos = jnp.asarray(lengths, jnp.int32)
        x = x[jnp.arange(b), pos - 1][:, None]
    x = L.norm_fwd(params["final_norm"], x, cfg.norm_eps)
    logits = L.head_fwd(params["embed"], x, cfg, impl=impl)[:, 0]
    new_cache = dict(new_self)
    new_cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
    new_cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
    new_cache["pos"] = pos
    return logits, new_cache


def decode_step(params, token, cfg, cache, impl: str = "auto"):
    pos = cache["pos"]
    x = L.embed_fwd(params["embed"], token[:, None])
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    # sinusoidal position for the current token, per batch row
    d = cfg.d_model
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos[:, None].astype(jnp.float32) / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((x.shape[0], d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    x = x + pe[:, None].astype(x.dtype)

    def body(carry, inp):
        lp, self_kv, ck, cv = inp
        out, new_self = _dec_layer(lp, carry, cfg, impl, cross_kv=(ck, cv),
                                   self_cache=self_kv, pos=pos, mode="decode")
        return out, new_self

    self_kv = {k: v for k, v in cache.items()
               if k not in ("pos", "cross_k", "cross_v")}
    x, new_self = L.maybe_scan(
        body, x,
        (params["dec_layers"], self_kv, cache["cross_k"], cache["cross_v"]),
        cfg.scan_layers)
    x = L.norm_fwd(params["final_norm"], x, cfg.norm_eps)
    logits = L.head_fwd(params["embed"], x, cfg, impl=impl)[:, 0]
    new_cache = dict(new_self)
    new_cache["cross_k"] = cache["cross_k"]
    new_cache["cross_v"] = cache["cross_v"]
    new_cache["pos"] = pos + 1
    return logits, new_cache
