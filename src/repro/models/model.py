"""Family dispatcher: one uniform API over all 10 assigned architectures.

    api = get_model(cfg)
    params = api.init(rng)                      # or jax.eval_shape(api.init, rng)
    loss = api.loss(params, batch)              # train_4k
    logits, cache = api.prefill(params, batch, cache)   # prefill_32k
    logits, cache = api.decode(params, token, cache)    # decode_32k / long_500k

Serving contract (consumed by repro.serve.engine):

- ``cache_spec``: pytree with the same treedef as ``init_cache`` output,
  each leaf the *batch axis* of the corresponding cache leaf. Slot-based
  engines index this axis to insert/evict requests — no shape guessing.
- ``ragged_prefill``: True when ``prefill`` accepts ``lengths`` ([B] int32)
  and handles right-padded mixed-length prompts in one batch (causal
  attention families). Recurrent families (ssm/hybrid) reject ``lengths``
  and must be prefixed in equal-length batches.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, transformer, xlstm


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]
    forward: Callable[..., Any]
    init_cache: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    cache_spec: Any = None           # batch axis per init_cache leaf
    ragged_prefill: bool = False     # prefill(lengths=...) supported
    # block-paged KV cache (serve-engine paged mode): pool + block-table
    # constructor and its leaf spec (block axis for pool leaves, slot axis
    # for pos/block_tables). None for recurrent/enc-dec families — their
    # state folding has no per-position cache to page, so the engine
    # rejects paged=True for them with a clear error.
    init_paged_cache: Optional[Callable[..., Any]] = None
    paged_cache_spec: Any = None
    # deploy-time fused-projection rewrite (wqkv / gate_up); apply AFTER
    # deploy_quantize. None when the family has no fusable projections.
    fuse_params: Optional[Callable[[Any], Any]] = None
    # True when prefill/decode accept the multi-LoRA delta-pipeline kwargs
    # (adapters=, adapter_idx=, lora_scaling=). Recurrent families fold
    # positions into state through paths with no per-slot projection hook,
    # so they stay False and the serve engine rejects adapter registries.
    supports_lora: bool = False


def get_model(cfg: ModelConfig, impl: str = "auto") -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: mod.init_params(rng, cfg),
            loss=lambda p, b: mod.loss_fn(p, b, cfg, impl=impl),
            forward=lambda p, b: mod.forward(p, b["tokens"], cfg, impl=impl),
            init_cache=lambda batch, max_len: mod.init_cache(
                cfg, batch, max_len),
            prefill=lambda p, b, c, lengths=None, adapters=None,
            adapter_idx=None, lora_scaling=1.0, prefix=None: mod.prefill(
                p, b["tokens"], cfg, c, impl=impl, lengths=lengths,
                adapters=adapters, adapter_idx=adapter_idx,
                lora_scaling=lora_scaling, prefix=prefix),
            decode=lambda p, t, c, adapters=None, adapter_idx=None,
            lora_scaling=1.0: mod.decode_step(
                p, t, cfg, c, impl=impl, adapters=adapters,
                adapter_idx=adapter_idx, lora_scaling=lora_scaling),
            cache_spec=mod.cache_spec(cfg),
            ragged_prefill=True,
            init_paged_cache=lambda batch, n_blocks, block_size,
            max_blocks: mod.init_paged_cache(
                cfg, batch, n_blocks, block_size, max_blocks),
            paged_cache_spec=mod.paged_cache_spec(cfg),
            fuse_params=lambda p: mod.fuse_params(p, cfg),
            supports_lora=True,
        )
    if fam == "ssm":
        mod = xlstm
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: mod.init_params(rng, cfg),
            loss=lambda p, b: mod.loss_fn(p, b, cfg, impl=impl),
            forward=lambda p, b: mod.forward(p, b["tokens"], cfg, impl=impl),
            init_cache=lambda batch, max_len: mod.init_cache(cfg, batch,
                                                             max_len),
            prefill=lambda p, b, c, lengths=None: mod.prefill(
                p, b["tokens"], cfg, c, impl=impl, lengths=lengths),
            decode=lambda p, t, c: mod.decode_step(p, t, cfg, c, impl=impl),
            cache_spec=mod.cache_spec(cfg),
            ragged_prefill=False,
            fuse_params=lambda p: mod.fuse_params(p, cfg),
        )
    if fam == "hybrid":
        mod = hybrid
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: mod.init_params(rng, cfg),
            loss=lambda p, b: mod.loss_fn(p, b, cfg, impl=impl),
            forward=lambda p, b: mod.forward(p, b["tokens"], cfg, impl=impl),
            init_cache=lambda batch, max_len: mod.init_cache(cfg, batch,
                                                             max_len),
            prefill=lambda p, b, c, lengths=None: mod.prefill(
                p, b["tokens"], cfg, c, impl=impl, lengths=lengths),
            decode=lambda p, t, c: mod.decode_step(p, t, cfg, c, impl=impl),
            cache_spec=mod.cache_spec(cfg),
            ragged_prefill=False,
            fuse_params=lambda p: mod.fuse_params(p, cfg),
        )
    if fam == "audio":
        mod = encdec
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: mod.init_params(rng, cfg),
            loss=lambda p, b: mod.loss_fn(p, b, cfg, impl=impl),
            forward=lambda p, b: mod.forward(p, b, cfg, impl=impl),
            init_cache=lambda batch, max_len: mod.init_cache(cfg, batch,
                                                             max_len),
            prefill=lambda p, b, c, lengths=None: mod.prefill(
                p, b, cfg, c, impl=impl, lengths=lengths),
            decode=lambda p, t, c: mod.decode_step(p, t, cfg, c, impl=impl),
            cache_spec=mod.cache_spec(cfg),
            ragged_prefill=True,
            fuse_params=lambda p: mod.fuse_params(p, cfg),
        )
    raise ValueError(f"unknown family {fam!r}")


def make_batch(cfg: ModelConfig, rng, batch: int, seq: int):
    """Concrete random batch for smoke tests / examples."""
    kt, kf = jax.random.split(jax.random.PRNGKey(rng) if isinstance(rng, int)
                              else rng)
    tokens = jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size,
                                jnp.int32)
    out = {"tokens": tokens, "targets": jnp.roll(tokens, -1, axis=1)}
    if cfg.is_encoder_decoder:
        out["frames"] = jax.random.normal(
            kf, (batch, cfg.enc_seq, cfg.d_feat), jnp.float32)
    return out
