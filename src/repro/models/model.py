"""Family dispatcher: one uniform API over all 10 assigned architectures.

    api = get_model(cfg)
    params = api.init(rng)                      # or jax.eval_shape(api.init, rng)
    loss = api.loss(params, batch)              # train_4k
    logits, cache = api.prefill(params, batch, cache)   # prefill_32k
    logits, cache = api.decode(params, token, cache)    # decode_32k / long_500k
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, transformer, xlstm


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]
    forward: Callable[..., Any]
    init_cache: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]


def get_model(cfg: ModelConfig, impl: str = "auto") -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: mod.init_params(rng, cfg),
            loss=lambda p, b: mod.loss_fn(p, b, cfg, impl=impl),
            forward=lambda p, b: mod.forward(p, b["tokens"], cfg, impl=impl),
            init_cache=lambda batch, max_len: mod.init_cache(
                cfg, batch, max_len),
            prefill=lambda p, b, c: mod.prefill(p, b["tokens"], cfg, c,
                                                impl=impl),
            decode=lambda p, t, c: mod.decode_step(p, t, cfg, c, impl=impl),
        )
    if fam == "ssm":
        mod = xlstm
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: mod.init_params(rng, cfg),
            loss=lambda p, b: mod.loss_fn(p, b, cfg, impl=impl),
            forward=lambda p, b: mod.forward(p, b["tokens"], cfg, impl=impl),
            init_cache=lambda batch, max_len: mod.init_cache(cfg, batch,
                                                             max_len),
            prefill=lambda p, b, c: mod.prefill(p, b["tokens"], cfg, c,
                                                impl=impl),
            decode=lambda p, t, c: mod.decode_step(p, t, cfg, c, impl=impl),
        )
    if fam == "hybrid":
        mod = hybrid
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: mod.init_params(rng, cfg),
            loss=lambda p, b: mod.loss_fn(p, b, cfg, impl=impl),
            forward=lambda p, b: mod.forward(p, b["tokens"], cfg, impl=impl),
            init_cache=lambda batch, max_len: mod.init_cache(cfg, batch,
                                                             max_len),
            prefill=lambda p, b, c: mod.prefill(p, b["tokens"], cfg, c,
                                                impl=impl),
            decode=lambda p, t, c: mod.decode_step(p, t, cfg, c, impl=impl),
        )
    if fam == "audio":
        mod = encdec
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: mod.init_params(rng, cfg),
            loss=lambda p, b: mod.loss_fn(p, b, cfg, impl=impl),
            forward=lambda p, b: mod.forward(p, b, cfg, impl=impl),
            init_cache=lambda batch, max_len: mod.init_cache(cfg, batch,
                                                             max_len),
            prefill=lambda p, b, c: mod.prefill(p, b, cfg, c, impl=impl),
            decode=lambda p, t, c: mod.decode_step(p, t, cfg, c, impl=impl),
        )
    raise ValueError(f"unknown family {fam!r}")


def make_batch(cfg: ModelConfig, rng, batch: int, seq: int):
    """Concrete random batch for smoke tests / examples."""
    kt, kf = jax.random.split(jax.random.PRNGKey(rng) if isinstance(rng, int)
                              else rng)
    tokens = jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size,
                                jnp.int32)
    out = {"tokens": tokens, "targets": jnp.roll(tokens, -1, axis=1)}
    if cfg.is_encoder_decoder:
        out["frames"] = jax.random.normal(
            kf, (batch, cfg.enc_seq, cfg.d_feat), jnp.float32)
    return out
