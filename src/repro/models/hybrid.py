"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block applied
every `cfg.hybrid_attn_every` layers (arXiv:2411.15242).

The shared block's *weights* are applied at every site, but each site keeps
its own KV cache (stacked on a leading site dim). Following Zamba, the shared
block sees concat(hidden, initial_embedding) projected back to d_model
("concat_proj"); the per-site LoRA specialization of Zamba2 is implemented as
an optional rank-16 adapter stack (enabled by default — it is tiny and it is
the LoRA surface the AxLLM Fig. 5 reuse targets in this arch).

Layer layout: n_layers = full_groups * every + remainder; a group is
[shared-attn site, `every` mamba layers]; remainder mamba layers close the
stack. Both levels are scans over stacked params.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models import attention as A
from repro.models import layers as L
from repro.models import ssm as S


def _groups(cfg):
    every = cfg.hybrid_attn_every
    assert every > 0
    return cfg.n_layers // every, cfg.n_layers % every, every


def init_shared_block(rng, cfg, dtype):
    ks = jax.random.split(rng, 4)
    return {
        "concat_proj": L.init_linear(ks[0], 2 * cfg.d_model, cfg.d_model,
                                     dtype),
        "ln1": L.init_norm(cfg),
        "attn": A.init_attention(ks[1], cfg, dtype),
        "ln2": L.init_norm(cfg),
        "mlp": L.init_mlp(ks[2], cfg, dtype=dtype),
    }


def init_params(rng, cfg):
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    n_full, rem, every = _groups(cfg)
    ke, km, kr, ks = jax.random.split(rng, 4)
    mkeys = jax.random.split(km, max(n_full * every, 1))
    mkeys = mkeys[: n_full * every].reshape(n_full, every, -1)
    mamba = jax.vmap(jax.vmap(lambda k: S.init_mamba2(k, cfg, dtype)))(mkeys)
    p = {
        "embed": L.init_embed(ke, cfg, dtype),
        "mamba": mamba,                       # [n_full, every, ...]
        "shared": init_shared_block(ks, cfg, dtype),
        "final_norm": L.init_norm(cfg),
    }
    if rem:
        rkeys = jax.random.split(kr, rem)
        p["mamba_rem"] = jax.vmap(
            lambda k: S.init_mamba2(k, cfg, dtype))(rkeys)
    return p


def fuse_params(params, cfg):
    """Deploy-time fused-projection rewrite (cfg.fuse_qkv) of the shared
    attention block (wqkv + gate_up). The Mamba backbone's projections are
    already layout-fused at init (in_proj carries x/z/B/C/dt together).
    Apply AFTER deploy_quantize so QTensors concat exactly."""
    shared = dict(params["shared"])
    shared["attn"] = A.fuse_attention_params(shared["attn"])
    shared["mlp"] = L.fuse_mlp_params(shared["mlp"])
    return {**params, "shared": shared}


def _shared_fwd(sp, x, x0, cfg, impl, cache=None, pos=None, mode="train"):
    """Apply the shared attention block. x, x0: [B, S, d]."""
    from repro.core.axllm_linear import linear
    xin = linear(jnp.concatenate([x, x0], -1), sp["concat_proj"])
    h = L.norm_fwd(sp["ln1"], xin, cfg.norm_eps)
    if mode == "train":
        att = A.attention_fwd(sp["attn"], h, cfg, impl=impl)
        new_cache = None
    elif mode == "prefill":
        att, new_cache = A.attention_prefill(sp["attn"], h, cfg, cache,
                                             impl=impl)
    else:
        att, new_cache = A.attention_decode(sp["attn"], h, cfg, cache, pos,
                                            impl=impl)
    xin = xin + att
    h2 = L.norm_fwd(sp["ln2"], xin, cfg.norm_eps)
    out = x + xin + L.mlp_fwd(sp["mlp"], h2, cfg, impl=impl)
    return shard(out, "batch", "seq"), new_cache


def forward(params, tokens, cfg, impl: str = "auto"):
    n_full, rem, every = _groups(cfg)
    x = L.embed_fwd(params["embed"], tokens)
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    x0 = x

    def mamba_body(carry, mp):
        return carry + S.mamba2_fwd(mp, carry, cfg), None

    def group_body(carry, gp):
        carry, _ = _shared_fwd(params["shared"], carry, x0, cfg, impl)
        body = jax.checkpoint(mamba_body, prevent_cse=False) if cfg.remat \
            else mamba_body
        carry, _ = L.maybe_scan(body, carry, gp, cfg.scan_layers)
        return carry, None

    x, _ = L.maybe_scan(group_body, x, params["mamba"], cfg.scan_layers)
    if rem:
        x, _ = L.maybe_scan(mamba_body, x, params["mamba_rem"],
                            cfg.scan_layers)
    x = L.norm_fwd(params["final_norm"], x, cfg.norm_eps)
    logits = L.head_fwd(params["embed"], x, cfg, impl=impl)
    return shard(logits, "batch", "seq", "vocab")


def loss_fn(params, batch, cfg, impl: str = "auto"):
    logits = forward(params, batch["tokens"], cfg, impl=impl)
    return L.cross_entropy(logits, batch["targets"], cfg.vocab_size)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype=None):
    n_full, rem, every = _groups(cfg)
    dtype = dtype or (jnp.bfloat16 if cfg.dtype == "bfloat16"
                      else jnp.float32)

    def stack(tree, n):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape), tree)

    conv, ssm_st = S.init_mamba_state(cfg, batch, dtype)
    cache = {
        "attn": A.init_cache(cfg, batch, max_len, dtype, n_layers=n_full),
        "conv": stack(stack(conv, every), n_full),
        "ssm": stack(stack(ssm_st, every), n_full),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if rem:
        cache["conv_rem"] = stack(conv, rem)
        cache["ssm_rem"] = stack(ssm_st, rem)
    return cache


def cache_spec(cfg):
    """Batch axis per cache leaf. Attention-site KV stacks over sites
    (batch axis 1, pos axis 0 — attention.cache_spec); Mamba states stack
    [n_full, every, B, ...] (axis 2), remainder layers [rem, B, ...]
    (axis 1)."""
    n_full, rem, every = _groups(cfg)
    spec = {"attn": A.cache_spec(cfg), "conv": 2, "ssm": 2, "pos": 0}
    if rem:
        spec["conv_rem"] = 1
        spec["ssm_rem"] = 1
    return spec


def decode_step(params, token, cfg, cache, impl: str = "auto"):
    n_full, rem, every = _groups(cfg)
    pos = cache["pos"]
    x = L.embed_fwd(params["embed"], token[:, None])
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    x0 = x
    attn_kv = {k: v for k, v in cache["attn"].items() if k != "pos"}

    def mamba_body(carry, inp):
        mp, cv, st = inp
        out, (ncv, nst) = S.mamba2_step(mp, carry, cfg, cv, st)
        return carry + out, (ncv, nst)

    def group_body(carry, inp):
        gp, site_kv, cv, st = inp
        carry, new_kv = _shared_fwd(params["shared"], carry, x0, cfg, impl,
                                    cache=site_kv, pos=pos, mode="decode")
        carry, (ncv, nst) = L.maybe_scan(mamba_body, carry, (gp, cv, st),
                                         cfg.scan_layers)
        return carry, (new_kv, ncv, nst)

    x, (new_kv, new_conv, new_ssm) = L.maybe_scan(
        group_body, x,
        (params["mamba"], attn_kv, cache["conv"], cache["ssm"]),
        cfg.scan_layers)
    new_cache = dict(cache)
    new_cache["attn"] = dict(new_kv)
    new_cache["attn"]["pos"] = pos + 1
    new_cache["conv"], new_cache["ssm"] = new_conv, new_ssm
    if rem:
        x, (ncr, nsr) = L.maybe_scan(
            mamba_body, x,
            (params["mamba_rem"], cache["conv_rem"], cache["ssm_rem"]),
            cfg.scan_layers)
        new_cache["conv_rem"], new_cache["ssm_rem"] = ncr, nsr
    x = L.norm_fwd(params["final_norm"], x, cfg.norm_eps)
    logits = L.head_fwd(params["embed"], x, cfg, impl=impl)[:, 0]
    new_cache["pos"] = pos + 1
    return logits, new_cache


def prefill(params, tokens, cfg, cache, impl: str = "auto", lengths=None):
    """Parallel prefill: chunkwise SSD over the full prompt + per-site
    attention prefill; emits all recurrent states and the filled site KVs.

    The Mamba backbone is recurrent, so ragged (`lengths`) prefill is
    rejected — the serve engine batches equal-length prompts instead."""
    if lengths is not None:
        raise NotImplementedError(
            "hybrid prefill is recurrent (Mamba backbone): padded positions "
            "would enter the state (ragged_prefill=False).")
    n_full, rem, every = _groups(cfg)
    b, s = tokens.shape
    pos = jnp.full((b,), s, jnp.int32)
    x = L.embed_fwd(params["embed"], tokens)
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    x0 = x
    attn_kv = {k: v for k, v in cache["attn"].items() if k != "pos"}

    def mamba_body(carry, mp):
        out, (cv, st) = S.mamba2_fwd(mp, carry, cfg, return_state=True)
        return carry + out, (cv, st)

    def group_body(carry, inp):
        gp, site_kv = inp
        carry, new_kv = _shared_fwd(params["shared"], carry, x0, cfg, impl,
                                    cache=site_kv, mode="prefill")
        carry, (cv, st) = L.maybe_scan(mamba_body, carry, gp,
                                       cfg.scan_layers)
        return carry, (new_kv, cv, st)

    x, (new_kv, conv, ssm_st) = L.maybe_scan(
        group_body, x, (params["mamba"], attn_kv), cfg.scan_layers)
    new_cache = dict(cache)
    new_cache["attn"] = dict(new_kv)
    new_cache["attn"]["pos"] = pos
    new_cache["conv"], new_cache["ssm"] = conv, ssm_st
    if rem:
        x, (cvr, str_) = L.maybe_scan(mamba_body, x, params["mamba_rem"],
                                      cfg.scan_layers)
        new_cache["conv_rem"], new_cache["ssm_rem"] = cvr, str_
    x = L.norm_fwd(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = L.head_fwd(params["embed"], x, cfg, impl=impl)[:, 0]
    new_cache["pos"] = pos
    return logits, new_cache
