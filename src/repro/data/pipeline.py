"""Deterministic, restart-safe data pipeline.

Every batch is a pure function of (seed, step) — random access by step is the
property the fault-tolerance layer relies on: after checkpoint restore at
step k, batch k+1 is bit-identical to the uninterrupted run, making
crash/restart *bitwise reproducible* (tested). Two sources:

* SyntheticLM: structured pseudo-text (Zipf-ish unigram + Markov-ish bigram
  mixing) — enough signal for loss to fall, no external data needed.
* ByteCorpus: byte-level LM over a directory of text files (self-contained:
  defaults to this repository's own sources), chunked deterministically.

Batches are host numpy; `shard_batch` places them against the active mesh
with the "batch" logical axis (single-host: one device_put per array).
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd


def _rng_for(seed: int, step: int) -> np.random.Generator:
    mix = hashlib.blake2b(f"{seed}:{step}".encode(), digest_size=8).digest()
    return np.random.default_rng(int.from_bytes(mix, "little"))


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    batch: int
    seq: int
    seed: int = 0
    frames: Optional[tuple] = None      # (enc_seq, d_feat) for enc-dec archs

    def __post_init__(self):
        rng = _rng_for(self.seed, -1)
        v = self.vocab_size
        # fixed Zipf unigram + a deterministic successor table => learnable
        self._probs = 1.0 / np.arange(1, v + 1)
        self._probs /= self._probs.sum()
        self._succ = rng.permutation(v)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = _rng_for(self.seed, step)
        b, s, v = self.batch, self.seq, self.vocab_size
        base = rng.choice(v, size=(b, s), p=self._probs)
        # 50% of positions follow the successor table of the previous token
        follow = rng.random((b, s)) < 0.5
        shifted = self._succ[np.roll(base, 1, axis=1)]
        tokens = np.where(follow, shifted, base).astype(np.int32)
        out = {"tokens": tokens,
               "targets": np.roll(tokens, -1, axis=1).astype(np.int32)}
        if self.frames:
            f, d = self.frames
            out["frames"] = rng.standard_normal((b, f, d)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class ByteCorpus:
    """Byte-level LM over the text files under `root` (deterministic)."""
    batch: int
    seq: int
    root: str = "."
    seed: int = 0
    exts: tuple = (".py", ".md", ".txt")
    vocab_size: int = 256

    def __post_init__(self):
        blobs = []
        for dirpath, _, files in sorted(os.walk(self.root)):
            if any(part.startswith(".") for part in dirpath.split(os.sep)):
                continue
            for f in sorted(files):
                if f.endswith(self.exts):
                    try:
                        with open(os.path.join(dirpath, f), "rb") as fh:
                            blobs.append(fh.read())
                    except OSError:
                        pass
        data = b"\n".join(blobs) or b"empty corpus " * 1024
        self._data = np.frombuffer(data, dtype=np.uint8)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = _rng_for(self.seed, step)
        n = len(self._data) - self.seq - 1
        starts = rng.integers(0, max(n, 1), size=self.batch)
        tok = np.stack([self._data[s:s + self.seq] for s in starts])
        tgt = np.stack([self._data[s + 1:s + self.seq + 1] for s in starts])
        return {"tokens": tok.astype(np.int32),
                "targets": tgt.astype(np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_dataset(cfg, batch: int, seq: int, seed: int = 0,
                 source: str = "synthetic"):
    if source == "bytes":
        return ByteCorpus(batch=batch, seq=seq, seed=seed)
    frames = (cfg.enc_seq, cfg.d_feat) if cfg.is_encoder_decoder else None
    return SyntheticLM(vocab_size=cfg.vocab_size, batch=batch, seq=seq,
                       seed=seed, frames=frames)


_BATCH_LOGICAL = {"tokens": ("batch", "seq"), "targets": ("batch", "seq"),
                  "frames": ("batch", "seq", None)}


def shard_batch(batch: Dict[str, np.ndarray], mesh=None):
    """Place a host batch on devices with the "batch" axis sharded."""
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    out = {}
    for k, v in batch.items():
        names = _BATCH_LOGICAL.get(k, ("batch",))
        ns = shd.named_sharding(v.shape, names[: v.ndim], mesh)
        out[k] = jax.device_put(v, ns)
    return out


def batch_specs(cfg, batch: int, seq: int, mesh, train: bool = True):
    """ShapeDtypeStructs (+shardings) for the dry-run input_specs."""
    specs = {
        "tokens": jax.ShapeDtypeStruct(
            (batch, seq), jnp.int32,
            sharding=shd.named_sharding((batch, seq), ("batch", "seq"),
                                        mesh)),
    }
    if train or True:
        specs["targets"] = specs["tokens"]
    if cfg.is_encoder_decoder:
        shp = (batch, cfg.enc_seq, cfg.d_feat)
        specs["frames"] = jax.ShapeDtypeStruct(
            shp, jnp.float32,
            sharding=shd.named_sharding(shp, ("batch", None, None), mesh))
    return specs
