"""Hand-rolled AdamW with optional blockwise-int8 moment states.

No optax in the container — this is the framework's optimizer substrate.
The int8 moments (bitsandbytes-style blockwise absmax over flattened
256-element blocks) cut optimizer memory from 8 to ~2 bytes/param — the knob
that lets arctic-480b fit 16 GB/chip on the single-pod mesh (DESIGN.md §5),
and an instance of the "distributed-optimization tricks" requirement
(state compression; gradient-transfer compression lives in
dist/compression.py).

Layout note: moments are stored per-leaf with the same sharding as the
parameter (pjit shards the update elementwise), so ZeRO-style partitioning
falls out of the FSDP param specs for free.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import QTensor

BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                 # peak; schedule multiplies
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    int8_moments: bool = False


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Q8:
    """Blockwise-int8 moment: codes int8 with the PARAMETER'S OWN SHAPE
    (blocks run along the last dim), scale f32 [..., n_blocks].

    Shape preservation is a sharding requirement, not cosmetics: flat codes
    lose the parameter's PartitionSpec, so the f32 dequantized temporaries
    inside the Adam update replicate — measured at ~6.9 TB/device on
    arctic-480b train (§Perf iteration 2). With param-shaped codes the spec
    propagates through dequantize→update→requantize elementwise chains.
    `shape` / `pad` are static aux data."""
    codes: Any
    scale: Any
    shape: tuple
    pad: int = 0

    def tree_flatten(self):
        return (self.codes, self.scale), (self.shape, self.pad)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])


def _q8(x: jax.Array, *, unsigned_sqrt: bool = False) -> Q8:
    """Blockwise absmax int8 along the last dim. For the (non-negative)
    second moment, `unsigned_sqrt` stores codes in the sqrt domain — code =
    round(255 * sqrt(v / blockmax)) — which keeps small-magnitude entries
    representable (a linear map collapses them to 0 and the Adam step
    m/sqrt(v)+eps explodes; observed empirically before this fix)."""
    xf = x.astype(jnp.float32)
    last = xf.shape[-1]
    pad = (-last) % BLOCK
    if pad:
        xf = jnp.pad(xf, [(0, 0)] * (xf.ndim - 1) + [(0, pad)])
    grp = xf.reshape(*xf.shape[:-1], -1, BLOCK)
    if unsigned_sqrt:
        blockmax = jnp.maximum(grp.max(axis=-1), 1e-20)      # [..., nblk]
        root = jnp.sqrt(grp / blockmax[..., None])
        codes = jnp.clip(jnp.round(root * 255.0) - 128, -128,
                         127).astype(jnp.int8)
    else:
        blockmax = jnp.maximum(jnp.abs(grp).max(axis=-1), 1e-12) / 127.0
        codes = jnp.clip(jnp.round(grp / blockmax[..., None]), -127,
                         127).astype(jnp.int8)
    codes = codes.reshape(*xf.shape[:-1], last + pad)[..., :last]
    return Q8(codes, blockmax, x.shape, pad)


def _deq8(q: Q8, *, unsigned_sqrt: bool = False) -> jax.Array:
    codes = q.codes.astype(jnp.float32)
    if q.pad:
        codes = jnp.pad(codes, [(0, 0)] * (codes.ndim - 1) + [(0, q.pad)])
    grp = codes.reshape(*codes.shape[:-1], -1, BLOCK)
    if unsigned_sqrt:
        root = (grp + 128.0) / 255.0
        fp = root * root * q.scale[..., None]
    else:
        fp = grp * q.scale[..., None]
    last = q.shape[-1]
    return fp.reshape(*codes.shape[:-1], last + q.pad)[..., :last]


def _is_param(x):
    return hasattr(x, "ndim") and not isinstance(x, QTensor)


def _zeros_like_moment(p, int8: bool):
    if int8 and p.size >= BLOCK and p.ndim >= 1:
        last = p.shape[-1]
        pad = (-last) % BLOCK
        nblk = (last + pad) // BLOCK
        return Q8(jnp.zeros(p.shape, jnp.int8),
                  jnp.zeros(p.shape[:-1] + (nblk,), jnp.float32),
                  tuple(p.shape), pad)
    return jnp.zeros(p.shape, jnp.float32)


def init(params, cfg: AdamWConfig):
    moments = lambda: jax.tree_util.tree_map(
        lambda p: _zeros_like_moment(p, cfg.int8_moments), params,
        is_leaf=lambda x: isinstance(x, QTensor))
    return {"m": moments(), "v": moments(),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    bc1 = 1.0 - cfg.beta1 ** count.astype(jnp.float32)
    bc2 = 1.0 - cfg.beta2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = _deq8(m) if isinstance(m, Q8) else m
        v_f = _deq8(v, unsigned_sqrt=True) if isinstance(v, Q8) else v
        m_new = cfg.beta1 * m_f + (1 - cfg.beta1) * g
        v_new = cfg.beta2 * v_f + (1 - cfg.beta2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # quantized moments: bound the per-element trust region against
        # residual quantization noise in tiny-v blocks
        step = jnp.clip(step, -3.0, 3.0)
        wd = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = (p.astype(jnp.float32) - lr * (step + wd)).astype(p.dtype)
        m_out = _q8(m_new) if isinstance(m, Q8) else m_new
        v_out = _q8(v_new, unsigned_sqrt=True) if isinstance(v, Q8) \
            else v_new
        return new_p, m_out, v_out

    is_q8 = lambda x: isinstance(x, Q8)
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm}


# ---------------------------------------------------------------------------
# LR schedule
# ---------------------------------------------------------------------------

def warmup_cosine(step, warmup: int, total: int, floor: float = 0.1):
    s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
