"""Training step factory: grad-accumulation microbatch scan + AdamW.

`make_train_step` builds the jit-able pure step used by the launcher, the
dry-run (lowered with ShapeDtypeStructs) and the tests. Gradient accumulation
is a lax.scan over `cfg.grad_accum` microbatches — activation memory is
bounded by ONE microbatch (the per-arch fit knob) and XLA overlaps each
microbatch's reduce-scatter with the next one's compute under pjit.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim import adamw


def make_train_step(api, opt_cfg: adamw.AdamWConfig, total_steps: int = 10000,
                    warmup: int = 100, grad_specs=None) -> Callable:
    """`grad_specs`: optional NamedSharding pytree (usually the parameter
    specs). Without it, XLA is free to REPLICATE the f32 gradient
    accumulator carried through the microbatch scan — measured at +7.5 TB/
    device on arctic-480b (§Perf iteration 1) — so the launcher/dry-run
    always passes the param specs."""
    cfg = api.cfg
    accum = max(cfg.grad_accum, 1)

    def loss_microbatch(params, mb):
        return api.loss(params, mb)

    grad_fn = jax.value_and_grad(loss_microbatch)

    def constrain(tree):
        if grad_specs is None:
            return tree
        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s)
            if s is not None else g, tree, grad_specs)

    acc_dtype = jnp.bfloat16 if cfg.grad_accum_dtype == "bfloat16" \
        else jnp.float32

    def train_step(params, opt_state, batch, step):
        if accum > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(accum, b // accum, *x.shape[1:])
            mbs = jax.tree_util.tree_map(split, batch)

            def body(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = grad_fn(params, mb)
                # constrain IMMEDIATELY: the raw grad pytree's sharding is
                # derived from the backward contraction (e.g. MoE dW loses
                # the "data" dim and materializes 313 GB/device on arctic);
                # giving the partitioner the spec at the earliest point lets
                # it propagate into the scan backward
                grads = constrain(grads)
                grads = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(acc_dtype), grads_acc, grads)
                return (loss_acc + loss, constrain(grads)), None

            zeros = constrain(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params))
            (loss, grads), _ = jax.lax.scan(body, (0.0, zeros), mbs)
            loss = loss / accum
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
        else:
            loss, grads = grad_fn(params, batch)
            grads = constrain(grads)

        lr_scale = adamw.warmup_cosine(step, warmup, total_steps)
        params, opt_state, metrics = adamw.update(params, grads, opt_state,
                                                  opt_cfg, lr_scale)
        metrics = dict(metrics, loss=loss, lr_scale=lr_scale)
        return params, opt_state, metrics

    return train_step


def make_eval_step(api) -> Callable:
    def eval_step(params, batch):
        return api.loss(params, batch)
    return eval_step


def jit_train_step(train_step, mesh=None, param_sharding=None,
                   opt_sharding=None, batch_sharding=None, donate=True):
    """jit with explicit shardings (the launcher/dry-run entry)."""
    kwargs = {}
    if param_sharding is not None:
        kwargs["in_shardings"] = (param_sharding, opt_sharding,
                                  batch_sharding, None)
        kwargs["out_shardings"] = (param_sharding, opt_sharding, None)
    if donate:
        kwargs["donate_argnums"] = (0, 1)
    return jax.jit(train_step, **kwargs)
