"""Hand-rolled sharded checkpointing (no orbax in the container).

Layout: <dir>/step_<N>/ holding one .npy per pytree leaf (path-encoded
filenames) + manifest.json (treedef repr, shapes, dtypes, step, config name).
Writes are atomic (tmp dir + rename); a `latest` marker file advances last;
`keep` old steps are garbage-collected. `save_async` snapshots to host
memory synchronously (device_get) and writes on a background thread — the
training loop is blocked only for the host copy, mirroring production async
checkpointing.

**Elastic restore**: restore() takes target shardings (possibly for a
different mesh shape than the save-time mesh) and device_puts each leaf
against them — checkpoint-level elastic rescaling (tested across mesh sizes
in tests/test_distributed.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np

import jax

from repro.core.quantization import QTensor
from repro.optim.adamw import Q8

_SEP = "__"


def _flatten(tree):
    """(path, leaf) pairs; QTensor/Q8 are decomposed into array children."""
    out = []

    def visit(path, node):
        if isinstance(node, dict):
            for k in sorted(node):
                visit(path + [str(k)], node[k])
        elif isinstance(node, (list, tuple)) and not hasattr(node, "shape"):
            for i, v in enumerate(node):
                visit(path + [str(i)], v)
        elif isinstance(node, QTensor):
            visit(path + ["@qt_codes"], node.codes)
            visit(path + ["@qt_scale"], node.scale)
            if node.codebook is not None:
                visit(path + ["@qt_codebook"], node.codebook)
        elif isinstance(node, Q8):
            visit(path + ["@q8_codes"], node.codes)
            visit(path + ["@q8_scale"], node.scale)
        else:
            out.append((_SEP.join(path), node))

    visit([], tree)
    return out


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None,
         keep: int = 3) -> str:
    """Synchronous atomic save. Returns the final step directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"][name] = {"shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(ckpt_dir, "latest.tmp"),
               os.path.join(ckpt_dir, "latest"))
    _gc(ckpt_dir, keep)
    return final


class AsyncSaver:
    """Snapshot synchronously, write on a background thread (one in flight)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def save(self, ckpt_dir: str, step: int, tree, extra=None, keep: int = 3):
        snapshot = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree,
            is_leaf=lambda x: isinstance(x, (QTensor, Q8)) or
            hasattr(x, "shape"))
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(ckpt_dir, step, snapshot, extra, keep),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    marker = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, like, step: Optional[int] = None,
            shardings: Optional[Any] = None):
    """Restore into the structure of `like` (a pytree or eval_shape result).

    `shardings`: optional matching pytree of NamedSharding — leaves are
    device_put against them (elastic reshard on a different mesh).
    Returns (tree, step).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    names = dict(_flatten(like))
    shard_map_ = dict(_flatten(shardings)) if shardings is not None else {}
    with open(os.path.join(d, "manifest.json")) as f:
        saved_dtypes = {k: v["dtype"]
                        for k, v in json.load(f)["leaves"].items()}
    loaded = {}
    for name in names:
        arr = np.load(os.path.join(d, name + ".npy"))
        if arr.dtype.kind == "V" and name in saved_dtypes:
            # numpy round-trips ml_dtypes arrays (bfloat16) as raw void —
            # reinterpret against the SAVE-time dtype the manifest recorded
            # (the target tree's dtype may legitimately differ, e.g. a
            # float16 template: view() there would misread the bits)
            arr = arr.view(np.dtype(saved_dtypes[name]))
        if name in shard_map_ and shard_map_[name] is not None:
            loaded[name] = jax.device_put(arr, shard_map_[name])
        else:
            loaded[name] = jax.numpy.asarray(arr)
    return _unflatten_like(like, loaded), step


def _unflatten_like(like, loaded: dict):
    def visit(path, node):
        if isinstance(node, dict):
            return {k: visit(path + [str(k)], v) for k, v in node.items()}
        if isinstance(node, (list, tuple)) and not hasattr(node, "shape"):
            vals = [visit(path + [str(i)], v) for i, v in enumerate(node)]
            return type(node)(vals)
        if isinstance(node, QTensor):
            cb = None
            if node.codebook is not None:
                cb = loaded[_SEP.join(path + ["@qt_codebook"])]
            return QTensor(
                codes=loaded[_SEP.join(path + ["@qt_codes"])],
                scale=loaded[_SEP.join(path + ["@qt_scale"])],
                codebook=cb, bits=node.bits, mode=node.mode,
                granularity=node.granularity, group_size=node.group_size,
                packed=node.packed, shape=node.shape)
        if isinstance(node, Q8):
            return Q8(loaded[_SEP.join(path + ["@q8_codes"])],
                      loaded[_SEP.join(path + ["@q8_scale"])], node.shape)
        return loaded[_SEP.join(path)]

    return visit([], like)


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.isdir(os.path.join(ckpt_dir, d)))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
