"""Fault tolerance: watchdog, failure injection, auto-restart driver.

Production posture for 1000+-node runs (DESIGN.md §5):

* checkpoints every `save_every` steps (async) — MTBF-bounded lost work;
* the data pipeline is random-access by step, so a restore at step k replays
  batch k+1 bit-identically: `resilient_train` passes the bitwise-resume
  test in tests/test_fault_tolerance.py;
* `StepMonitor` flags stragglers (step time > factor x EMA). On a real
  multi-host deployment the surrounding launcher maps flagged hosts to the
  respawn path (jax.distributed makes missing hosts fatal, so the recovery
  unit is process-restart + elastic restore — which checkpoint.restore
  supports across mesh shapes);
* `FailureInjector` deterministically raises mid-run to exercise the path.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

import jax

from repro.train import checkpoint as ckpt_lib

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class StepMonitor:
    """EMA step-time watchdog; straggler events feed the restart policy."""
    ema_decay: float = 0.9
    straggler_factor: float = 3.0
    warmup_steps: int = 3
    _ema: Optional[float] = None
    _count: int = 0
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self._count += 1
        if self._ema is None:
            self._ema = dt
            return False
        is_straggler = (self._count > self.warmup_steps
                        and dt > self.straggler_factor * self._ema)
        if is_straggler:
            self.events.append((step, dt, self._ema))
            log.warning("straggler: step %d took %.3fs (ema %.3fs)",
                        step, dt, self._ema)
        self._ema = self.ema_decay * self._ema + (1 - self.ema_decay) * dt
        return is_straggler


class FailureInjector:
    """Raises RuntimeError at the given global steps (once each)."""

    def __init__(self, fail_at=()):
        self.remaining = set(fail_at)

    def __call__(self, step: int):
        if step in self.remaining:
            self.remaining.discard(step)
            raise RuntimeError(f"injected failure at step {step}")


def resilient_train(*, train_step: Callable, params, opt_state, dataset,
                    ckpt_dir: str, total_steps: int, save_every: int = 20,
                    max_restarts: int = 5, fail_hook: Optional[Callable] = None,
                    monitor: Optional[StepMonitor] = None,
                    shardings=None, log_every: int = 10):
    """Run to total_steps, checkpointing and auto-restarting on failure.

    Returns (params, opt_state, metrics_history, restarts).
    """
    saver = ckpt_lib.AsyncSaver()
    monitor = monitor or StepMonitor()
    restarts = 0
    history = []
    step = 0

    # resume if a checkpoint already exists
    existing = ckpt_lib.latest_step(ckpt_dir) if ckpt_dir else None
    if existing is not None:
        (params, opt_state), step = ckpt_lib.restore(
            ckpt_dir, (params, opt_state), shardings=shardings)
        log.info("resumed from step %d", step)

    while step < total_steps:
        try:
            while step < total_steps:
                batch = dataset.batch_at(step)
                t0 = time.monotonic()
                if fail_hook is not None:
                    fail_hook(step)
                params, opt_state, metrics = train_step(
                    params, opt_state, batch, step)
                jax.block_until_ready(metrics["loss"])
                dt = time.monotonic() - t0
                monitor.observe(step, dt)
                step += 1
                if step % log_every == 0 or step == total_steps:
                    history.append((step, float(metrics["loss"])))
                if ckpt_dir and step % save_every == 0:
                    saver.save(ckpt_dir, step, (params, opt_state))
            break
        except (RuntimeError, FloatingPointError) as e:  # node failure class
            restarts += 1
            log.warning("failure at step %d: %s (restart %d/%d)",
                        step, e, restarts, max_restarts)
            if restarts > max_restarts:
                raise
            saver.wait()
            latest = ckpt_lib.latest_step(ckpt_dir) if ckpt_dir else None
            if latest is None:
                step = 0  # restart from scratch
                continue
            (params, opt_state), step = ckpt_lib.restore(
                ckpt_dir, (params, opt_state), shardings=shardings)
            log.info("restored step %d", step)

    saver.wait()
    if ckpt_dir:
        ckpt_lib.save(ckpt_dir, step, (params, opt_state))
    return params, opt_state, history, restarts
