"""Docs gate for CI: doctests, link integrity, and flags-table drift.

Three checks, all of which must pass:

1. **Doctests** over the doc-bearing modules listed in ``DOCTEST_MODULES``
   (signature-level examples in the serve/kernel surface). The run also
   fails if the modules collectively contain zero doctests — an empty
   pass would make this gate decorative.
2. **Links**: every relative link/image in ``docs/``, the root README
   and the dist README must resolve to an existing file.
3. **Flags drift**: the ``launch/serve.py`` flags table in
   docs/ARCHITECTURE.md must list exactly the flags the parser exposes
   (``--help`` is the source of truth) — update both together.

Run:  PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import doctest
import importlib
import pathlib
import re
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

DOCTEST_MODULES = [
    "repro.serve.adapters",
    "repro.serve.engine",
    "repro.serve.decode",
    "repro.serve.speculative",
    "repro.serve.scheduler",
    "repro.launch.mesh",
    "repro.kernels.ops",
    "repro.core.axllm_linear",
    "repro.core.quantization",
]

DOC_FILES = [
    REPO / "README.md",
    REPO / "src" / "repro" / "dist" / "README.md",
    *sorted((REPO / "docs").glob("*.md")),
]

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
FLAG_ROW_RE = re.compile(r"^\|\s*`(--[^`]+)`")
HELP_FLAG_RE = re.compile(r"--[a-z][a-z0-9-]*")


def check_doctests() -> list:
    errors, attempted = [], 0
    for name in DOCTEST_MODULES:
        mod = importlib.import_module(name)
        res = doctest.testmod(mod, verbose=False)
        attempted += res.attempted
        if res.failed:
            errors.append(f"doctest: {res.failed} failure(s) in {name}")
    if not attempted:
        errors.append("doctest: zero doctests found across "
                      f"{len(DOCTEST_MODULES)} modules — the gate is empty")
    print(f"  doctests: {attempted} examples across "
          f"{len(DOCTEST_MODULES)} modules")
    return errors


def check_links() -> list:
    errors, n = [], 0
    for doc in DOC_FILES:
        text = doc.read_text()
        for m in LINK_RE.finditer(text):
            target = m.group(1).split("#")[0]
            if not target or target.startswith(("http://", "https://",
                                               "mailto:")):
                continue
            n += 1
            resolved = (doc.parent / target).resolve()
            if not resolved.exists():
                errors.append(f"link: {doc.relative_to(REPO)} -> {target} "
                              "does not exist")
    print(f"  links: {n} relative links across {len(DOC_FILES)} files")
    return errors


def documented_flags(arch_md: pathlib.Path) -> set:
    """Flags from the ARCHITECTURE.md table (rows like ``| `--arch` | ...``;
    combined cells like ``--fuse-qkv` / `--no-fuse-qkv`` list both)."""
    flags = set()
    in_table = False
    for line in arch_md.read_text().splitlines():
        if FLAG_ROW_RE.match(line):
            in_table = True
            cell = line.split("|")[1]
            flags.update(HELP_FLAG_RE.findall(cell))
        elif in_table and not line.startswith("|"):
            in_table = False
    return flags


def check_flags_drift() -> list:
    arch_md = REPO / "docs" / "ARCHITECTURE.md"
    if not arch_md.exists():
        return ["flags: docs/ARCHITECTURE.md missing"]
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--help"],
        capture_output=True, text=True, cwd=REPO)
    if proc.returncode != 0:
        return [f"flags: `serve --help` failed:\n{proc.stderr[-500:]}"]
    actual = set(HELP_FLAG_RE.findall(proc.stdout)) - {"--help"}
    documented = documented_flags(arch_md)
    errors = []
    for missing in sorted(actual - documented):
        errors.append(f"flags: {missing} exists in launch/serve.py but is "
                      "not documented in docs/ARCHITECTURE.md")
    for stale in sorted(documented - actual):
        errors.append(f"flags: {stale} documented in docs/ARCHITECTURE.md "
                      "but launch/serve.py no longer exposes it")
    print(f"  flags: {len(actual)} parser flags vs {len(documented)} "
          "documented")
    return errors


def main() -> int:
    errors = []
    print("check_docs: doctests")
    errors += check_doctests()
    print("check_docs: links")
    errors += check_links()
    print("check_docs: launch/serve.py flags table")
    errors += check_flags_drift()
    if errors:
        print(f"\nFAIL ({len(errors)}):")
        for e in errors:
            print(f"  - {e}")
        return 1
    print("\nOK: docs checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
