"""Bench regression gate for CI: fresh serve throughput vs checked-in floors.

Compares the ``tokens_per_sec`` of the base decode modes in a freshly
written ``BENCH_serve.json`` against ``benchmarks/serve_floors.json`` and
fails when a mode regresses more than ``GRACE`` (20%) below its floor.
Floors are deliberately conservative (roughly a quarter of a warm local
run) because CI runners are slower and noisier than dev machines — the
gate exists to catch structural regressions (a dispatch sneaking back into
the decode hot loop, a donation lost, an accidental recompile per step),
not single-digit jitter. The shared-prefix prefill speedup is gated as a
*ratio*, which is machine-independent.

Run:  PYTHONPATH=src python tools/check_bench.py [BENCH_serve.json]

Updating floors: when a legitimate change moves steady-state throughput,
re-run ``benchmarks/serve_bench.py --smoke`` locally and set each floor to
roughly a quarter of the new local tok/s (keep the ratio floors as-is
unless the workload itself changed).
"""

from __future__ import annotations

import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
FLOORS = REPO / "benchmarks" / "serve_floors.json"
GRACE = 0.20          # allowed shortfall below a floor before failing


def check(bench_path: pathlib.Path) -> list:
    floors = json.loads(FLOORS.read_text())
    fresh = json.loads(bench_path.read_text())
    errors = []
    for mode, floor in floors["tokens_per_sec"].items():
        row = fresh.get("modes", {}).get(mode)
        if row is None:
            errors.append(f"mode {mode!r} has a floor but is missing from "
                          f"{bench_path.name}")
            continue
        got = row["tokens_per_sec"]
        bar = floor * (1.0 - GRACE)
        verdict = "OK" if got >= bar else "FAIL"
        print(f"  {mode}: {got:.1f} tok/s vs floor {floor} "
              f"(bar {bar:.1f}) {verdict}")
        if got < bar:
            errors.append(f"{mode}: {got:.1f} tok/s is >20% below the "
                          f"checked-in floor {floor}")
    for name, floor in floors.get("ratios", {}).items():
        got = fresh
        for key in name.split("."):
            got = got.get(key, {}) if isinstance(got, dict) else {}
        if not isinstance(got, (int, float)):
            errors.append(f"ratio {name!r} missing from {bench_path.name}")
            continue
        verdict = "OK" if got >= floor else "FAIL"
        print(f"  {name}: {got} vs floor {floor} {verdict}")
        if got < floor:
            errors.append(f"{name}: {got} fell below its floor {floor}")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    bench = pathlib.Path(argv[0]) if argv else REPO / "BENCH_serve.json"
    if not bench.exists():
        print(f"check_bench: {bench} not found — run "
              "benchmarks/serve_bench.py --smoke first")
        return 1
    print(f"check_bench: {bench.name} vs {FLOORS.relative_to(REPO)}")
    errors = check(bench)
    if errors:
        print(f"\nFAIL ({len(errors)}):")
        for e in errors:
            print(f"  - {e}")
        return 1
    print("\nOK: serve throughput at or above floors")
    return 0


if __name__ == "__main__":
    sys.exit(main())
