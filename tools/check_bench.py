"""Bench regression gate for CI: fresh serve throughput vs checked-in floors,
plus provenance-matched kernel-bench checks.

Compares the ``tokens_per_sec`` of the base decode modes in a freshly
written ``BENCH_serve.json`` against ``benchmarks/serve_floors.json`` and
fails when a mode regresses more than ``GRACE`` (20%) below its floor.
Floors are deliberately conservative (roughly a quarter of a warm local
run) because CI runners are slower and noisier than dev machines — the
gate exists to catch structural regressions (a dispatch sneaking back into
the decode hot loop, a donation lost, an accidental recompile per step),
not single-digit jitter. The shared-prefix prefill speedup is gated as a
*ratio*, which is machine-independent, as is the speculative
accepted-tokens-per-step ratio (> 1 means drafting pays for itself).
``flags`` entries are exact-match booleans with no grace — the speculative
``identical_output`` provenance tag must be True, because greedy
speculative decoding is bit-identical to target-only greedy by
construction and any mismatch is a correctness bug. ``ceilings`` entries
gate latency-style metrics from above — the open-loop steady p99 TTFT must not
drift past its ceiling (+20% grace), catching admission/preemption paths
that start stalling requests.

The kernel side gates ``BENCH_kernel.json`` (when present) against
``benchmarks/kernel_floors.json``. Kernel rows carry {impl, backend, units}
provenance (benchmarks.common.row); the gate refuses to compare rows whose
provenance disagrees on the fields a check lists in ``match`` — the bug
this fixes is a CPU ``impl="ref"`` timing silently standing in for a Pallas
kernel result. Floors additionally pin the impl/units a row must carry.
The reuse floors gate the paper's core claim: the achieved
multiply-reduction measured *by the kernel* must stay above its floor and
within ``max_abs_diff`` of the simulator's predicted reuse rate.

Run:  PYTHONPATH=src python tools/check_bench.py [BENCH_serve.json]

Updating floors: when a legitimate change moves steady-state throughput,
re-run ``benchmarks/serve_bench.py --smoke`` locally and set each floor to
roughly a quarter of the new local tok/s (keep the ratio floors as-is
unless the workload itself changed).
"""

from __future__ import annotations

import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
FLOORS = REPO / "benchmarks" / "serve_floors.json"
KERNEL_FLOORS = REPO / "benchmarks" / "kernel_floors.json"
GRACE = 0.20          # allowed shortfall below a floor before failing


def check(bench_path: pathlib.Path) -> list:
    floors = json.loads(FLOORS.read_text())
    fresh = json.loads(bench_path.read_text())
    errors = []
    for mode, floor in floors["tokens_per_sec"].items():
        row = fresh.get("modes", {}).get(mode)
        if row is None:
            errors.append(f"mode {mode!r} has a floor but is missing from "
                          f"{bench_path.name}")
            continue
        got = row["tokens_per_sec"]
        bar = floor * (1.0 - GRACE)
        verdict = "OK" if got >= bar else "FAIL"
        print(f"  {mode}: {got:.1f} tok/s vs floor {floor} "
              f"(bar {bar:.1f}) {verdict}")
        if got < bar:
            errors.append(f"{mode}: {got:.1f} tok/s is >20% below the "
                          f"checked-in floor {floor}")
    for name, floor in floors.get("ratios", {}).items():
        got = _lookup(fresh, name)
        if not isinstance(got, (int, float)):
            errors.append(f"ratio {name!r} missing from {bench_path.name}")
            continue
        verdict = "OK" if got >= floor else "FAIL"
        print(f"  {name}: {got} vs floor {floor} {verdict}")
        if got < floor:
            errors.append(f"{name}: {got} fell below its floor {floor}")
    # ceilings bound latency-style metrics from above (e.g. the open-loop
    # steady p99 TTFT): a value drifting past ceiling*(1+GRACE) means the
    # admission/preemption path started stalling requests
    # flags are exact-match booleans (no grace): provenance tags like the
    # speculative identical_output bit, where any mismatch is a correctness
    # bug rather than a performance regression
    for name, want in floors.get("flags", {}).items():
        got = _lookup(fresh, name)
        if not isinstance(got, bool):
            errors.append(f"flag {name!r} missing from {bench_path.name}")
            continue
        verdict = "OK" if got == want else "FAIL"
        print(f"  {name}: {got} (want {want}) {verdict}")
        if got != want:
            errors.append(f"{name}: {got}, expected exactly {want}")
    for name, ceiling in floors.get("ceilings", {}).items():
        got = _lookup(fresh, name)
        if not isinstance(got, (int, float)):
            errors.append(f"ceiling {name!r} missing from {bench_path.name}")
            continue
        bar = ceiling * (1.0 + GRACE)
        verdict = "OK" if got <= bar else "FAIL"
        print(f"  {name}: {got} vs ceiling {ceiling} (bar {bar:.4g}) "
              f"{verdict}")
        if got > bar:
            errors.append(f"{name}: {got} is >20% above the checked-in "
                          f"ceiling {ceiling}")
    return errors


def _lookup(report: dict, dotted: str):
    """Walk a dotted path ('open_loop.steady.ttft_s.p99') into the report."""
    got = report
    for key in dotted.split("."):
        got = got.get(key, {}) if isinstance(got, dict) else {}
    return got


def _kernel_rows(report: dict) -> dict:
    """name -> (value, meta) for every persisted kernel_bench row.

    Rows are ``[name, value, derived]`` or ``[..., meta]`` where meta is
    the {impl, backend, units} provenance dict; legacy rows get {}.
    """
    out = {}
    for rows in report.get("rows", {}).values():
        for r in rows:
            meta = r[3] if len(r) > 3 and isinstance(r[3], dict) else {}
            out[r[0]] = (float(r[1]), meta)
    return out


def check_kernel(bench_path: pathlib.Path) -> list:
    """Gate BENCH_kernel.json rows against kernel_floors.json.

    Floors compare a row's value only after its provenance matches the
    floor's pinned impl/units; pairs compare two rows only when every
    field listed in ``match`` agrees between them.
    """
    floors = json.loads(KERNEL_FLOORS.read_text())
    rows = _kernel_rows(json.loads(bench_path.read_text()))
    errors = []
    for name, spec in floors.get("values", {}).items():
        if name not in rows:
            errors.append(f"kernel row {name!r} has a floor but is missing "
                          f"from {bench_path.name}")
            continue
        value, meta = rows[name]
        bad = [f"{k}={meta.get(k)!r} (want {spec[k]!r})"
               for k in ("impl", "backend", "units")
               if k in spec and meta.get(k) != spec[k]]
        if bad:
            errors.append(f"{name}: provenance mismatch — {'; '.join(bad)}")
            continue
        verdict = "OK" if value >= spec["floor"] else "FAIL"
        print(f"  {name}: {value} vs floor {spec['floor']} "
              f"[{meta.get('impl')}/{meta.get('backend')}/"
              f"{meta.get('units')}] {verdict}")
        if value < spec["floor"]:
            errors.append(f"{name}: {value} fell below its floor "
                          f"{spec['floor']}")
    for pair in floors.get("pairs", []):
        a, b = pair["a"], pair["b"]
        missing = [n for n in (a, b) if n not in rows]
        if missing:
            errors.append(f"pair {pair['name']!r}: missing rows {missing}")
            continue
        (va, ma), (vb, mb) = rows[a], rows[b]
        drift = [f"{k}: {ma.get(k)!r} vs {mb.get(k)!r}"
                 for k in pair.get("match", []) if ma.get(k) != mb.get(k)]
        if drift:
            errors.append(f"pair {pair['name']!r}: provenance drift — "
                          f"{'; '.join(drift)} (rows are not comparable)")
            continue
        tol = pair.get("max_abs_diff")
        diff = abs(va - vb)
        if tol is not None and diff > tol:
            print(f"  {pair['name']}: |{va} - {vb}| = {diff:.4g} "
                  f"> tol {tol} FAIL")
            errors.append(f"pair {pair['name']!r}: |{a} - {b}| = {diff:.4g}"
                          f" exceeds max_abs_diff {tol}")
        else:
            extra = f", |diff| = {diff:.4g} <= {tol}" if tol is not None \
                else ""
            print(f"  {pair['name']}: provenance matched{extra} OK")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    bench = pathlib.Path(argv[0]) if argv else REPO / "BENCH_serve.json"
    if not bench.exists():
        print(f"check_bench: {bench} not found — run "
              "benchmarks/serve_bench.py --smoke first")
        return 1
    print(f"check_bench: {bench.name} vs {FLOORS.relative_to(REPO)}")
    errors = check(bench)
    kernel_bench = REPO / "BENCH_kernel.json"
    if kernel_bench.exists():
        print(f"check_bench: {kernel_bench.name} vs "
              f"{KERNEL_FLOORS.relative_to(REPO)}")
        errors += check_kernel(kernel_bench)
    else:
        print("check_bench: BENCH_kernel.json not present — kernel gate "
              "skipped")
    if errors:
        print(f"\nFAIL ({len(errors)}):")
        for e in errors:
            print(f"  - {e}")
        return 1
    print("\nOK: serve throughput and kernel rows at or above floors")
    return 0


if __name__ == "__main__":
    sys.exit(main())
