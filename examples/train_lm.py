"""End-to-end training driver: the repro-100m dense LM for a few hundred
steps on the byte-level corpus (this repository's own sources), with
checkpointing, crash resilience, straggler monitoring, and a final export of
the quantized weight codes for the Fig. 8 reuse-rate cross-check on REAL
trained weights (benchmarks/fig8_reuse_rate.py picks the export up).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--small]
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.axllm_linear import deploy_quantize
from repro.core.quantization import QTensor, QuantConfig, decode_codes
from repro.data.pipeline import make_dataset
from repro.models.model import get_model
from repro.optim import adamw
from repro.train.fault_tolerance import StepMonitor, resilient_train
from repro.train.loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="4-layer variant for quick runs")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="results/train_lm/ckpt")
    args = ap.parse_args()

    cfg = get_config("repro-100m")
    if args.small:
        cfg = cfg.reduced(vocab_size=256, d_model=256, n_layers=4,
                          d_ff=512, n_heads=4, n_kv_heads=2)
    else:
        import dataclasses
        cfg = dataclasses.replace(cfg, vocab_size=256, dtype="float32")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params), "
          f"byte-level corpus")

    ocfg = adamw.AdamWConfig(lr=3e-4, int8_moments=False)
    opt = adamw.init(params, ocfg)
    step_jit = jax.jit(make_train_step(api, ocfg, total_steps=args.steps,
                                       warmup=20))

    def step_fn(p, o, batch, s):
        return step_jit(p, o, jax.tree_util.tree_map(jnp.asarray, batch), s)

    ds = make_dataset(cfg, batch=args.batch, seq=args.seq, seed=0,
                      source="bytes")
    monitor = StepMonitor()
    params, opt, history, restarts = resilient_train(
        train_step=step_fn, params=params, opt_state=opt, dataset=ds,
        ckpt_dir=args.ckpt, total_steps=args.steps, save_every=50,
        monitor=monitor, log_every=10)
    for s, l in history[-5:]:
        print(f"  step {s:4d}  loss {l:.3f}")
    print(f"restarts: {restarts}, stragglers flagged: {len(monitor.events)}")

    # export quantized codes of the trained weights for the Fig. 8 benchmark
    qparams = deploy_quantize(params, QuantConfig())
    out = {}
    for name in ("gate", "up", "down"):
        w = qparams["layers"]["ffn"][name]
        if isinstance(w, QTensor):
            out[f"ffn_{name}"] = np.asarray(decode_codes(w))[0]
    os.makedirs("results/train_lm", exist_ok=True)
    np.savez("results/train_lm/quantized_codes.npz", **out)
    print("exported trained quantized codes -> "
          "results/train_lm/quantized_codes.npz")


if __name__ == "__main__":
    main()
