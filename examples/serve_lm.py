"""Batched serving example: loads the examples/train_lm.py checkpoint if one
exists (otherwise a fresh model), deploys it through the AxLLM int8 path,
and runs a stream of batched requests through the continuous-batching engine
— comparing tokens/step and agreement between the bf16 and AxLLM paths.

Uses the current ServeEngine contract: chunked on-device decode
(`decode_chunk` scan steps per dispatch) and the scheduler stats surface
(`eng.stats`). See docs/ARCHITECTURE.md for the full contract.

Run:  PYTHONPATH=src python examples/serve_lm.py
      (SMOKE=1 trims the request budget for CI)
"""

import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import get_model
from repro.serve.engine import ServeEngine
from repro.train import checkpoint as C


def main():
    import dataclasses
    cfg = dataclasses.replace(get_config("repro-100m"), vocab_size=256,
                              dtype="float32")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    ckpt_dir = "results/train_lm/ckpt"
    if C.latest_step(ckpt_dir or "") is not None:
        from repro.optim import adamw
        opt = adamw.init(params, adamw.AdamWConfig())
        (params, _), step = C.restore(ckpt_dir, (params, opt))
        print(f"loaded checkpoint at step {step}")
    else:
        print("no checkpoint found — serving the random-init model "
              "(run examples/train_lm.py first for meaningful text)")

    prompts = [np.frombuffer(s, dtype=np.uint8).astype(np.int32)
               for s in (b"def main():", b"import nump", b"class Model",
                         b"return self", b"for i in ra", b"print(f\"st")]
    prompts = [p[:11] for p in prompts]

    max_new = 8 if os.environ.get("SMOKE") else 24
    results = {}
    for label, quant in (("bf16", False), ("axllm-int8", True)):
        eng = ServeEngine(cfg, params, n_slots=4, max_len=128,
                          quantize=quant)
        t0 = time.time()
        outs = eng.generate(prompts, max_new=max_new)
        dt = time.time() - t0
        results[label] = outs
        toks = sum(len(o) for o in outs)
        st = eng.stats
        print(f"[{label}] {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.1f} tok/s on CPU fallback; "
              f"{st.decode_chunks} decode dispatches for {st.steps} device "
              f"steps, occupancy {st.mean_occupancy:.2f})")

    agree = np.mean([a == b
                     for A, B in zip(results["bf16"], results["axllm-int8"])
                     for a, b in zip(A, B)])
    print(f"greedy-token agreement bf16 vs AxLLM-int8: {agree:.2%}")
    for p, o in zip(prompts, results["axllm-int8"]):
        txt = bytes(p.tolist()).decode(errors="replace") + "|" + \
            bytes([min(max(t, 0), 255) for t in o]).decode(errors="replace")
        print("  " + repr(txt))


if __name__ == "__main__":
    main()
