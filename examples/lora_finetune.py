"""LoRA fine-tune → register → serve walkthrough (paper §III + Fig. 5).

Takes a base dense LM, freezes it, trains rank-16 adapters on the attention
projections against a shifted data distribution, then:
  1. verifies merged-adapter equivalence,
  2. checks the quantized-base combined path on one layer,
  3. measures the paper's Fig. 5 statistic on the REAL trained A matrices:
     the fraction of A-row values already present in the corresponding W row
     (paper: ~90%), and the adapter-matrix speedup from combined reuse
     (paper: ~1.8x), and
  4. registers the trained adapters in an AdapterRegistry and serves a
     mixed base + LoRA request stream through the continuous-batching
     ServeEngine on the AxLLM int8 path — the dual-pipeline serving
     story: frozen quantized base, bf16 low-rank deltas, no parameter
     rewrites.

Run:  PYTHONPATH=src python examples/lora_finetune.py
      (SMOKE=1 trims the training loop for CI)
"""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import axllm_linear as AL
from repro.core import reuse, simulator
from repro.core.quantization import QuantConfig, decode_codes, quantize
from repro.data.pipeline import make_dataset
from repro.models import attention as ATT
from repro.models.model import get_model
from repro.optim import adamw


def main():
    cfg = ModelConfig(name="lora-base", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=256, head_dim=32, vocab_pad_multiple=64,
                      dtype="float32")
    api = get_model(cfg)
    base = api.init(jax.random.PRNGKey(0))
    lcfg = AL.LoRAConfig(rank=16, alpha=32.0)

    # adapters for wq/wv of every layer (trainable); base frozen
    rng = jax.random.PRNGKey(1)
    adapters = {}
    d, h, hk, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim)
    for tgt, n_out in (("wq", h * hd), ("wv", hk * hd)):
        keys = jax.random.split(jax.random.fold_in(rng, hash(tgt) % 997),
                                cfg.n_layers)
        adapters[tgt] = jax.vmap(
            lambda k: AL.lora_init(k, d, n_out, lcfg))(keys)

    def apply_adapters(base_params, ads):
        """Fold adapters into effective weights (merge-apply formulation —
        equivalent to the runtime combined path, convenient for jax.grad)."""
        layers = dict(base_params["layers"])
        attn = dict(layers["attn"])
        for tgt, ad in ads.items():
            delta = jnp.einsum("lik,lkj->lij", ad["lora_a"], ad["lora_b"])
            attn[tgt] = attn[tgt] + lcfg.scaling * delta
        layers["attn"] = attn
        return dict(base_params, layers=layers)

    def loss_fn(ads, batch):
        return api.loss(apply_adapters(base, ads), batch)

    ocfg = adamw.AdamWConfig(lr=1e-3, weight_decay=0.0)
    opt = adamw.init(adapters, ocfg)
    # fine-tuning distribution: different seed/bigram structure
    ds = make_dataset(cfg, batch=16, seq=64, seed=1234)

    @jax.jit
    def step(ads, opt_state, batch, s):
        loss, g = jax.value_and_grad(loss_fn)(ads, batch)
        ads, opt_state, _ = adamw.update(ads, g, opt_state, ocfg, 1.0)
        return ads, opt_state, loss

    n_steps = 8 if os.environ.get("SMOKE") else 60
    for s in range(n_steps):
        b = jax.tree_util.tree_map(jnp.asarray, ds.batch_at(s))
        adapters, opt, loss = step(adapters, opt, b, s)
        if s % 20 == 0:
            print(f"step {s:3d}  adapter loss {float(loss):.3f}")

    # 1) merge equivalence on one layer
    w0 = base["layers"]["attn"]["wq"][0]
    ad0 = jax.tree_util.tree_map(lambda a: a[0], adapters["wq"])
    x = jax.random.normal(jax.random.PRNGKey(3), (4, cfg.d_model))
    y_rt = AL.lora_linear(x, w0, ad0, lcfg)
    y_merged = x @ AL.merge_lora(w0, ad0, lcfg)
    print("merge equivalence max err:",
          float(jnp.max(jnp.abs(y_rt - y_merged))))

    # 2) quantized base + adapters (Fig. 5 combined path)
    qt = quantize(w0, QuantConfig())
    y_q = AL.lora_linear(x, qt, ad0, lcfg, impl="ref")
    print("quantized-base LoRA output delta vs fp:",
          float(jnp.max(jnp.abs(y_q - y_rt))))

    # 3) Fig. 5 reuse statistics on the TRAINED adapter
    w_codes = np.asarray(decode_codes(qt)).astype(np.int32)
    a_q = quantize(ad0["lora_a"], QuantConfig())
    a_codes = np.asarray(decode_codes(a_q)).astype(np.int32)
    overlap = reuse.lora_row_overlap(w_codes, a_codes)
    sim = simulator.simulate_lora(w_codes, a_codes, simulator.SimConfig())
    print(f"A-row overlap with W rows: {overlap:.3f}  (paper: ~0.90)")
    print(f"adapter-matrix speedup via combined [W|A] reuse: "
          f"{sim['adapter_speedup']:.2f}x  (paper: ~1.8x)")

    # 4) register the trained adapters and serve a mixed stream through the
    # continuous-batching engine (train -> register -> serve). The trained
    # per-target layout {"lora_a": [n_layers, d, r], "lora_b": [n_layers,
    # r, n_out]} is exactly what the registry stacks.
    from repro.serve.adapters import AdapterRegistry
    from repro.serve.engine import ServeEngine

    reg = AdapterRegistry(cfg, lcfg, max_loras=2)
    reg.add("tuned", adapters)
    eng = ServeEngine(cfg, base, n_slots=2, max_len=64, quantize=True,
                      adapters=reg)
    prompts = [np.arange(8), np.arange(8) + 40, np.arange(8) + 90,
               np.arange(8) + 130]
    names = [None, "tuned", None, "tuned"]
    outs = eng.generate(prompts, max_new=12, adapters=names)
    print(f"served {len(outs)} requests (base + LoRA mixed), "
          f"{eng.stats.lora_requests} on the adapter, "
          f"occupancy {eng.stats.mean_occupancy:.2f}")

    # the engine's LoRA rows match serving the merged weights directly
    merged_eng = ServeEngine(cfg, apply_adapters(base, adapters), n_slots=2,
                             max_len=64, quantize=True)
    merged = merged_eng.generate([p for p, n in zip(prompts, names) if n],
                                 max_new=12)
    served = [o for o, n in zip(outs, names) if n]
    agree = np.mean([a == b for A, B in zip(served, merged)
                     for a, b in zip(A, B)])
    print(f"engine LoRA rows vs merged-weights engine: {agree:.2%} "
          f"greedy-token agreement (runtime delta vs merged; int8 base)")


if __name__ == "__main__":
    main()
