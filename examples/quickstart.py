"""Quickstart: the AxLLM pipeline in one page.

Builds a small dense LM, trains it briefly on synthetic text, converts it
post-training to the AxLLM int8 representation (zero setup time — paper §I),
serves a batch of prompts through the fused dequant-matmul path, and prints
the paper's headline statistics (reuse rate, simulated speedup) measured on
THIS model's actual weights.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import reuse, simulator
from repro.core.axllm_linear import deploy_quantize
from repro.core.quantization import QTensor, QuantConfig, decode_codes
from repro.data.pipeline import make_dataset
from repro.models.model import get_model
from repro.optim import adamw
from repro.serve.engine import ServeEngine
from repro.train.loop import make_train_step


def main():
    cfg = ModelConfig(name="quickstart", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=256, head_dim=32, vocab_pad_multiple=64,
                      dtype="float32")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))

    # -- short training run ---------------------------------------------------
    ocfg = adamw.AdamWConfig(lr=2e-3)
    opt = adamw.init(params, ocfg)
    step = jax.jit(make_train_step(api, ocfg, total_steps=60, warmup=5))
    ds = make_dataset(cfg, batch=16, seq=64, seed=0)
    for s in range(40):
        batch = jax.tree_util.tree_map(jnp.asarray, ds.batch_at(s))
        params, opt, m = step(params, opt, batch, s)
        if s % 10 == 0:
            print(f"step {s:3d}  loss {float(m['loss']):.3f}")

    # -- post-training AxLLM conversion (the paper's deployment story) --------
    qparams = deploy_quantize(params, QuantConfig(bits=8))
    w = qparams["layers"]["ffn"]["up"]
    assert isinstance(w, QTensor)
    codes = np.asarray(decode_codes(w))[0]
    print(f"\nreuse rate of a trained FFN matrix "
          f"(256-entry buffers): {reuse.reuse_rate(codes, 256):.3f}")
    rep = simulator.simulate_matrix(codes.astype(np.int32),
                                    simulator.SimConfig())
    print(f"simulated AxLLM speedup on that matrix: {rep.speedup:.2f}x "
          f"(paper average: 1.7x)")

    # -- serve through the quantized path --------------------------------------
    eng = ServeEngine(cfg, params, n_slots=4, max_len=128, quantize=True)
    prompts = [np.arange(16) + i for i in range(4)]
    outs = eng.generate(prompts, max_new=12)
    print("\ngenerated continuations (int8 AxLLM path):")
    for p, o in zip(prompts, outs):
        print(f"  {list(p[:6])}... -> {o}")


if __name__ == "__main__":
    main()
