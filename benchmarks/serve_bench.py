"""Continuous-batching serve throughput benchmark -> BENCH_serve.json.

Drives the ServeEngine scheduler step-by-step over a mixed-length synthetic
request stream (ragged prefill waves) in both bf16 and AxLLM-int8 modes and
records the throughput/occupancy trajectory:

  PYTHONPATH=src python benchmarks/serve_bench.py --smoke --out BENCH_serve.json

CI runs --smoke on every push and uploads the JSON artifact, so the serving
perf trajectory accumulates per-commit. Also exposes the harness-standard
``run() -> [(name, us_per_call, derived)]`` used by benchmarks.run.
"""

from __future__ import annotations

import argparse
import json
import time

SMOKE = dict(d_model=64, n_layers=2, vocab=256, n_slots=2, max_len=64,
             requests=6, max_new=4, prompt_lens=(8, 12, 31))
FULL = dict(d_model=128, n_layers=4, vocab=512, n_slots=8, max_len=256,
            requests=48, max_new=32, prompt_lens=(8, 12, 31, 64, 96))


def _build(p):
    import jax
    from repro.configs.base import ModelConfig
    from repro.models.model import get_model

    cfg = ModelConfig(name="serve-bench", family="dense",
                      n_layers=p["n_layers"], d_model=p["d_model"],
                      n_heads=4, n_kv_heads=2, d_ff=2 * p["d_model"],
                      vocab_size=p["vocab"], head_dim=16,
                      vocab_pad_multiple=64, dtype="float32")
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _serve(cfg, params, p, quantize: bool):
    import numpy as np
    from repro.serve.engine import ServeEngine

    def submit_stream(eng):
        rng = np.random.default_rng(0)
        lens = p["prompt_lens"]
        for i in range(p["requests"]):
            eng.submit(rng.integers(0, cfg.vocab_size,
                                    size=lens[i % len(lens)])
                       .astype(np.int32), max_new=p["max_new"])

    # untimed warmup pass: the timed engine inherits the jitted
    # prefill-bucket/decode/writer callables, so the trajectory below is
    # compile-free steady state
    warm = ServeEngine(cfg, params, n_slots=p["n_slots"],
                       max_len=p["max_len"], quantize=quantize)
    submit_stream(warm)
    warm.run()
    eng = ServeEngine(cfg, params, n_slots=p["n_slots"],
                      max_len=p["max_len"], quantize=quantize)
    eng._prefill_cache = warm._prefill_cache
    eng._decode = warm._decode
    eng._writer = warm._writer
    submit_stream(eng)

    traj = []
    t0 = time.perf_counter()
    decoded = 0
    while eng.step():
        traj.append({
            "step": eng.stats.steps,
            "active": eng.stats.decode_tokens - decoded,  # slots decoded
            "queued": len(eng.queue),
        })
        decoded = eng.stats.decode_tokens
    wall = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in eng.finished)
    return {
        "wall_s": round(wall, 4),
        "generated_tokens": toks,
        "tokens_per_sec": round(toks / wall, 2) if wall else 0.0,
        "stats": eng.stats.as_dict(),
        "trajectory": traj,
    }


def bench(smoke: bool = True) -> dict:
    p = SMOKE if smoke else FULL
    cfg, params = _build(p)
    report = {
        "smoke": smoke,
        "workload": {k: (list(v) if isinstance(v, tuple) else v)
                     for k, v in p.items()},
        "modes": {},
    }
    for label, quant in (("bf16", False), ("axllm-int8", True)):
        report["modes"][label] = _serve(cfg, params, p, quant)
    return report


def run():
    """benchmarks.run harness entry."""
    rep = bench(smoke=True)
    rows = []
    for label, m in rep["modes"].items():
        us = 1e6 * m["wall_s"] / max(m["generated_tokens"], 1)
        rows.append((f"serve/{label}", us,
                     f"tok/s={m['tokens_per_sec']};"
                     f"occ={m['stats']['mean_occupancy']:.2f}"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (seconds, not minutes)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    rep = bench(smoke=args.smoke)
    rep["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    with open(args.out, "w") as f:
        json.dump(rep, f, indent=2, sort_keys=True)
    for label, m in rep["modes"].items():
        print(f"[{label}] {m['generated_tokens']} tokens "
              f"{m['tokens_per_sec']} tok/s "
              f"occupancy {m['stats']['mean_occupancy']:.2f} "
              f"({m['stats']['steps']} steps)")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
