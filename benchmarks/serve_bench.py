"""Continuous-batching serve throughput benchmark -> BENCH_serve.json.

Drives the ServeEngine scheduler over a mixed-length synthetic request
stream on the repro_100m config (its CPU-scale ``reduced()`` variant — the
full 100M-parameter model does not fit a CI time budget) and records the
throughput/occupancy trajectory across the serving modes that matter for
the decode hot path:

  - bf16 vs AxLLM-int8 weights (the paper's deployment conversion)
  - decode_chunk=1 (per-token host round-trip) vs decode_chunk=8 (one
    on-device lax.scan dispatch per 8 tokens) — ``decode_chunk_speedup``
    records tok/s(chunk8) / tok/s(chunk1) per mode
  - fused wqkv/gate_up projections on top of int8 + chunked decode
  - multi-LoRA serving: the same int8/chunk8 engine with an
    AdapterRegistry holding 2 synthetic adapters, requests cycling
    base/adapter0/adapter1 — the ``multi_lora`` row records the tok/s
    overhead of the gathered delta pipeline vs the base-only engine
    (paper's dual-pipeline claim: the base path is untouched, so the
    overhead is just the low-rank einsums + gather)
  - paged KV cache: the int8/chunk8 engine on the block-paged pool
    (decode reads through block tables) — tok/s parity with dense shows
    the indirection is free on the decode path
  - shared-prefix workload (``shared_prefix`` row): every request repeats
    one long system prompt with a short unique tail; the paged engine
    with prefix reuse prefills the shared head ONCE and only computes the
    tails (``prefix_hit_tokens``), so its *effective prefill throughput*
    (submitted prompt tokens / wall time inside prefill waves) must beat
    the dense engine by >= 1.5x — the serving-level payoff of the paper's
    computation-reuse principle
  - tensor-parallel serving (``axllm-int8/chunk8/meshN`` rows): the same
    int8/chunk8 engine under a 1xN ("data","model") mesh at N = --mesh
    sizes (default 1/2/8, forced host CPU devices). The meshN rows use a
    request stream sized to keep every slot occupied (occupancy ~= 1.0 in
    the recorded stats — see --requests/--prompt-pool); check_bench gates
    the mesh1 row against the single-device floor, proving the mesh path
    compiles to the same program at size 1. Sizes beyond the device count
    record a "skipped" row instead of failing.

  - open-loop arrivals (``open_loop`` rows): requests arrive on a Poisson
    clock (``--arrival poisson:<rate>``) decoupled from completions. The
    ``steady`` row offers ~60% of measured capacity and records p50/p99
    TTFT and inter-token latency; the ``overload`` row offers 3x capacity
    into a bounded queue under the reject admission policy with mixed
    priorities and a queue-wait deadline, recording the shed counters
    (rejected / expired / preempted) alongside the tail latencies —
    check_bench gates the steady p99 TTFT against a ceiling. Both rows
    serve under a chunked-prefill budget, which fixes the prefill wave
    shape: trickling sub-wave arrivals reuse the closed-loop warmup's
    compiled buckets (this replaced a per-arrival-pattern warmup sweep).

  - long-prompt interleave (``long_prompt_interleave`` row): a 4k-token
    prompt arrives while short streams are mid-decode, served once with
    a chunked-prefill budget and once without. Tokens are emitted through
    the streaming ``on_token`` callback and per-token gaps of the short
    streams recorded: the unbudgeted run eats the full monolithic prefill
    as one head-of-line stall, the budgeted run bounds it to one chunk.
    check_bench gates the budgeted p99 gap against a ceiling and the
    budgeted/unbudgeted throughput ratio against a floor.

  PYTHONPATH=src python benchmarks/serve_bench.py --smoke --out BENCH_serve.json

CI runs --smoke on every push and uploads the JSON artifact, so the serving
perf trajectory accumulates per-commit (tools/check_bench.py gates tok/s
regressions against benchmarks/serve_floors.json). Also exposes the
harness-standard ``run() -> [(name, us_per_call, derived)]`` used by
benchmarks.run.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

_LONG_PROMPT = dict(long_len=4096, long_max_new=8, short_len=16, n_short=3,
                    max_new=48, n_slots=4, max_len=4224, kv_block_size=16,
                    budget=512)

SMOKE = dict(n_slots=2, max_len=64, requests=6, max_new=16,
             prompt_lens=(8, 12, 31),
             shared_prefix=dict(prefix_len=96, suffix_len=8, requests=6,
                                max_new=8, max_len=128, kv_block_size=16),
             long_prompt=dict(_LONG_PROMPT))
FULL = dict(n_slots=4, max_len=256, requests=32, max_new=32,
            prompt_lens=(8, 12, 31, 64, 96),
            shared_prefix=dict(prefix_len=192, suffix_len=16, requests=16,
                               max_new=16, max_len=256, kv_block_size=16),
            long_prompt=dict(_LONG_PROMPT))

#: chunked-prefill budget for the open-loop rows: bounds every step's
#: prefill work AND fixes the budgeted wave shape (n_slots x budget
#: bucket), so trickling sub-wave arrivals hit the same compiled program
#: as the closed-loop warmup — no per-arrival-pattern warmup needed
OPEN_LOOP_PREFILL_BUDGET = 32

# (label, quantize, decode_chunk, fuse_qkv, n_loras, paged)
MODES = [
    ("bf16/chunk1", False, 1, False, 0, False),
    ("bf16/chunk8", False, 8, False, 0, False),
    ("axllm-int8/chunk1", True, 1, False, 0, False),
    ("axllm-int8/chunk8", True, 8, False, 0, False),
    ("axllm-int8/chunk8/fused", True, 8, True, 0, False),
    ("axllm-int8/chunk8/multi-lora", True, 8, False, 2, False),
    ("axllm-int8/chunk8/paged", True, 8, False, 0, True),
]

TRAJECTORY_CAP = 50     # max per-run trajectory points kept in the JSON


def _downsample(traj, cap: int = TRAJECTORY_CAP):
    """Thin a per-step trajectory to <= cap evenly spaced points (first and
    last kept) so BENCH_serve.json stays diff-reviewable."""
    if len(traj) <= cap:
        return traj
    idx = np.linspace(0, len(traj) - 1, cap).round().astype(int)
    return [traj[i] for i in dict.fromkeys(int(i) for i in idx)]


def _build():
    import jax
    from repro.configs.repro_100m import CONFIG
    from repro.models.model import get_model

    cfg = CONFIG.reduced(dtype="float32", remat=False)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _serve(cfg, params, p, quantize: bool, decode_chunk: int,
           fuse_qkv: bool, lora: int = 0, paged: bool = False, mesh=None):
    from repro.serve.engine import ServeEngine

    if lora:
        from repro.launch.serve import make_synthetic_adapters
        registry, names = make_synthetic_adapters(cfg, n=lora)
        cycle = [None] + names
    else:
        registry, cycle = None, [None]

    def submit_stream(eng):
        rng = np.random.default_rng(0)
        lens = p["prompt_lens"]
        for i in range(p["requests"]):
            eng.submit(rng.integers(0, cfg.vocab_size,
                                    size=lens[i % len(lens)])
                       .astype(np.int32), max_new=p["max_new"],
                       adapter=cycle[i % len(cycle)])

    def make():
        return ServeEngine(cfg, params, n_slots=p["n_slots"],
                           max_len=p["max_len"], quantize=quantize,
                           decode_chunk=decode_chunk, fuse_qkv=fuse_qkv,
                           adapters=registry, paged=paged,
                           kv_block_size=16, mesh=mesh)

    # untimed warmup pass: the timed engine inherits the jitted
    # prefill-bucket/chunk-decode/writer callables, so the trajectory below
    # is compile-free steady state
    warm = make()
    submit_stream(warm)
    warm.run()
    eng = make().adopt_compiled(warm)
    submit_stream(eng)

    traj = []
    t0 = time.perf_counter()
    decoded = 0
    while eng.step():
        traj.append({
            "step": eng.stats.steps,
            "tokens": eng.stats.decode_tokens - decoded,  # this chunk
            "queued": len(eng.queue),
        })
        decoded = eng.stats.decode_tokens
    wall = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in eng.finished)
    return {
        "wall_s": round(wall, 4),
        "generated_tokens": toks,
        "tokens_per_sec": round(toks / wall, 2) if wall else 0.0,
        "stats": eng.stats.as_dict(),
        "trajectory": _downsample(traj),
    }


def _serve_shared_prefix(cfg, params, sp: dict, n_slots: int, paged: bool):
    """Drive the shared-prefix workload (one long system prompt, short
    unique tails) and report effective prefill throughput: submitted
    prompt tokens per second of wall time spent inside prefill waves."""
    from repro.serve.engine import ServeEngine

    rng = np.random.default_rng(1)
    prefix = rng.integers(0, cfg.vocab_size, size=sp["prefix_len"])
    prompts = [np.concatenate(
        [prefix, rng.integers(0, cfg.vocab_size, size=sp["suffix_len"])])
        .astype(np.int32) for _ in range(sp["requests"])]

    def make():
        return ServeEngine(cfg, params, n_slots=n_slots,
                           max_len=sp["max_len"], quantize=True,
                           decode_chunk=8, paged=paged,
                           kv_block_size=sp["kv_block_size"])

    warm = make()
    for pr in prompts:
        warm.submit(pr, max_new=sp["max_new"])
    warm.run()
    eng = make().adopt_compiled(warm)          # fresh engine, empty index
    for pr in prompts:
        eng.submit(pr, max_new=sp["max_new"])
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    st = eng.stats
    prompt_tokens = sum(len(pr) for pr in prompts)
    toks = sum(len(r.tokens) for r in eng.finished)
    eff = prompt_tokens / st.prefill_wall_s if st.prefill_wall_s else 0.0
    return {
        "wall_s": round(wall, 4),
        "generated_tokens": toks,
        "tokens_per_sec": round(toks / wall, 2) if wall else 0.0,
        "submitted_prompt_tokens": prompt_tokens,
        "computed_prefill_tokens": st.prefill_tokens,
        "prefill_wall_s": round(st.prefill_wall_s, 4),
        "effective_prefill_tok_s": round(eff, 2),
        "prefix_hit_tokens": st.prefix_hit_tokens,
        "blocks_in_use": st.blocks_in_use,
        "cow_copies": st.cow_copies,
    }


def _pct(a) -> dict:
    """p50/p99 summary of a latency sample (rounded, None when empty)."""
    if not len(a):
        return {"p50": None, "p99": None}
    return {"p50": round(float(np.percentile(a, 50)), 4),
            "p99": round(float(np.percentile(a, 99)), 4)}


def _serve_open_loop(cfg, params, p, spec: str, label: str,
                     admission: str = "block", max_queue=None,
                     deadline_s=None, priorities=(0,), warm=None):
    """Open-loop arrivals: requests land on their own (Poisson or fixed)
    clock regardless of engine backlog, so queueing delay and shedding
    become visible — a closed-loop driver that only submits when slots
    free up can never overload the engine. Reports TTFT and
    inter-token-latency percentiles from the engine's per-request
    timestamps plus the rejected/expired/preempted shed counts."""
    from repro.serve.engine import ServeEngine
    from repro.serve.scheduler import arrival_times

    rng = np.random.default_rng(2)
    lens = p["prompt_lens"]
    n = p["requests"]
    prompts = [rng.integers(0, cfg.vocab_size, size=lens[i % len(lens)])
               .astype(np.int32) for i in range(n)]
    at = arrival_times(spec, n, seed=3)

    def make():
        # the prefill budget fixes the wave shape, so sub-wave arrival
        # patterns reuse the closed-loop warmup's compiled buckets
        return ServeEngine(cfg, params, n_slots=p["n_slots"],
                           max_len=p["max_len"], quantize=True,
                           decode_chunk=8, paged=True, kv_block_size=16,
                           max_queue=max_queue, admission=admission,
                           prefill_budget=OPEN_LOOP_PREFILL_BUDGET)

    if warm is None:
        warm = make()
        for pr in prompts:
            warm.submit(pr, max_new=p["max_new"])
        warm.run()
    eng = make().adopt_compiled(warm)
    i = 0
    t0 = time.perf_counter()
    while True:
        now = time.perf_counter() - t0
        while i < n and at[i] <= now:
            eng.submit(prompts[i], max_new=p["max_new"],
                       priority=priorities[i % len(priorities)],
                       deadline_s=deadline_s)
            i += 1
        if eng.step():
            continue
        if i >= n:
            break
        # drained before the next arrival: idle until it lands
        time.sleep(min(0.002, max(0.0, at[i] - (time.perf_counter() - t0))))
    wall = time.perf_counter() - t0
    done = [r for r in eng.finished
            if r.finish_reason not in ("rejected", "expired")]
    ttft = [r.t_first - r.t_submit for r in done if r.t_first is not None]
    itl = [(r.t_last - r.t_first) / (len(r.tokens) - 1) for r in done
           if r.t_first is not None and r.t_last is not None
           and len(r.tokens) > 1]
    toks = sum(len(r.tokens) for r in done)
    st = eng.stats
    return {
        "arrival": spec,
        "admission": admission,
        "max_queue": max_queue,
        "deadline_s": deadline_s,
        "wall_s": round(wall, 4),
        "generated_tokens": toks,
        "tokens_per_sec": round(toks / wall, 2) if wall else 0.0,
        "completed": len(done),
        "rejected": st.rejected,
        "expired": st.expired,
        "preempted": st.preempted,
        "restored": st.restored,
        "fast_restores": st.fast_restores,
        "ttft_s": _pct(ttft),
        "inter_token_s": _pct(itl),
    }, warm


def _serve_long_prompt_interleave(cfg, params, lp: dict, budget):
    """One multi-thousand-token prompt arrives while short streams are
    mid-decode. With a chunked-prefill ``budget`` the prompt is consumed
    in bounded chunks between decode chunks, so the running streams keep
    ticking; with ``budget=None`` it admits as a single monolithic
    prefill wave that stalls every stream for the full prompt. Reports
    the short streams' per-token gap percentiles (timestamps recorded by
    an ``on_token`` streaming callback — the gap spanning the long
    prompt's prefill is the head-of-line stall) plus total throughput."""
    from repro.serve.engine import ServeEngine

    rng = np.random.default_rng(4)
    shorts = [rng.integers(0, cfg.vocab_size, size=lp["short_len"])
              .astype(np.int32) for _ in range(lp["n_short"])]
    long_p = rng.integers(0, cfg.vocab_size,
                          size=lp["long_len"]).astype(np.int32)

    def make():
        return ServeEngine(cfg, params, n_slots=lp["n_slots"],
                           max_len=lp["max_len"], quantize=True,
                           decode_chunk=8, paged=True,
                           kv_block_size=lp["kv_block_size"],
                           prefill_budget=budget)

    def drive(eng):
        stamps = {}

        def on_token(req, tok):
            stamps.setdefault(req.rid, []).append(time.perf_counter())

        t0 = time.perf_counter()
        short_rids = [eng.submit(pr, max_new=lp["max_new"],
                                 on_token=on_token) for pr in shorts]
        # every short stream must be emitting before the long prompt
        # lands — the row measures interference with *running* decodes
        while not all(stamps.get(r) for r in short_rids):
            eng.step()
        eng.submit(long_p, max_new=lp["long_max_new"], on_token=on_token)
        while eng.step():
            pass
        wall = time.perf_counter() - t0
        toks = sum(len(r.tokens) for r in eng.finished)
        gaps = []
        for r in short_rids:
            ts = stamps[r]
            gaps.extend(float(b - a) for a, b in zip(ts, ts[1:]))
        return {
            "prefill_budget": budget,
            "wall_s": round(wall, 4),
            "generated_tokens": toks,
            "tokens_per_sec": round(toks / wall, 2) if wall else 0.0,
            "short_stream_gap_s": _pct(np.asarray(gaps)),
        }

    # warmup replays the identical workload (same lengths, same max_new)
    # so the timed run inherits every (wave, padded_len, blocks) bucket
    warm = make()
    for pr in shorts:
        warm.submit(pr, max_new=lp["max_new"])
    warm.run()
    warm.submit(long_p, max_new=lp["long_max_new"])
    warm.run()
    return drive(make().adopt_compiled(warm))


def _serve_speculative(cfg, params, p, spec_k: int = 4,
                       draft_bits: int = 4):
    """Self-speculative decoding row: the int8/chunk8 target engine with an
    int4 draft proposing ``spec_k`` tokens per round, against the same
    target-only engine on the identical request stream. Greedy speculative
    decoding is bit-identical to target-only greedy by construction, so
    ``identical_output`` doubles as a provenance tag — a False here means
    the accept/rollback path is broken, not that the workload drifted."""
    from repro.serve.engine import ServeEngine

    def submit_stream(eng):
        rng = np.random.default_rng(0)
        lens = p["prompt_lens"]
        for i in range(p["requests"]):
            eng.submit(rng.integers(0, cfg.vocab_size,
                                    size=lens[i % len(lens)])
                       .astype(np.int32), max_new=p["max_new"])

    def make(speculate):
        kw = dict(speculate=True, spec_k=spec_k,
                  draft_bits=draft_bits) if speculate else {}
        return ServeEngine(cfg, params, n_slots=p["n_slots"],
                           max_len=p["max_len"], quantize=True,
                           decode_chunk=8, **kw)

    def run_timed(speculate):
        warm = make(speculate)
        submit_stream(warm)
        warm.run()
        eng = make(speculate).adopt_compiled(warm)
        submit_stream(eng)
        t0 = time.perf_counter()
        eng.run()
        wall = time.perf_counter() - t0
        outs = [list(map(int, r.tokens))
                for r in sorted(eng.finished, key=lambda r: r.rid)]
        return wall, outs, eng.stats

    base_wall, base_outs, _ = run_timed(False)
    wall, outs, st = run_timed(True)
    toks = sum(len(t) for t in outs)
    base_toks = sum(len(t) for t in base_outs)
    return {
        "spec_k": spec_k,
        "draft_bits": draft_bits,
        "wall_s": round(wall, 4),
        "generated_tokens": toks,
        "tokens_per_sec": round(toks / wall, 2) if wall else 0.0,
        "target_only_tokens_per_sec":
            round(base_toks / base_wall, 2) if base_wall else 0.0,
        "drafted_tokens": st.drafted_tokens,
        "accepted_draft_tokens": st.accepted_draft_tokens,
        "acceptance_rate": round(st.acceptance_rate, 4),
        "accepted_tokens_per_step": round(st.accepted_tokens_per_step, 4),
        "identical_output": outs == base_outs,
    }


#: mesh sizes the meshN rows run at (1xN "data"/"model" host meshes)
MESH_SIZES = (1, 2, 8)


def bench(smoke: bool = True, requests: int = None, prompt_pool=None,
          mesh_sizes=MESH_SIZES, arrival: str = None) -> dict:
    from repro.launch.mesh import force_host_device_count, make_host_mesh

    # before the first jax computation: the CPU host-device forcing only
    # takes effect before backend init (no-op under pytest, whose conftest
    # already forces 8)
    if mesh_sizes:
        force_host_device_count(max(mesh_sizes))
    p = dict(SMOKE if smoke else FULL)
    if requests is not None:
        p["requests"] = requests
    if prompt_pool is not None:
        p["prompt_lens"] = tuple(prompt_pool)
    cfg, params = _build()
    report = {
        "smoke": smoke,
        "config": "repro-100m (reduced CPU-scale variant)",
        "workload": {k: (list(v) if isinstance(v, tuple) else v)
                     for k, v in p.items()},
        "modes": {},
        "decode_chunk_speedup": {},
    }
    for label, quant, chunk, fuse, lora, paged in MODES:
        report["modes"][label] = _serve(cfg, params, p, quant, chunk, fuse,
                                        lora=lora, paged=paged)
    # tensor-parallel rows: int8/chunk8 under a 1xN mesh, with a stream
    # long enough that every slot stays occupied (the hardcoded 6-request
    # smoke workload drains before occupancy stabilizes)
    import jax
    n_dev = len(jax.devices())
    p_mesh = dict(p, requests=max(p["requests"], 4 * p["n_slots"]))
    report["mesh"] = {"sizes": list(mesh_sizes), "devices": n_dev,
                      "requests": p_mesh["requests"]}
    for msize in mesh_sizes:
        label = f"axllm-int8/chunk8/mesh{msize}"
        if msize > n_dev:
            report["modes"][label] = {
                "skipped": f"needs {msize} devices, have {n_dev}"}
            continue
        mesh = make_host_mesh(data=1, model=msize)
        report["modes"][label] = _serve(cfg, params, p_mesh, True, 8,
                                        False, mesh=mesh)
    for base in ("bf16", "axllm-int8"):
        t1 = report["modes"][f"{base}/chunk1"]["tokens_per_sec"]
        t8 = report["modes"][f"{base}/chunk8"]["tokens_per_sec"]
        report["decode_chunk_speedup"][base] = round(t8 / t1, 2) if t1 else 0.0
    # dual-pipeline overhead: base-only vs mixed base+2-adapters stream on
    # the same int8/chunk8 engine (>= 1.0 means LoRA serving costs that
    # factor in tok/s; the acceptance bar is <= 1.3x)
    t_base = report["modes"]["axllm-int8/chunk8"]["tokens_per_sec"]
    t_lora = report["modes"]["axllm-int8/chunk8/multi-lora"]["tokens_per_sec"]
    report["multi_lora"] = {
        "n_adapters": 2,
        "tokens_per_sec": t_lora,
        "base_tokens_per_sec": t_base,
        "overhead_vs_base": round(t_base / t_lora, 3) if t_lora else 0.0,
    }
    # open-loop arrivals on the paged int8/chunk8 engine. "steady" offers
    # ~60% of the measured closed-loop capacity (queueing stays bounded,
    # TTFT percentiles are meaningful); "overload" offers 3x capacity into
    # a bounded queue under the reject policy with mixed priorities and a
    # queue-wait deadline, so the shed counters (rejected / expired /
    # preempted) and tail latencies show the admission-control behavior.
    cap_tok_s = report["modes"]["axllm-int8/chunk8/paged"]["tokens_per_sec"]
    cap_rps = cap_tok_s / p["max_new"] if cap_tok_s else 1.0
    p_ol = dict(p, requests=max(p["requests"], 4 * p["n_slots"]))
    steady_spec = arrival or f"poisson:{round(0.6 * cap_rps, 3)}"
    steady, warm_ol = _serve_open_loop(cfg, params, p_ol, steady_spec,
                                       "steady")
    over, _ = _serve_open_loop(
        cfg, params, p_ol, f"poisson:{round(3.0 * cap_rps, 3)}", "overload",
        admission="reject", max_queue=p["n_slots"],
        deadline_s=round(2.0 / cap_rps, 3), priorities=(0, 9),
        warm=warm_ol)
    report["open_loop"] = {
        "capacity_rps_estimate": round(cap_rps, 3),
        "steady": steady,
        "overload": over,
    }
    # long-prompt interleave: a 4k-token prompt arriving mid-decode, with
    # and without a chunked-prefill budget — the acceptance bars are the
    # budgeted short-stream p99 gap under its floors ceiling and total
    # throughput within 20% of the unbudgeted path
    lp = p["long_prompt"]
    lp_b = _serve_long_prompt_interleave(cfg, params, lp, lp["budget"])
    lp_u = _serve_long_prompt_interleave(cfg, params, lp, None)
    report["long_prompt_interleave"] = {
        "workload": dict(lp),
        "budgeted": lp_b,
        "unbudgeted": lp_u,
        "throughput_ratio": round(
            lp_b["tokens_per_sec"] / lp_u["tokens_per_sec"], 3)
        if lp_u["tokens_per_sec"] else 0.0,
    }
    # speculative decoding: int8 target + int4 draft vs the target-only
    # int8/chunk8 engine on the identical stream — the acceptance bars are
    # accepted_tokens_per_step > 1 and bit-identical output
    report["speculative"] = _serve_speculative(cfg, params, p)
    # shared-prefix workload: paged + prefix reuse vs dense on the same
    # stream — the acceptance bar is >= 1.5x effective prefill throughput
    sp = p["shared_prefix"]
    dense_sp = _serve_shared_prefix(cfg, params, sp, p["n_slots"],
                                    paged=False)
    paged_sp = _serve_shared_prefix(cfg, params, sp, p["n_slots"],
                                    paged=True)
    e_d = dense_sp["effective_prefill_tok_s"]
    e_p = paged_sp["effective_prefill_tok_s"]
    report["shared_prefix"] = {
        "workload": dict(sp),
        "dense": dense_sp,
        "paged": paged_sp,
        "prefill_speedup": round(e_p / e_d, 2) if e_d else 0.0,
    }
    return report


def run():
    """benchmarks.run harness entry."""
    rep = bench(smoke=True)
    rows = []
    for label, m in rep["modes"].items():
        if "skipped" in m:
            rows.append((f"serve/{label}", 0.0, m["skipped"]))
            continue
        us = 1e6 * m["wall_s"] / max(m["generated_tokens"], 1)
        rows.append((f"serve/{label}", us,
                     f"tok/s={m['tokens_per_sec']};"
                     f"occ={m['stats']['mean_occupancy']:.2f}"))
    for base, s in rep["decode_chunk_speedup"].items():
        rows.append((f"serve/{base}/chunk_speedup", 0.0, f"{s}x"))
    ml = rep["multi_lora"]
    rows.append(("serve/multi_lora/overhead", 0.0,
                 f"{ml['overhead_vs_base']}x vs base-only"))
    sp = rep["shared_prefix"]
    rows.append(("serve/shared_prefix/prefill_speedup", 0.0,
                 f"{sp['prefill_speedup']}x eff-prefill; "
                 f"hits={sp['paged']['prefix_hit_tokens']}"))
    sd = rep["speculative"]
    rows.append(("serve/speculative", 0.0,
                 f"acc={sd['acceptance_rate']} "
                 f"tok/step={sd['accepted_tokens_per_step']} "
                 f"identical={sd['identical_output']}"))
    for key in ("steady", "overload"):
        r = rep["open_loop"][key]
        rows.append((f"serve/open_loop/{key}", 0.0,
                     f"{r['arrival']} ttft_p99={r['ttft_s']['p99']}s "
                     f"rej={r['rejected']} exp={r['expired']} "
                     f"pre={r['preempted']}"))
    li = rep["long_prompt_interleave"]
    rows.append(("serve/long_prompt_interleave", 0.0,
                 f"gap_p99={li['budgeted']['short_stream_gap_s']['p99']}s "
                 f"(unbudgeted "
                 f"{li['unbudgeted']['short_stream_gap_s']['p99']}s) "
                 f"tput_ratio={li['throughput_ratio']}"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI (seconds, not minutes)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--requests", type=int, default=None,
                    help="override the workload's request count (the meshN "
                         "rows further raise it to >= 4*n_slots so slots "
                         "stay occupied)")
    ap.add_argument("--prompt-pool", default=None,
                    help="comma list of prompt lengths cycled over the "
                         "stream (overrides the workload's prompt_lens)")
    ap.add_argument("--mesh", default=",".join(map(str, MESH_SIZES)),
                    help="comma list of tensor-parallel mesh sizes for the "
                         "meshN rows (empty string disables them)")
    ap.add_argument("--arrival", default=None,
                    help="open-loop arrival process for the steady row, "
                         "'poisson:<rate>' or 'fixed:<rate>' in requests/s "
                         "(default: poisson at 60%% of measured capacity); "
                         "the overload row always offers 3x capacity")
    args = ap.parse_args(argv)
    if args.arrival:
        from repro.serve.scheduler import parse_arrival
        parse_arrival(args.arrival)      # fail fast on a bad spec
    pool = None
    if args.prompt_pool:
        pool = tuple(int(x) for x in args.prompt_pool.split(",") if x)
    sizes = tuple(int(x) for x in args.mesh.split(",") if x)
    rep = bench(smoke=args.smoke, requests=args.requests, prompt_pool=pool,
                mesh_sizes=sizes, arrival=args.arrival)
    rep["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    with open(args.out, "w") as f:
        json.dump(rep, f, indent=2, sort_keys=True)
    for label, m in rep["modes"].items():
        if "skipped" in m:
            print(f"[{label}] skipped: {m['skipped']}")
            continue
        print(f"[{label}] {m['generated_tokens']} tokens "
              f"{m['tokens_per_sec']} tok/s "
              f"occupancy {m['stats']['mean_occupancy']:.2f} "
              f"({m['stats']['steps']} steps, "
              f"{m['stats']['decode_chunks']} dispatches)")
    for base, s in rep["decode_chunk_speedup"].items():
        print(f"decode_chunk=8 vs 1 [{base}]: {s}x tok/s")
    ml = rep["multi_lora"]
    print(f"multi-LoRA (2 adapters) overhead vs base-only: "
          f"{ml['overhead_vs_base']}x tok/s")
    for key in ("steady", "overload"):
        r = rep["open_loop"][key]
        print(f"open-loop [{key}] {r['arrival']}: "
              f"{r['completed']} completed, ttft p50/p99 "
              f"{r['ttft_s']['p50']}/{r['ttft_s']['p99']}s, itl p50/p99 "
              f"{r['inter_token_s']['p50']}/{r['inter_token_s']['p99']}s, "
              f"rejected={r['rejected']} expired={r['expired']} "
              f"preempted={r['preempted']}")
    li = rep["long_prompt_interleave"]
    print(f"long-prompt interleave ({li['workload']['long_len']} tokens "
          f"mid-decode): short-stream gap p99 "
          f"{li['budgeted']['short_stream_gap_s']['p99']}s budgeted "
          f"(budget={li['workload']['budget']}) vs "
          f"{li['unbudgeted']['short_stream_gap_s']['p99']}s unbudgeted, "
          f"throughput ratio {li['throughput_ratio']}")
    sd = rep["speculative"]
    print(f"speculative (k={sd['spec_k']}, int{sd['draft_bits']} draft): "
          f"{sd['tokens_per_sec']} tok/s vs target-only "
          f"{sd['target_only_tokens_per_sec']}, acceptance "
          f"{sd['acceptance_rate']}, {sd['accepted_tokens_per_step']} "
          f"accepted tok/step, identical_output={sd['identical_output']}")
    sp = rep["shared_prefix"]
    print(f"shared-prefix: paged effective prefill "
          f"{sp['paged']['effective_prefill_tok_s']} tok/s vs dense "
          f"{sp['dense']['effective_prefill_tok_s']} tok/s "
          f"({sp['prefill_speedup']}x, "
          f"{sp['paged']['prefix_hit_tokens']} prefix-hit tokens)")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
