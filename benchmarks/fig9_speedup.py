"""Paper Fig. 9 + absolute-cycle check: AxLLM vs multiplier-only baseline on
the Table I models (64 lanes, 256-entry buffers, 4 slices)."""

from __future__ import annotations

from benchmarks.common import Row, cycles_to_us
from repro.core import simulator as S


def run() -> list:
    rows: list = []
    for name, spec in S.PAPER_MODELS.items():
        # llama models: simulate one layer's matrices and scale (identical
        # statistics per layer; keeps the harness < minutes on 1 core)
        rep = S.simulate_model(spec, S.SimConfig())
        rows.append((f"fig9/{name}", cycles_to_us(rep.cycles_axllm),
                     f"speedup={rep.speedup:.3f},reuse={rep.reuse_rate:.3f}"))
        if name == "distilbert":
            rows.append((f"fig9/{name}/absolute_Mcycles",
                         cycles_to_us(rep.cycles_axllm),
                         f"axllm={rep.cycles_axllm/1e6:.2f}M,"
                         f"base={rep.cycles_baseline/1e6:.2f}M,"
                         f"paper=85.11M/159.34M"))
    sps = []
    for r in rows:
        if "speedup=" in r[2]:
            sps.append(float(r[2].split("speedup=")[1].split(",")[0]))
    rows.append(("fig9/avg_speedup_vs_paper_1.7", 0.0,
                 f"avg={sum(sps)/len(sps):.3f}"))
    return rows
