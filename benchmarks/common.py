"""Shared helpers for the benchmark harness. Every bench module exposes
run() -> list[(name, us_per_call, derived)] rows; benchmarks.run prints the
combined CSV. Simulated-cycle benches report cycles/1000 as us_per_call
(1 GHz clock, paper §IV timing)."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]


def timeit(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (jax: blocks on result)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def cycles_to_us(cycles: float, f_ghz: float = 1.0) -> float:
    return cycles / (f_ghz * 1e3)
