"""Shared helpers for the benchmark harness. Every bench module exposes
run() -> list of rows; benchmarks.run prints the combined CSV and persists
them to BENCH_kernel.json. Simulated-cycle benches report cycles/1000 as
us_per_call (1 GHz clock, paper §IV timing).

A row is either the legacy 3-tuple ``(name, value, derived)`` or — via
:func:`row` — a 4-tuple whose last element is a provenance dict
``{"impl", "backend", "units"}``. Provenance exists because a value alone
is ambiguous: a CPU ``impl="ref"`` timing is not comparable to a TPU Pallas
timing of the same op, and a reuse *rate* is not a microsecond.
``tools/check_bench.py`` only compares rows whose provenance matches."""

from __future__ import annotations

import time
from typing import Callable, List, Optional, Tuple, Union

Meta = dict
Row = Union[Tuple[str, float, str], Tuple[str, float, str, Meta]]


def backend() -> str:
    """The live jax backend name ("cpu" / "tpu" / ...)."""
    import jax
    return jax.default_backend()


def row(name: str, value: float, derived: str, *, impl: str,
        units: str = "us_per_call", backend_name: Optional[str] = None
        ) -> Row:
    """A bench row with provenance: which impl produced ``value``, on what
    backend, in what units. ``impl`` is the kernels.ops dispatch string
    ("ref", "pallas_interpret", ...) or "jnp"/"sim" for non-ops code."""
    return (name, value, derived,
            {"impl": impl, "backend": backend_name or backend(),
             "units": units})


def row_meta(r: Row) -> Meta:
    """Provenance of a row; {} for legacy 3-tuples."""
    return r[3] if len(r) > 3 else {}


def timeit(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (jax: blocks on result)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def cycles_to_us(cycles: float, f_ghz: float = 1.0) -> float:
    return cycles / (f_ghz * 1e3)
