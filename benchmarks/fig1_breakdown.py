"""Paper Fig. 1: contribution of each part to one DistilBERT layer's
computation — establishes that the linear-projection + feed-forward matmuls
AxLLM targets dominate the layer."""

from __future__ import annotations

from benchmarks.common import Row, cycles_to_us


def run() -> list:
    d, dff, seq, heads = 768, 3072, 236, 12
    hd = d // heads
    # multiplies per token
    parts = {
        "linear_projection_qkvo": 4 * d * d,
        "feed_forward": 2 * d * dff,
        "attention_scores": 2 * seq * d,      # QK^T + PV per token avg
        "softmax_other": 5 * heads * seq,     # exp/sum/scale estimate
    }
    total = sum(parts.values())
    rows: list = []
    covered = (parts["linear_projection_qkvo"] + parts["feed_forward"]) \
        / total
    for name, ops in parts.items():
        rows.append((f"fig1/{name}", cycles_to_us(ops * seq / 64),
                     f"share={ops / total:.3f}"))
    rows.append(("fig1/axllm_target_share", 0.0,
                 f"target_share={covered:.3f} (paper: dominant)"))
    return rows
