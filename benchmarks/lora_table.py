"""Paper §V LoRA results: A-row overlap (~90%) and adapter-matrix speedup
(1.82x BERT / 1.81x DistilBERT) via the combined [W ‖ A] scheme (Fig. 5)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row
from repro.core import simulator as S


def run() -> list:
    rows: list = []
    for name, d, rank in (("bert-imdb", 768, 16),
                          ("distilbert-yelp", 768, 16)):
        rng = np.random.default_rng(hash(name) % 2 ** 31)
        w = S.gaussian_codes(rng, d, d)
        a = S.gaussian_codes(rng, d, rank)
        out = S.simulate_lora(w, a, S.SimConfig())
        rows.append((f"lora/{name}", 0.0,
                     f"adapter_speedup={out['adapter_speedup']:.2f},"
                     f"overlap={out['row_overlap']:.3f},"
                     f"paper=1.8x/0.90"))
    return rows
