"""Paper §V comparison with ShiftAddLLM [9]: cycle ratio at matched 64-unit
configuration (paper: AxLLM 29% faster on DistilBERT) + the exactness
comparison (AxLLM is exact w.r.t. the int8 model; ShiftAdd approximates —
our greedy binarization is a lower bound on their optimized variant)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, cycles_to_us
from repro.core import shiftadd as SA
from repro.core import simulator as S


def run() -> list:
    rows: list = []
    for name in ("distilbert", "bert-base"):
        r = SA.compare_vs_axllm(S.PAPER_MODELS[name])
        rows.append((f"shiftadd/{name}",
                     cycles_to_us(r["shiftadd_cycles"]),
                     f"axllm_speedup_over_shiftadd="
                     f"{r['axllm_over_shiftadd']:.3f} (paper: 1.29)"))
    rng = np.random.default_rng(0)
    w = rng.standard_normal((768, 768)).astype(np.float32)
    sa_err = SA.reconstruction_error(w, 8)
    scale = np.abs(w).max(axis=0) / 127
    int8_err = float(np.linalg.norm(w - np.round(w / scale) * scale)
                     / np.linalg.norm(w))
    rows.append(("shiftadd/reconstruction_error", 0.0,
                 f"shiftadd={sa_err:.4f},axllm_int8={int8_err:.4f} "
                 f"(AxLLM exact w.r.t. quantized model)"))
    return rows
