"""Paper §V power results: baseline 0.94 W -> AxLLM 0.67 W on one DistilBERT
layer (the energy model is calibrated to the baseline endpoint only; the
AxLLM power and the -28% reduction are predictions — see core/energy.py)."""

from __future__ import annotations

from benchmarks.common import Row
from repro.core import simulator as S
from repro.core.energy import power_report


def run() -> list:
    rows: list = []
    for name in ("distilbert", "bert-base", "llama-7b"):
        spec = S.PAPER_MODELS[name]
        rep = S.simulate_model(spec, S.SimConfig())
        pr = power_report(rep)
        rows.append((f"power/{name}", 0.0,
                     f"base={pr['power_baseline_w']:.2f}W,"
                     f"axllm={pr['power_axllm_w']:.2f}W,"
                     f"reduction={pr['power_reduction']:.3f},"
                     f"energy_reduction={pr['energy_reduction']:.3f}"))
    rows.append(("power/paper_reference", 0.0,
                 "paper: 0.94W -> 0.67W (28%); energy -28% at 1.7x speed"))
    return rows
