"""Roofline summary rows from the dry-run JSON records (results/dryrun/):
per (arch x shape) — the three terms, dominant bottleneck, MODEL_FLOPS
ratio. Requires launch/dryrun.py to have populated the cache; rows are
omitted (not failed) for cells not yet run so benchmarks.run works at any
sweep stage."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Row
from repro.configs import get_config
from repro.launch.shapes import SHAPES
from repro.roofline import analysis as ra


def _load(results_dir="results/dryrun"):
    recs = {}
    for f in glob.glob(os.path.join(results_dir, "*.json")):
        with open(f) as fh:
            r = json.load(fh)
        recs[(r["cell"], r["mesh"], r.get("variant", "axllm-int8"))] = r
    return recs


def corrected_totals(rec):
    """Apply the 1/2-group delta extrapolation (per-device -> global)."""
    aux = rec.get("aux")
    chips = rec["chips"]
    if not aux:
        return None
    ng = aux["n_groups"]
    out = {}
    for key, src in (("flops", "flops"), ("bytes", "bytes"),
                     ("coll", "collective_bytes")):
        c1, c2 = aux["g1"][src], aux["g2"][src]
        if c1 is None or c2 is None:
            return None
        out[key] = ra.extrapolate(c1, c2, ng)
    # train aux runs used a reduced batch; scale to the full global batch
    cell_shape = rec["cell"].split(":")[1]
    spec = SHAPES[cell_shape]
    if spec.kind == "train":
        scale = spec.global_batch / aux["g1"]["aux_batch"]
        for k in out:
            out[k] *= scale
    out["flops_global"] = out["flops"] * chips
    out["bytes_global"] = out["bytes"] * chips
    out["coll_global"] = out["coll"] * chips
    return out


def run() -> list:
    rows: list = []
    recs = _load()
    for (cell, mesh, variant), rec in sorted(recs.items()):
        if mesh != "pod16x16" or variant != "axllm-int8":
            continue
        if rec["status"] == "skipped":
            rows.append((f"roofline/{cell}", 0.0, "SKIP: " + rec["reason"][:60]))
            continue
        if rec["status"] != "ok":
            rows.append((f"roofline/{cell}", 0.0, "ERROR"))
            continue
        arch, shape = cell.split(":")
        cfg = get_config(arch)
        spec = SHAPES[shape]
        corr = corrected_totals(rec)
        if corr is None:
            # fall back to raw per-device cost (scan-undercounted; flagged)
            flops_g = (rec["cost_analysis"].get("flops") or 0) * rec["chips"]
            bytes_g = (rec["cost_analysis"].get("bytes accessed") or 0) \
                * rec["chips"]
            coll_g = rec["collective_bytes"] * rec["chips"]
            tag = "RAW(scan-undercount)"
        else:
            flops_g, bytes_g, coll_g = (corr["flops_global"],
                                        corr["bytes_global"],
                                        corr["coll_global"])
            tag = "corrected"
        terms = ra.roofline_terms(flops_g, bytes_g, coll_g, rec["chips"])
        mf = ra.model_flops(cfg, spec.kind, spec.seq, spec.global_batch)
        ratio = mf / flops_g if flops_g else float("nan")
        rows.append((
            f"roofline/{cell}", terms["bound_step_s"] * 1e6,
            f"dom={terms['dominant']},comp={terms['compute_s']:.2e}s,"
            f"mem={terms['memory_s']:.2e}s,coll={terms['collective_s']:.2e}s,"
            f"model/hlo_flops={ratio:.2f},{tag}"))
    return rows
