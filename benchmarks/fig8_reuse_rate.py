"""Paper Fig. 8: computation-reuse rate per model, unbounded vs 256-entry
buffers. Also cross-checks the statistics on REAL trained weights from the
examples/train_lm.py checkpoint when one exists (weights are then not
Gaussian surrogates but actual SGD products)."""

from __future__ import annotations

import os

import numpy as np

from benchmarks.common import Row
from repro.core import reuse as R
from repro.core import simulator as S


def run() -> list:
    rows: list = []
    rng = np.random.default_rng(0)
    dims = {"distilbert": 768, "bert-base": 768, "bert-large": 1024,
            "llama-7b": 4096, "llama-13b": 5120}
    for name, d in dims.items():
        codes = S.gaussian_codes(np.random.default_rng(0), d, d)
        full = R.reuse_rate(codes, None)
        seg = R.reuse_rate(codes, 256)
        rows.append((f"fig8/{name}/full_row", 0.0, f"reuse={full:.3f}"))
        rows.append((f"fig8/{name}/buf256", 0.0, f"reuse={seg:.3f}"))
    # paper claims: min >= 0.87 full; ~0.70 average at 256
    fulls = [float(r[2].split("=")[1]) for r in rows if "full" in r[0]]
    segs = [float(r[2].split("=")[1]) for r in rows if "buf256" in r[0]]
    rows.append(("fig8/min_full_vs_paper_0.87", 0.0,
                 f"min={min(fulls):.3f}"))
    rows.append(("fig8/avg_256_vs_paper_0.70", 0.0,
                 f"avg={sum(segs)/len(segs):.3f}"))

    ckpt = "results/train_lm/quantized_codes.npz"
    if os.path.exists(ckpt):
        data = np.load(ckpt)
        rates = [R.reuse_rate(data[k], 256) for k in data.files]
        rows.append(("fig8/trained_100m_buf256", 0.0,
                     f"reuse={np.mean(rates):.3f} (real trained weights)"))
    return rows
