# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (benchmarks.common.Row) and persists every module's rows to
# BENCH_kernel.json at the repo root (the artifact CI uploads — without it
# the kernel bench trajectory was never recorded). Modules:
#   fig1_breakdown    paper Fig. 1   layer computation shares
#   fig8_reuse_rate   paper Fig. 8   reuse rate per model / buffer budget
#   fig9_speedup      paper Fig. 9   AxLLM vs baseline cycles + absolutes
#   lora_table        paper §V       LoRA overlap + adapter speedup
#   shiftadd_compare  paper §V       vs ShiftAddLLM (cycles + exactness)
#   power_table       paper §V       power/energy model
#   kernel_bench      (framework)    int8/int4 vs f32 matmul, fused QKV,
#                                    chunked decode, block-table sweep
#   roofline_table    (deliverable g) per-cell roofline terms from dry-run
#   serve_bench       (framework)    continuous-batching tok/s + occupancy
#
#   python benchmarks/run.py [substring]   # run only matching modules

from __future__ import annotations

import json
import os
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO_ROOT not in sys.path:   # allow `python benchmarks/run.py` directly
    sys.path.insert(0, _REPO_ROOT)


def main() -> None:
    from benchmarks import (fig1_breakdown, fig8_reuse_rate, fig9_speedup,
                            kernel_bench, lora_table, power_table,
                            roofline_table, serve_bench, shiftadd_compare)

    modules = [fig1_breakdown, fig8_reuse_rate, fig9_speedup, lora_table,
               shiftadd_compare, power_table, kernel_bench, roofline_table,
               serve_bench]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    out = os.path.join(_REPO_ROOT, "BENCH_kernel.json")
    # merge into any existing report so a filtered run (e.g.
    # `run.py kernel_bench`) refreshes only its own modules instead of
    # clobbering the accumulated trajectory
    report = {"rows": {}, "errors": {}}
    if os.path.exists(out):
        try:
            with open(out) as f:
                prev = json.load(f)
            report["rows"] = dict(prev.get("rows", {}))
            report["errors"] = dict(prev.get("errors", {}))
        except (OSError, ValueError):
            pass
    print("name,us_per_call,derived")
    for mod in modules:
        name = mod.__name__.split(".")[-1]
        if only and only not in name:
            continue
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # keep the harness robust mid-development
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
            report["errors"][name] = f"{type(e).__name__}: {e}"
            continue
        report["errors"].pop(name, None)
        # 4th element (when present) is the provenance dict from
        # benchmarks.common.row — persisted so check_bench can refuse to
        # compare rows of different impl/backend/units
        report["rows"][name] = [
            [r[0],
             round(float(r[1]),
                   2 if len(r) < 4 or r[3].get("units") == "us_per_call"
                   else 6),
             str(r[2])] + list(r[3:4])
            for r in rows]
        for r in rows:
            derived = str(r[2]).replace(",", ";")
            print(f"{r[0]},{r[1]:.2f},{derived}")
        print(f"{name}/_elapsed,{(time.time()-t0)*1e6:.0f},-")
    report["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()
