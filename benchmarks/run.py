# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (benchmarks.common.Row). Modules:
#   fig1_breakdown    paper Fig. 1   layer computation shares
#   fig8_reuse_rate   paper Fig. 8   reuse rate per model / buffer budget
#   fig9_speedup      paper Fig. 9   AxLLM vs baseline cycles + absolutes
#   lora_table        paper §V       LoRA overlap + adapter speedup
#   shiftadd_compare  paper §V       vs ShiftAddLLM (cycles + exactness)
#   power_table       paper §V       power/energy model
#   kernel_bench      (framework)    int8/int4 vs f32 matmul + KV bytes
#   roofline_table    (deliverable g) per-cell roofline terms from dry-run
#   serve_bench       (framework)    continuous-batching tok/s + occupancy

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (fig1_breakdown, fig8_reuse_rate, fig9_speedup,
                            kernel_bench, lora_table, power_table,
                            roofline_table, serve_bench, shiftadd_compare)

    modules = [fig1_breakdown, fig8_reuse_rate, fig9_speedup, lora_table,
               shiftadd_compare, power_table, kernel_bench, roofline_table,
               serve_bench]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for mod in modules:
        name = mod.__name__.split(".")[-1]
        if only and only not in name:
            continue
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # keep the harness robust mid-development
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
            continue
        for r in rows:
            derived = str(r[2]).replace(",", ";")
            print(f"{r[0]},{r[1]:.2f},{derived}")
        print(f"{name}/_elapsed,{(time.time()-t0)*1e6:.0f},-")


if __name__ == "__main__":
    main()
