"""Kernel-level microbench on the XLA fallback path (CPU container; the
Pallas kernels target TPU and are validated in interpret mode). Measures the
byte-traffic effect of the AxLLM representation (int8/int4 vs bf16 matmul),
the fused-QKV projection vs three separate matmuls, chunked scan-decode vs
the per-token dispatch loop, sweeps the decode-shape block table
(validating every (bm, bk, bn) choice in Pallas interpret mode), and
records the predicted-vs-achieved computation-reuse rows (simulator
analytic vs the reuse kernel's own multiply counter — see _reuse_rows).
Every row carries {impl, backend, units} provenance (benchmarks.common.row)
so tools/check_bench.py never compares a CPU ref timing against a Pallas
kernel result.

benchmarks/run.py persists these rows to BENCH_kernel.json at the repo root
so the kernel perf trajectory accumulates per-commit."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, row, timeit
from repro.core.quantization import QuantConfig, qconcat, quantize
from repro.kernels import ops


def _matmul_rows(rows, rng):
    m, k, n = 8, 4096, 4096          # decode-like skinny matmul
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    qt8 = quantize(w, QuantConfig(8, "affine", "per_channel"))
    qt4 = quantize(w, QuantConfig(4, "affine", "per_channel", pack=True))

    f_fp = jax.jit(lambda a, b: a @ b)
    f_q8 = jax.jit(lambda a, q: ops.axllm_matmul(a, q, impl="ref"))

    t_fp = timeit(f_fp, x, w)
    t_q8 = timeit(f_q8, x, qt8)
    t_q4 = timeit(f_q8, x, qt4)
    bytes_fp = k * n * 4
    bytes_q8 = k * n + n * 4
    bytes_q4 = k * n // 2 + n * 4
    rows.append(row("kernel/matmul_f32", t_fp,
                    f"weight_bytes={bytes_fp}", impl="jnp"))
    rows.append(row("kernel/matmul_axllm_int8", t_q8,
                    f"weight_bytes={bytes_q8} ({bytes_fp/bytes_q8:.1f}x "
                    f"less)", impl="ref"))
    rows.append(row("kernel/matmul_axllm_int4", t_q4,
                    f"weight_bytes={bytes_q4} ({bytes_fp/bytes_q4:.1f}x "
                    f"less)", impl="ref"))


def _fused_qkv_rows(rows, rng):
    """One [K, (H+2Hk)·hd] fused matmul vs three separate Q/K/V matmuls
    (GQA shapes: the K/V projections are narrower than Q)."""
    m, k = 8, 2048
    n_q, n_kv = 2048, 512
    qcfg = QuantConfig(8, "affine", "per_channel")
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    wq = quantize(jnp.asarray(rng.standard_normal((k, n_q)), jnp.float32),
                  qcfg)
    wk = quantize(jnp.asarray(rng.standard_normal((k, n_kv)), jnp.float32),
                  qcfg)
    wv = quantize(jnp.asarray(rng.standard_normal((k, n_kv)), jnp.float32),
                  qcfg)
    wqkv = qconcat([wq, wk, wv])

    f3 = jax.jit(lambda a, q1, q2, q3: (
        ops.axllm_matmul(a, q1, impl="ref"),
        ops.axllm_matmul(a, q2, impl="ref"),
        ops.axllm_matmul(a, q3, impl="ref")))
    f1 = jax.jit(lambda a, q: ops.axllm_matmul(a, q, impl="ref"))
    t3 = timeit(f3, x, wq, wk, wv)
    t1 = timeit(f1, x, wqkv)
    rows.append(row("kernel/qkv_3matmuls", t3,
                    "3 launches; 3 codebook loads", impl="ref"))
    rows.append(row("kernel/qkv_fused", t1,
                    f"1 launch; {t3/max(t1, 1e-9):.2f}x vs separate",
                    impl="ref"))


def _chunked_decode_rows(rows):
    """Per-token dispatch loop (host sync + sample every step) vs one
    on-device decode_steps scan — the serve engine's hot-loop choice."""
    from repro.configs.base import ModelConfig
    from repro.models.model import get_model
    from repro.serve.decode import decode_steps

    cfg = ModelConfig(name="kb-decode", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=256, head_dim=16, vocab_pad_multiple=64,
                      dtype="float32")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b, steps = 4, 16
    cache = api.init_cache(b, 64)
    toks = jnp.ones((b, 8), jnp.int32)
    logits, cache = jax.jit(
        lambda p, t, c: api.prefill(p, {"tokens": t}, c))(params, toks, cache)
    last = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    dec = jax.jit(api.decode)

    def per_token(params, last, cache):
        for _ in range(steps):
            lg, cache = dec(params, last, cache)
            # host round-trip: sample in NumPy like the old engine loop
            last = jnp.asarray(
                np.argmax(np.asarray(lg[:, : cfg.vocab_size]), -1),
                jnp.int32)
        return last

    chunk = jax.jit(lambda p, l, c, r: decode_steps(
        api.decode, p, l, c, r, jnp.zeros((b,), bool),
        jnp.ones((b,), jnp.int32), jnp.full((b,), steps + 1, jnp.int32),
        n=steps, vocab_size=cfg.vocab_size, max_len=64).tokens)

    rng = jax.random.PRNGKey(0)
    t_loop = timeit(per_token, params, last, cache) / steps
    t_scan = timeit(chunk, params, last, cache, rng) / steps
    rows.append(row("kernel/decode_per_token", t_loop,
                    f"{steps} dispatches + host sampling", impl="auto"))
    rows.append(row("kernel/decode_chunked_scan", t_scan,
                    f"1 dispatch; {t_loop/max(t_scan, 1e-9):.2f}x vs "
                    f"per-token", impl="auto"))


def _block_table_rows(rows, rng):
    """Decode-shape block-table sweep: every picked (bm, bk, bn) is
    validated against the jnp oracle in Pallas interpret mode, and the
    no-pad fast path (pad_m == 0 for m in 8..64 multiples of 8) is
    asserted rather than trusted."""
    k, n = 256, 256
    qcfg = QuantConfig(8, "affine", "per_channel")
    w = quantize(jnp.asarray(rng.standard_normal((k, n)), jnp.float32), qcfg)
    for m in (1, 4, 8, 16, 24, 32, 48, 64, 100, 128):
        bm, bk, bn, pad_m = ops.pick_blocks(m, k, n)
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        y_ref = ops.axllm_matmul(x, w, impl="ref")
        y_pal = ops.axllm_matmul(x, w, impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                                   rtol=2e-5, atol=2e-4)
        if 8 <= m < 128 and m % 8 == 0:
            assert pad_m == 0, f"m={m} should hit the no-pad fast path"
        t = timeit(jax.jit(lambda a: ops.axllm_matmul(a, w, impl="ref")), x)
        rows.append(row(f"kernel/blocks_m{m}", t,
                        f"bm={bm};bk={bk};bn={bn};pad_m={pad_m};"
                        f"interpret=ok", impl="ref"))


def _reuse_rows(rows, rng):
    """Predicted vs achieved computation reuse (paper §III.b) — the first
    place the simulator's model and the kernel's measurement meet.

    *Predicted* is ``core.reuse.reuse_rate`` on the quantized codes at the
    kernel's own column-segment width (the same analytic that feeds the
    Fig. 8 table and ``simulator.simulate_matrix``). *Achieved* is
    ``1 - mults / (K*N)`` where ``mults`` is the multiply count the reuse
    kernel itself tallies while running in interpret mode — distinct
    alphabet cells per (k-row, bn segment). The two are computed by
    disjoint code paths (numpy bincount vs in-kernel one-hot reduction)
    and must agree to |diff| <= 1e-6 (gated in
    benchmarks/kernel_floors.json at 0.01 for runner safety). Also times
    the reuse jnp oracle against the multiply-dequant ref like-for-like
    (same backend/units; impl differs by construction)."""
    from repro.core.reuse import rc_alphabet, reuse_rate

    m, k, n = 8, 1024, 1024
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    for bits, mode in ((8, "affine"), (4, "codebook")):
        tag = f"{mode}{bits}"
        qt = quantize(w, QuantConfig(bits, mode, "per_channel"))
        levels, fold = rc_alphabet(bits, mode)
        _, _, bn, _ = ops.pick_blocks(m, k, n, reuse_levels=len(levels))
        # pass the QTensor, not qt.codes: int4 codes are packed two-per-
        # byte and the analytics must see decoded signed codes
        predicted = reuse_rate(qt, segment=bn, fold_sign=fold)
        _, mults = ops.reuse_matmul(x, qt, impl="reuse_interpret",
                                    with_stats=True)
        achieved = 1.0 - int(mults) / (k * n)
        rows.append(row(f"kernel/reuse_predicted_{tag}", predicted,
                        f"segment={bn};fold_sign={fold}", impl="sim",
                        units="reuse_rate"))
        rows.append(row(f"kernel/reuse_achieved_{tag}", achieved,
                        f"mults={int(mults)}/{k*n}; "
                        f"|pred-ach|={abs(predicted-achieved):.2e}",
                        impl="reuse_interpret", units="reuse_rate"))

    qt8 = quantize(w, QuantConfig(8, "affine", "per_channel"))
    f_mul = jax.jit(lambda a, q: ops.axllm_matmul(a, q, impl="ref"))
    f_reu = jax.jit(lambda a, q: ops.axllm_matmul(a, q, impl="reuse_ref"))
    t_mul = timeit(f_mul, x, qt8)
    t_reu = timeit(f_reu, x, qt8)
    rows.append(row("kernel/matmul_multiply_ref_int8", t_mul,
                    "dequant+MAC every code", impl="ref"))
    rows.append(row("kernel/matmul_reuse_ref_int8", t_reu,
                    "LUT build + gather (XLA oracle of the reuse kernel)",
                    impl="reuse_ref"))


def run() -> list:
    rows: list = []
    rng = np.random.default_rng(0)
    _matmul_rows(rows, rng)
    _fused_qkv_rows(rows, rng)
    _chunked_decode_rows(rows)
    _block_table_rows(rows, rng)
    _reuse_rows(rows, rng)

    # decode attention: bf16 KV vs int8 KV (bytes halve)
    b, s, h, hk, d = 4, 8192, 8, 2, 128
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, s, hk, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, s, hk, d)), jnp.float32)
    sc = jnp.maximum(jnp.abs(kc).max(-1, keepdims=True), 1e-8) / 127
    kq = jnp.clip(jnp.round(kc / sc), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(vc / sc), -127, 127).astype(jnp.int8)
    length = jnp.full((b,), s, jnp.int32)
    f_fp = jax.jit(lambda *a: ops.decode_attention(*a, impl="ref"))
    f_q = jax.jit(lambda q_, k_, v_, l_, ks_, vs_: ops.decode_attention(
        q_, k_, v_, l_, k_scale=ks_, v_scale=vs_, impl="ref"))
    t1 = timeit(f_fp, q, kc, vc, length)
    t2 = timeit(f_q, q, kq, vq, length, sc, sc)
    rows.append(row("kernel/decode_attn_f32kv", t1,
                    f"kv_bytes={2*b*s*hk*d*4}", impl="ref"))
    rows.append(row("kernel/decode_attn_int8kv", t2,
                    f"kv_bytes={2*b*s*hk*(d+4)} (≈4x less than f32)",
                    impl="ref"))
    return rows
