"""Kernel-level microbench on the XLA fallback path (CPU container; the
Pallas kernels target TPU and are validated in interpret mode). Measures the
byte-traffic effect of the AxLLM representation: int8-code matmul vs bf16
matmul wall time + the derived HBM-byte ratio the TPU roofline uses."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timeit
from repro.core.quantization import QuantConfig, quantize
from repro.kernels import ops


def run() -> list:
    rows: list = []
    rng = np.random.default_rng(0)
    m, k, n = 8, 4096, 4096          # decode-like skinny matmul
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    qt8 = quantize(w, QuantConfig(8, "affine", "per_channel"))
    qt4 = quantize(w, QuantConfig(4, "affine", "per_channel", pack=True))

    f_fp = jax.jit(lambda a, b: a @ b)
    f_q8 = jax.jit(lambda a, q: ops.axllm_matmul(a, q, impl="ref"))

    t_fp = timeit(f_fp, x, w)
    t_q8 = timeit(f_q8, x, qt8)
    t_q4 = timeit(f_q8, x, qt4)
    bytes_fp = k * n * 4
    bytes_q8 = k * n + n * 4
    bytes_q4 = k * n // 2 + n * 4
    rows.append(("kernel/matmul_f32", t_fp, f"weight_bytes={bytes_fp}"))
    rows.append(("kernel/matmul_axllm_int8", t_q8,
                 f"weight_bytes={bytes_q8} ({bytes_fp/bytes_q8:.1f}x less)"))
    rows.append(("kernel/matmul_axllm_int4", t_q4,
                 f"weight_bytes={bytes_q4} ({bytes_fp/bytes_q4:.1f}x less)"))

    # decode attention: bf16 KV vs int8 KV (bytes halve)
    b, s, h, hk, d = 4, 8192, 8, 2, 128
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, s, hk, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, s, hk, d)), jnp.float32)
    sc = jnp.maximum(jnp.abs(kc).max(-1, keepdims=True), 1e-8) / 127
    kq = jnp.clip(jnp.round(kc / sc), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(vc / sc), -127, 127).astype(jnp.int8)
    length = jnp.full((b,), s, jnp.int32)
    f_fp = jax.jit(lambda *a: ops.decode_attention(*a, impl="ref"))
    f_q = jax.jit(lambda q_, k_, v_, l_, ks_, vs_: ops.decode_attention(
        q_, k_, v_, l_, k_scale=ks_, v_scale=vs_, impl="ref"))
    t1 = timeit(f_fp, q, kc, vc, length)
    t2 = timeit(f_q, q, kq, vq, length, sc, sc)
    rows.append(("kernel/decode_attn_f32kv", t1,
                 f"kv_bytes={2*b*s*hk*d*4}"))
    rows.append(("kernel/decode_attn_int8kv", t2,
                 f"kv_bytes={2*b*s*hk*(d+4)} (≈4x less than f32)"))
    return rows
